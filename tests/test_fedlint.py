"""Tests for the fedlint static-analysis pass (tools/fedlint).

Fixture pairs under ``tests/fedlint_fixtures/`` pin each rule's behavior:
the ``*_bad.py`` file must produce exactly its expected findings, the
``*_clean.py`` twin none. CLI tests run ``python -m fedlint`` as a
subprocess the way CI does; the repo-gate test asserts the shipped tree
is fedlint-clean.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from fedlint.core import (load_baseline, split_baselined, suppressed_rules,
                          write_baseline)
from fedlint.runner import run

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).resolve().parent / "fedlint_fixtures"

#: rule id -> (expected finding count in its firing fixture, expected lines)
EXPECTED = {
    "FL001": 3,
    "FL002": 1,
    "FL003": 2,
    "FL004": 1,
    "FL005": 4,
    "FL006": 2,
    "FL007": 3,
}


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "tools")
    return env


def _fedlint(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "fedlint", *args],
        capture_output=True, text=True, env=_env(), cwd=cwd)


# -- per-rule fixture pairs --------------------------------------------------

@pytest.mark.parametrize("rule", sorted(EXPECTED))
def test_firing_fixture(rule):
    path = FIXTURES / f"{rule.lower()}_bad.py"
    findings = run([path], select=[rule], root=REPO)
    assert len(findings) == EXPECTED[rule], [f.message for f in findings]
    assert all(f.rule == rule for f in findings)
    assert all(f.path.endswith(f"{rule.lower()}_bad.py") for f in findings)


@pytest.mark.parametrize("rule", sorted(EXPECTED))
def test_clean_fixture(rule):
    path = FIXTURES / f"{rule.lower()}_clean.py"
    findings = run([path], select=[rule], root=REPO)
    assert findings == [], [f.message for f in findings]


def test_bad_fixtures_fire_without_select():
    """Running all rules over all firing fixtures finds at least the per-
    rule expectations (cross-rule extras are allowed in this mode)."""
    findings = run([FIXTURES / f"{r.lower()}_bad.py" for r in EXPECTED],
                   root=REPO)
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
    for rule, count in EXPECTED.items():
        assert len(by_rule.get(rule, [])) >= count, rule


# -- suppressions ------------------------------------------------------------

def test_inline_suppression(tmp_path):
    src = (FIXTURES / "fl004_bad.py").read_text()
    suppressed = src.replace(
        "    b = jax.random.normal(rng, (4,))",
        "    # fedlint: disable=FL004 -- correlated draws are intended here\n"
        "    b = jax.random.normal(rng, (4,))")
    target = tmp_path / "suppressed.py"
    target.write_text(suppressed)
    assert run([target], select=["FL004"], root=tmp_path) == []
    # the marker only silences the named rule
    assert suppressed_rules(["x = 1  # fedlint: disable=FL001,FL004"], 1) \
        == {"FL001", "FL004"}


# -- baseline round trip -----------------------------------------------------

def test_baseline_round_trip(tmp_path):
    findings = run([FIXTURES / "fl004_bad.py"], select=["FL004"], root=REPO)
    assert findings
    baseline_path = tmp_path / "baseline.json"
    write_baseline(baseline_path, findings)
    baseline = load_baseline(baseline_path)
    new, old = split_baselined(findings, baseline)
    assert new == [] and len(old) == len(findings)
    # a fresh finding in another file is NOT absorbed by the baseline
    other = run([FIXTURES / "fl001_bad.py"], select=["FL001"], root=REPO)
    new2, _ = split_baselined(other, baseline)
    assert len(new2) == len(other)


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == {}


# -- CLI ---------------------------------------------------------------------

def test_cli_exit_codes():
    bad = _fedlint(str(FIXTURES / "fl001_bad.py"), "--no-baseline",
                   "--select", "FL001")
    assert bad.returncode == 1, bad.stdout + bad.stderr
    clean = _fedlint(str(FIXTURES / "fl001_clean.py"), "--no-baseline",
                     "--select", "FL001")
    assert clean.returncode == 0, clean.stdout + clean.stderr


def test_cli_json_schema():
    proc = _fedlint(str(FIXTURES / "fl003_bad.py"), "--no-baseline",
                    "--select", "FL003", "--json")
    assert proc.returncode == 1
    report = json.loads(proc.stdout)
    assert set(report) == {"version", "findings", "summary"}
    assert report["summary"] == {"total": 2, "new": 2, "baselined": 0}
    for f in report["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "message",
                          "baselined"}
        assert f["rule"] == "FL003" and f["baselined"] is False
        assert isinstance(f["line"], int) and f["line"] >= 1


def test_cli_write_baseline_round_trip(tmp_path):
    baseline = tmp_path / "bl.json"
    wrote = _fedlint(str(FIXTURES / "fl006_bad.py"), "--select", "FL006",
                     "--baseline", str(baseline), "--write-baseline")
    assert wrote.returncode == 0, wrote.stdout + wrote.stderr
    reread = _fedlint(str(FIXTURES / "fl006_bad.py"), "--select", "FL006",
                      "--baseline", str(baseline), "--json")
    assert reread.returncode == 0, reread.stdout + reread.stderr
    report = json.loads(reread.stdout)
    assert report["summary"]["new"] == 0
    assert report["summary"]["baselined"] == EXPECTED["FL006"]


def test_cli_list_rules():
    proc = _fedlint("--list-rules")
    assert proc.returncode == 0
    for rule in EXPECTED:
        assert rule in proc.stdout


# -- the shipped tree is clean ----------------------------------------------

def test_repo_gate():
    """`python -m fedlint src/repro --json` exits 0 on the final tree."""
    proc = _fedlint("src/repro", "benchmarks", "--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["summary"]["new"] == 0
