"""The trip-count-aware HLO cost walker vs analytic flops on loop probes —
the §Roofline methodology's validation (EXPERIMENTS.md)."""
import jax
import jax.numpy as jnp
import pytest

from repro.sharding.hlo_cost import analyze, xla_cost_analysis

D = 128
UNIT = 2 * D**3  # one (D,D)@(D,D) matmul


def _flops(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return analyze(comp.as_text())["flops"], xla_cost_analysis(comp)["flops"]


def _xw():
    return (jax.ShapeDtypeStruct((D, D), jnp.float32),
            jax.ShapeDtypeStruct((D, D), jnp.float32))


def _scan_fn(length):
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=length)
        return y.sum()
    return f


def test_scan_trips_counted():
    got, xla_raw = _flops(_scan_fn(7), *_xw())
    assert got == pytest.approx(7 * UNIT, rel=1e-2)
    # and the documented XLA undercount really exists (body counted once)
    assert xla_raw == pytest.approx(UNIT, rel=1e-2)


def test_grad_of_scan():
    f = _scan_fn(7)

    def g(x, w):
        return jax.grad(lambda ww: f(x, ww))(w).sum()

    got, _ = _flops(g, *_xw())
    # fwd (1 dot) + bwd (2 dots) per iteration
    assert got == pytest.approx(21 * UNIT, rel=1e-2)


def test_nested_scans_multiply():
    def h(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y.sum()

    got, _ = _flops(h, *_xw())
    assert got == pytest.approx(15 * UNIT, rel=1e-2)


def test_vmap_counts_real_dims():
    f = _scan_fn(7)

    def v(x, w):
        xx = jnp.stack([x, x, x])
        return jax.vmap(lambda xi: f(xi, w))(xx).sum()

    got, _ = _flops(v, *_xw())
    assert got == pytest.approx(21 * UNIT, rel=1e-2)


def test_bytes_scale_with_trips():
    a5, _ = _flops(_scan_fn(5), *_xw())
    r5 = analyze(jax.jit(_scan_fn(5)).lower(*_xw()).compile().as_text())
    r10 = analyze(jax.jit(_scan_fn(10)).lower(*_xw()).compile().as_text())
    assert r10["bytes"] > 1.5 * r5["bytes"]
