"""FL003 firing fixture: dtype-inheriting accumulator init + scan carry."""
import jax
import jax.numpy as jnp

from repro.core import tree_math as tm


class BadAccum(FedAlgorithm):  # noqa: F821 -- resolved by name, not import
    """Accumulates in whatever dtype the payload happens to carry."""

    def init_accum(self, payload):
        """Zeros that inherit the payload dtype (bf16 re-rounds)."""
        return tm.tzeros_like(payload)

    def make_client_update(self, grad_fn, client_opt):
        """Client update with a dtype-inheriting scan carry."""

        def update(params, batches):
            def accum(carry, batch):
                _, g = grad_fn(params, batch)
                return tm.tadd(carry, g), None

            total, _ = jax.lax.scan(accum, jnp.zeros_like(params), batches)
            return total

        return update
