"""FL004 firing fixture: one key feeds two samplers."""
import jax


def init_params(rng):
    """`rng` is consumed twice — the two draws are correlated."""
    w = jax.random.normal(rng, (4, 4))
    b = jax.random.normal(rng, (4,))
    return w, b
