"""FL005 clean fixture: every contract declared, every knob validated."""
from dataclasses import dataclass


def register_algorithm(name):
    """Stub decorator so the class-contract checks engage."""

    def deco(cls):
        return cls

    return deco


class FedAlgorithm:
    """Stub base marking subclasses for the contract checks."""


@dataclass(frozen=True)
class FedConfig:
    """Stub config whose knob is validated by name at construction."""

    mystery_knob: float = 0.5

    def __post_init__(self):
        """Range-checks mystery_knob eagerly."""
        if not 0.0 <= self.mystery_knob <= 1.0:
            raise ValueError("mystery_knob must be in [0, 1]")


@register_algorithm("tidy")
class Tidy(FedAlgorithm):
    """Declares init_client_state/abstract_payload/broadcast extras."""

    stateful = True

    def init_client_state(self, params):
        """State template for the client store."""
        return params

    def broadcast(self, state, server_opt):
        """Ships extras, with their abstract shapes declared below."""
        return (state,)

    def abstract_broadcast_extras(self, params):
        """Abstract shapes of the broadcast extras."""
        return (params,)

    def payload_accum(self, acc, payload, weight):
        """Reshaped payload, with abstract_payload declared below."""
        return acc

    def abstract_payload(self, params):
        """Abstract shape of the communicated payload."""
        return params

    def make_client_update(self, grad_fn, client_opt):
        """Reads only the knob __post_init__ validates."""
        lr = self.fed.mystery_knob
        return lambda params, batches: (params, lr)
