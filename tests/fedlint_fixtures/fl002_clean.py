"""FL002 clean fixture: the rebind-from-result donation idiom."""
from repro.core.client_state import jit_donating_store

apply_round = jit_donating_store(None, 0, out_shardings=None)


def run(store, batches):
    """Rebinding `store` from the call's result un-poisons the name."""
    store, metrics = apply_round(store, batches)
    return store, metrics
