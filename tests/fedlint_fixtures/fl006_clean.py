"""FL006 clean fixture: donating jits with pinned output shardings."""
import jax

from repro.core.client_state import jit_donating_store


def build(round_fn, out_sh):
    """Donation composed with an explicit out_shardings pin."""
    apply_a = jit_donating_store(round_fn, 3, out_shardings=out_sh)
    apply_b = jax.jit(round_fn, donate_argnums=(0,), out_shardings=out_sh)
    plain = jax.jit(round_fn)
    return apply_a, apply_b, plain
