"""FL002 firing fixture: a donated store read after the donating call."""
from repro.core.client_state import jit_donating_store

apply_round = jit_donating_store(None, 0, out_shardings=None)


def run(store, batches):
    """Reads `store` after its buffer was donated to apply_round."""
    out, metrics = apply_round(store, batches)
    return store, out, metrics
