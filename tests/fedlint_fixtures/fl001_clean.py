"""FL001 clean fixture: only static/host-safe operations under jit."""
import jax
import jax.numpy as jnp


@jax.jit
def good_round(x):
    """Shape-derived casts and jax.debug.print are trace-safe."""
    dim = int(x.shape[0])
    width = float(len(x.shape))
    jax.debug.print("dim={d}", d=dim)
    return jnp.sum(x) * dim * width


@jax.jit
def maybe_host(w):
    """Host math lexically guarded by a Tracer check is exempt."""
    if not isinstance(w, jax.core.Tracer):
        return jnp.asarray(float(w.sum()))
    return jnp.sum(w)
