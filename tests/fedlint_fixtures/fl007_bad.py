"""FL007 firing fixture: history assembly outside core/history.py."""
from repro.core.history import json_scalar


def run_rounds(engine, state, cohorts):
    """A frontend regrowing its own round loop's history assembly."""
    history = []
    for t, cohort in enumerate(cohorts):
        state, metrics = engine.apply(state, cohort)
        # 1) re-converting metrics instead of consuming recorder records
        loss = json_scalar(metrics["loss_last"])
        # 2) a hand-rolled record duplicating the recorder's schema
        history.append({
            "round": t,
            "staleness": 0,
            "client_loss": loss,
            "state_drops": 0,
        })
    return state, history


def summarize(rec):
    """3) partial schema rebuilds count too (two marker keys)."""
    return {"staleness": rec["staleness"], "straggled": rec["straggled"]}
