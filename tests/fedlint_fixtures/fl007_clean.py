"""FL007 clean fixture: frontends consume recorder records, never build
them."""


def run_rounds(engine, state, build_cohort, num_rounds, emit):
    """The sanctioned shape: the engine's RoundRecorder assembles records;
    the frontend logs single fields off them."""

    def on_round(rec, round_state):
        # borrowing ONE schema field for a log line is fine; rebuilding
        # the record is not
        emit({"round": rec["round"], "staleness": rec["staleness"],
              "sec": 0.0})

    return engine.run(state, build_cohort, num_rounds, on_round=on_round)


def wire_bytes(params_bytes):
    """Byte-accounting dicts share key names with the schema but are not
    records (compression.round_bytes's shape)."""
    return {"bytes_up": params_bytes, "bytes_down": params_bytes}
