"""FL006 firing fixture: donating jits without an out_shardings pin."""
import jax

from repro.core.client_state import jit_donating_store


def build(round_fn):
    """Two donating wrappers, neither pinning its output shardings."""
    apply_a = jit_donating_store(round_fn, 3)
    apply_b = jax.jit(round_fn, donate_argnums=(0,))
    return apply_a, apply_b
