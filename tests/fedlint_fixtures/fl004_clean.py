"""FL004 clean fixture: split before every consumption."""
import jax


def init_params(rng):
    """Each sampler gets its own subkey."""
    k_w, k_b = jax.random.split(rng)
    w = jax.random.normal(k_w, (4, 4))
    b = jax.random.normal(k_b, (4,))
    return w, b


def sample_rounds(rng, n):
    """Loop consumption with a per-iteration split."""
    outs = []
    for _ in range(n):
        rng, sub = jax.random.split(rng)
        outs.append(jax.random.normal(sub, (2,)))
    return outs
