"""FL003 clean fixture: fp32 accumulators with one terminal cast."""
import jax
import jax.numpy as jnp

from repro.core import tree_math as tm


class GoodAccum(FedAlgorithm):  # noqa: F821 -- resolved by name, not import
    """fp32 accumulator space; finalize owns the single cast."""

    def init_accum(self, payload):
        """Zeros pinned to fp32 regardless of the payload dtype."""
        return tm.tzeros_like(payload, jnp.float32)

    def accumulate(self, acc, delta, weight):
        """Casting into the accumulator's own dtype is allowed."""
        return tm.tmap(lambda a, d: a + weight * d.astype(a.dtype),
                       acc, delta)

    def finalize(self, acc, params):
        """The one terminal cast back to the param dtype."""
        return tm.tmap(lambda a, p: a.astype(p.dtype), acc, params)

    def make_client_update(self, grad_fn, client_opt):
        """Client update whose scan carry pins fp32 explicitly."""

        def update(params, batches):
            def accum(carry, batch):
                _, g = grad_fn(params, batch)
                return tm.tmap(lambda c, gi: c + gi.astype(c.dtype),
                               carry, g), None

            total, _ = jax.lax.scan(
                accum, tm.tzeros_like(params, jnp.float32), batches)
            return total

        return update
