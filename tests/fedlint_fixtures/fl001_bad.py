"""FL001 firing fixture: three host syncs inside one jitted body."""
import jax
import numpy as np


@jax.jit
def bad_round(x):
    """numpy call, .item(), and float() on a traced value."""
    y = np.mean(x)
    z = x.sum().item()
    w = float(x[0])
    return y + z + w
