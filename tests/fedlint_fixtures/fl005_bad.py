"""FL005 firing fixture: registry + config contract drift (4 findings)."""
from dataclasses import dataclass


def register_algorithm(name):
    """Stub decorator so the class-contract checks engage."""

    def deco(cls):
        return cls

    return deco


class FedAlgorithm:
    """Stub base marking subclasses for the contract checks."""


@dataclass(frozen=True)
class FedConfig:
    """Stub config with one knob no validator ever checks by name."""

    mystery_knob: float = 0.5

    def __post_init__(self):
        """Validates nothing."""


@register_algorithm("drifty")
class Drifty(FedAlgorithm):
    """Stateful, reshapes its payload, broadcasts extras — declares none."""

    stateful = True

    def broadcast(self, state, server_opt):
        """Ships extras without abstract_broadcast_extras."""
        return (state,)

    def payload_accum(self, acc, payload, weight):
        """Reshapes the payload without abstract_payload."""
        return acc

    def make_client_update(self, grad_fn, client_opt):
        """Reads a config knob that is never validated by name."""
        lr = self.fed.mystery_knob
        return lambda params, batches: (params, lr)
