"""Streaming (any-time) FedPA client == batch FedPA client; MIME baseline
(Karimireddy et al. 2020) corrects FedAvg's bias on quadratics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import FedSim, fedavg_fixed_point, global_posterior_mode
from repro.core.client import make_client_update
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import sgd


def _grad_fn(n):
    def fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r) * n
        return jax.value_and_grad(loss)(params)
    return fn


def test_streaming_dp_equals_batch_dp():
    clients, data = make_federated_lsq(1, 60, 5, heterogeneity=10.0, seed=1)
    X, y = data[0]
    fed = FedConfig(algorithm="fedpa", local_steps=60, burn_in_steps=20,
                    steps_per_sample=10, shrinkage_rho=0.7,
                    client_opt="sgd", client_lr=0.002)
    opt = sgd(fed.client_lr)
    grad_fn = _grad_fn(60)
    batches = lsq_batches(X, y, 15, fed.local_steps, seed=3)
    theta0 = jnp.asarray(np.random.default_rng(0).normal(size=5),
                         jnp.float32)

    batch_up = jax.jit(make_client_update(grad_fn, fed, opt))
    stream_up = jax.jit(make_client_update(
        grad_fn, dataclasses.replace(fed, streaming_dp=True), opt))
    d1, m1, _ = batch_up(theta0, batches)
    d2, m2, _ = stream_up(theta0, batches)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=2e-4,
                               atol=2e-4)
    assert float(m1["loss_last"]) == float(m2["loss_last"])


def test_mime_converges_comparably_to_fedavg():
    """MIME's control variates reduce local-update VARIANCE, not the client
    drift bias — consistent with the paper's Table 3 where MIME does not
    dominate FedAvg-ME. We assert it converges to the same bias class as
    FedAvg (within a small factor of the analytic FedAvg fixed point), not
    that it wins."""
    clients, data = make_federated_lsq(2, 50, 2, heterogeneity=40.0, seed=3)
    mu = np.asarray(global_posterior_mode(clients))
    grad_fn = _grad_fn(50)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 25, steps, seed=r * 131 + cid)

    fed = FedConfig(algorithm="mime", clients_per_round=2, local_steps=100,
                    server_opt="sgdm", server_lr=1.0, server_momentum=0.9,
                    client_opt="sgd", client_lr=0.002, mime_beta=0.5)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=2)
    st, _ = sim.run(jnp.zeros(2), 80)
    d_mime = float(np.linalg.norm(np.asarray(st.params) - mu))
    d_avg = float(np.linalg.norm(
        np.asarray(fedavg_fixed_point(clients, 100, 0.002)) - mu))
    assert np.isfinite(d_mime)
    assert d_mime < 3.0 * d_avg, (d_mime, d_avg)


def test_mime_anchor_accumulates_in_fp32():
    """The SVRG anchor must not saturate under bf16 params (fedlint FL003).

    bf16 has a 7-bit mantissa: summing more than 256 unit gradients into a
    bf16 carry silently drops increments (ulp(256) = 2), halving the anchor
    at K = 512. With grad(p) = p - b and mime_beta = 0 the local fixed
    point is exactly -anchor, so a saturated anchor lands the client at
    p = 0.5 instead of 1.0 — a 2x error this asserts against.
    """
    from repro.algorithms import get_algorithm

    K = 512
    fed = FedConfig(algorithm="mime", mime_beta=0.0, client_lr=0.1,
                    local_steps=K, client_opt="sgd")
    alg = get_algorithm(fed)

    def grad_fn(p, batch):
        def loss(q):
            return 0.5 * jnp.sum((q - batch["b"]) ** 2)
        return jax.value_and_grad(loss)(p)

    update = jax.jit(alg.make_client_update(grad_fn, None))
    params = jnp.zeros((), jnp.bfloat16)
    batches = {"b": jnp.ones((K,), jnp.bfloat16)}
    server_m = jnp.zeros((), jnp.bfloat16)
    result = update(params, batches, server_m)
    # fedavg_delta = theta_0 - theta_K = -1 at the true fixed point
    np.testing.assert_allclose(float(result.payload), -1.0, rtol=0.05)
