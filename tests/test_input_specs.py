"""input_specs() shape correctness for every (arch x shape) — the contract
the dry-run lowers against (no device allocation; single-device mesh)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import SHAPES
from repro.launch.dryrun import default_fed_config
from repro.launch.specs import input_specs
from repro.sharding import make_mesh_compat


@pytest.fixture(scope="module")
def mesh():
    # version-guarded: jax 0.4.x has no AxisType / axis_types kwarg
    return make_mesh_compat((1,), ("data",))


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_train_specs(arch, mesh):
    cfg = configs.get_config(arch)
    fed = default_fed_config()
    spec = input_specs(cfg, SHAPES["train_4k"], fed, mesh,
                       placement="sequential")
    state, batches = spec["args"]
    C, K, B, S1 = batches["tokens"].shape
    assert C == fed.clients_per_round and K == fed.local_steps
    assert B == 256
    s_text = 4096 - (cfg.frontend_tokens if cfg.frontend else 0)
    assert S1 == s_text + 1
    if cfg.frontend:
        assert batches["frontend"].shape == (C, K, B, cfg.frontend_tokens,
                                             cfg.d_model)
    # server state holds params + opt moments
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    assert n == cfg.param_count()


@pytest.mark.parametrize("arch", ["gemma3-27b", "granite-34b", "xlstm-125m"])
def test_decode_specs(arch, mesh):
    cfg = configs.get_config(arch)
    spec = input_specs(cfg, SHAPES["decode_32k"], default_fed_config(), mesh)
    params, tok, state = spec["args"]
    assert tok.shape == (128,) and tok.dtype == jnp.int32
    # every attention cache is bounded by window or seq_len
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        ks = jax.tree_util.keystr(path)
        if ks.endswith(".k"):
            L = leaf.shape[-3]
            assert L <= 32_768
    assert spec["kind"] == "decode"


def test_parallel_train_batch_split(mesh):
    cfg = configs.get_config("xlstm-125m")
    spec = input_specs(cfg, SHAPES["train_4k"], default_fed_config(), mesh,
                       placement="parallel")
    C, K, B, _ = spec["args"][1]["tokens"].shape
    assert C * B == 256      # clients x local batch = global batch


def test_prefill_specs(mesh):
    cfg = configs.get_config("internvl2-26b")
    spec = input_specs(cfg, SHAPES["prefill_32k"], default_fed_config(), mesh)
    params, batch = spec["args"]
    B, S = batch["tokens"].shape
    assert B == 32 and S == 32_768 - cfg.frontend_tokens
    assert batch["frontend"].shape == (32, 256, cfg.d_model)


def test_device_store_specs_pads_non_divisible_population():
    """Regression: a population that doesn't divide the client-axis extent
    used to fall back to full replication silently; it must now pad N up
    and keep the population axis sharded."""
    try:
        from jax.sharding import AbstractMesh
    except ImportError:
        pytest.skip("jax without AbstractMesh")
    from jax.sharding import PartitionSpec as P

    from repro.launch.specs import device_store_specs

    mesh = AbstractMesh((("data", 8), ("model", 2)))
    cfg = configs.get_config("xlstm-125m")
    fed = default_fed_config("scaffold")
    store_spec, store_sh, ids_spec, ids_sh = device_store_specs(
        cfg, fed, mesh, "parallel", num_clients=10)
    # 10 clients over extent 8 -> 16 padded rows, still sharded over "data"
    assert store_spec["stamps"].shape == (16,)
    assert store_sh["stamps"].spec == P("data")
    for leaf, sh in zip(
            jax.tree_util.tree_leaves(store_spec["buffers"]),
            jax.tree_util.tree_leaves(store_sh["buffers"])):
        assert leaf.shape[0] == 16
        assert sh.spec[0] == "data"
    assert ids_spec.shape == (8,) and ids_sh.spec == P()
    # a divisible population is unpadded but equally sharded
    spec64, sh64, _, _ = device_store_specs(cfg, fed, mesh, "parallel",
                                            num_clients=64)
    assert spec64["stamps"].shape == (64,)
    assert sh64["stamps"].spec == P("data")


def test_store_population_layout_is_specs_source_of_truth():
    """launch.specs delegates population layout to core.client_state —
    one definition of padding/extent for specs, store, and dry-run."""
    try:
        from jax.sharding import AbstractMesh
    except ImportError:
        pytest.skip("jax without AbstractMesh")
    from repro.core.client_state import population_layout
    from repro.launch.specs import store_population_layout

    mesh = AbstractMesh((("data", 8), ("model", 2)))
    assert store_population_layout(mesh, 10) == population_layout(mesh, 10)
