"""Per-arch smoke tests: reduced variant of each assigned architecture runs
one forward/train step and one decode step on CPU — shapes + finiteness.
(Deliverable (f): reduced-config smoke tests for all ten assigned archs.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (init_decode_state, init_params, lm_loss,
                          serve_step)
from repro.models.model import count_params
from repro.models.steps import centralized_train_step
from repro.optim import sgd


def _batch(cfg, B=2, S=64, seed=1):
    s_text = S - (cfg.frontend_tokens if cfg.frontend else 0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (B, s_text + 1), 0,
                                          cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.frontend_tokens,
                                           cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe.enabled:
        assert cfg.moe.num_experts <= 4
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: lm_loss(p, b, cfg, q_chunk=32))(params, batch)
    assert np.isfinite(float(loss))
    # one optimizer step decreases nothing in particular but must stay finite
    opt = sgd(0.1)
    p2, _, loss2, _ = jax.jit(
        lambda p, s, b: centralized_train_step(p, s, b, cfg, opt, q_chunk=32)
    )(params, opt.init(params), batch)
    assert np.isfinite(float(loss2))
    leaves = jax.tree_util.tree_leaves(p2)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves)


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_decode_step(arch):
    cfg = configs.get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B = 2
    state = init_decode_state(cfg, B, max_len=128)
    tok = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, t, s: serve_step(p, t, s, cfg))
    for _ in range(3):
        tok, logits, state = step(params, tok, state)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(state.pos) == 3
    # sampled tokens always within the real vocab (padding masked)
    assert int(tok.max()) < cfg.vocab_size


def test_count_params_matches_init():
    cfg = configs.get_smoke("qwen3-moe-30b-a3b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert n == count_params(cfg) == cfg.param_count()


def test_active_params_lt_total_for_moe():
    for arch in ("qwen3-moe-30b-a3b", "llama4-scout-17b-a16e"):
        cfg = configs.get_config(arch)
        assert cfg.active_param_count() < cfg.param_count()
    dense = configs.get_config("qwen3-32b")
    assert dense.active_param_count() == dense.param_count()


def test_mlstm_init_keys_are_independent():
    """w_down must use its own subkey, not fold_in of w_up's consumed key
    (fedlint FL004): every mLSTM weight draws from a distinct split of the
    init key, so no two leaves can be correlated by key reuse."""
    from repro.models.xlstm import init_mlstm_params

    cfg = configs.get_smoke("xlstm-125m")
    rng = jax.random.PRNGKey(7)
    p = init_mlstm_params(rng, cfg)
    ks = jax.random.split(rng, 7)
    e, d = p["w_down"].shape
    expect = jax.random.normal(ks[6], (e, d), p["w_down"].dtype) \
        * (1.0 / jnp.sqrt(e))
    np.testing.assert_array_equal(np.asarray(p["w_down"]),
                                  np.asarray(expect))
