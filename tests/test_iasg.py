"""IASG sampler (Algorithm 4) + ESS diagnostics (Appendix A.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.diagnostics import (effective_sample_size, ess_from_losses,
                                    sample_autocorr)
from repro.core.iasg import iasg_sample, sgd_steps
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import sgd


def _problem(seed=0, d=4, n=200):
    clients, data = make_federated_lsq(1, n, d, heterogeneity=0.0, seed=seed)
    X, y = data[0]

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r)
        return jax.value_and_grad(loss)(params)

    return clients[0], X, y, grad_fn


def test_shapes_and_counts():
    c, X, y, grad_fn = _problem()
    opt = sgd(0.05)
    params = jnp.zeros(4)
    B, K, ell = 10, 5, 3
    batches = lsq_batches(X, y, 20, B + K * ell, seed=1)
    res = iasg_sample(params, opt, opt.init(params), grad_fn, batches,
                      burn_in_steps=B, steps_per_sample=K, num_samples=ell)
    assert res.samples.shape == (ell, 4)
    assert res.burn_in_losses.shape == (B,)
    assert res.sample_losses.shape == (ell, K)
    assert np.all(np.isfinite(np.asarray(res.samples)))


def test_batch_count_mismatch_raises():
    c, X, y, grad_fn = _problem()
    opt = sgd(0.05)
    params = jnp.zeros(4)
    batches = lsq_batches(X, y, 20, 7, seed=1)
    with pytest.raises(ValueError):
        iasg_sample(params, opt, opt.init(params), grad_fn, batches,
                    burn_in_steps=4, steps_per_sample=2, num_samples=3)


def test_samples_concentrate_near_local_optimum():
    """After burn-in, iterate averages cluster around mu_i (the local
    posterior mode) — the estimator FedPA's xbar relies on."""
    c, X, y, grad_fn = _problem(seed=3)
    opt = sgd(0.05)
    params = jnp.zeros(4)
    batches = lsq_batches(X, y, 20, 200 + 20 * 8, seed=2)
    res = iasg_sample(params, opt, opt.init(params), grad_fn, batches,
                      burn_in_steps=200, steps_per_sample=20, num_samples=8)
    xbar = np.asarray(res.samples).mean(axis=0)
    err = np.linalg.norm(xbar - np.asarray(c.mu)) / np.linalg.norm(np.asarray(c.mu))
    assert err < 0.05, err


def test_sgd_steps_decreases_loss():
    c, X, y, grad_fn = _problem(seed=4)
    opt = sgd(0.05)
    params = jnp.zeros(4)
    batches = lsq_batches(X, y, 20, 100, seed=3)
    final, _, losses = sgd_steps(params, opt, opt.init(params), grad_fn,
                                 batches)
    assert float(losses[-10:].mean()) < 0.1 * float(losses[0])


def test_more_steps_per_sample_decorrelates():
    """Appendix A.2: larger K => less correlated samples."""
    c, X, y, grad_fn = _problem(seed=5, d=10)
    opt = sgd(0.08)
    params = jnp.zeros(10)

    def run(K):
        batches = lsq_batches(X, y, 10, 100 + K * 30, seed=4)
        res = iasg_sample(params, opt, opt.init(params), grad_fn, batches,
                          burn_in_steps=100, steps_per_sample=K,
                          num_samples=30)
        return float(sample_autocorr(res.samples, lag=1))

    assert run(20) < run(1) + 1e-3


def test_ess_logspace_stability_and_bounds():
    lw = jnp.asarray([-1000.0, -1000.0, -1000.0])
    assert float(effective_sample_size(lw)) == pytest.approx(3.0, rel=1e-5)
    # one dominant weight -> ESS ~ 1
    lw = jnp.asarray([0.0, -50.0, -50.0])
    assert float(effective_sample_size(lw)) == pytest.approx(1.0, rel=1e-4)
    losses = jnp.asarray([2.0, 2.0, 2.0, 2.0])
    assert float(ess_from_losses(losses)) == pytest.approx(4.0, rel=1e-5)
