"""One-time generator for the engine-equivalence goldens.

Run at the commit immediately BEFORE the unified ``core/engine``
refactor, so the artifacts under ``tests/goldens/engine/`` capture the
original sync loop (``FedSim.run``) and the original standalone
``AsyncRoundEngine`` byte for byte:

    PYTHONPATH=src:tools python tests/_generate_engine_goldens.py

The matrix definition lives in ``engine_goldens_common.py`` (shared
with the regression test); this script only iterates and writes.
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent))

import engine_goldens_common as common  # noqa: E402


def main():
    """Generate every golden cell in the matrix."""
    problem = common.make_problem()
    t0 = time.time()
    n = 0
    for name in common.SPECS:
        for mode in common.MODES:
            if mode == "sync" and name in common.ASYNC_ONLY:
                continue
            for placement in common.PLACEMENTS:
                t = time.time()
                out = common.run_case(name, mode, placement, problem)
                common.save_case(name, mode, placement, *out)
                n += 1
                print(f"[{n}] {common.case_id(name, mode, placement)}"
                      f"  ({time.time() - t:.1f}s)", flush=True)
    print(f"done: {n} cells in {time.time() - t0:.1f}s "
          f"-> {common.GOLDEN_DIR}")


if __name__ == "__main__":
    main()
