"""Engine-equivalence matrix: the unified RoundEngine vs pre-refactor
goldens.

``tests/goldens/engine/`` was generated (once, by
``tests/_generate_engine_goldens.py``) with the PRE-refactor loops —
``FedSim.run``'s inline sync loop and the standalone ``AsyncRoundEngine``
— so these tests pin the refactor's core contract: the one staleness-
general loop reproduces both loops it replaced **bitwise** (params, full
client-state store, JSON history) across every registered algorithm ×
placement × {sync, async staleness=2}, including burn-in regimes, fault
injection, and both store placements.

Also here:

* the unification dividend — ``async_rounds=True, max_staleness=0``
  (no stragglers) now takes the fused window=1 path and is bitwise the
  SYNC goldens (the pre-refactor engines only agreed to float rounding);
* the golden-schema regression test for the satellite "history schema
  drift" fix: one uniform record schema over both modes, stamped with
  explicit defaults, JSON-serializable end to end.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import engine_goldens_common as egc
from repro.configs.base import FedConfig
from repro.core import FedSim

MATRIX = [
    (name, mode, placement)
    for name in egc.SPECS
    for mode in egc.MODES
    if not (mode == "sync" and name in egc.ASYNC_ONLY)
    for placement in egc.PLACEMENTS
]

#: Every record the unified engine emits carries exactly these keys
#: (plus the flattened eval metrics on eval-cadence rounds).
UNIFORM_KEYS = frozenset({
    "round", "staleness", "loss_first", "loss_last", "client_loss",
    "bytes_up", "bytes_down", "dropped", "straggled", "state_drops",
})


@pytest.fixture(scope="module", autouse=True)
def _x32():
    """The goldens were generated at jax's default precision; a test
    module that flips ``jax_enable_x64`` at import time (test_dp_delta,
    test_posterior, test_shrinkage) must not leak float64 — and doubled
    byte accounting — into the bitwise comparison."""
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    yield
    jax.config.update("jax_enable_x64", prev)


@pytest.fixture(scope="module")
def problem():
    return egc.make_problem()


def _assert_bitwise(arrays, history, g_arrays, g_history):
    assert set(arrays) == set(g_arrays), (
        set(arrays) ^ set(g_arrays))
    for k in g_arrays:
        got, want = arrays[k], g_arrays[k]
        assert got.dtype == want.dtype and got.shape == want.shape, k
        assert np.array_equal(got, want, equal_nan=True), k
    assert len(history) == len(g_history)
    for rec, g_rec in zip(history, g_history):
        # key SUBSET on the golden side: the uniform schema stamps keys
        # (staleness/state_drops/straggled/dropped) the old sync loop
        # omitted; every key the old loops DID emit must match exactly
        missing = set(g_rec) - set(rec)
        assert not missing, missing
        for k, v in g_rec.items():
            assert rec[k] == v, (k, rec[k], v)


@pytest.mark.parametrize("name,mode,placement", MATRIX,
                         ids=[egc.case_id(*m) for m in MATRIX])
def test_bitwise_vs_prerefactor_goldens(name, mode, placement, problem):
    """Window=1 ≡ the old sync loop; staleness=2 ≡ the old async engine."""
    arrays_p, arrays_s, history = egc.run_case(name, mode, placement,
                                               problem)
    g_arrays, g_history = egc.load_case(name, mode, placement)
    _assert_bitwise({**arrays_p, **arrays_s}, history, g_arrays, g_history)


#: A cross-section of the matrix (stateless + burn-in + device store +
#: codec + faults) for the async0 == sync unification claim; stragglers
#: excluded by construction (they force the split pipeline).
ASYNC0_SPECS = ("fedavg", "fedpa", "scaffold_dev", "fedlora",
                "fedavg_dropout")


@pytest.mark.parametrize("name", ASYNC0_SPECS)
@pytest.mark.parametrize("placement", ("parallel", "chunked"))
def test_async0_bitwise_equals_sync_goldens(name, placement, problem):
    """max_staleness=0 without stragglers now runs the fused window=1
    path: bitwise the SYNC goldens, where the two pre-refactor loops only
    agreed to float rounding."""
    kwargs, weights = egc.SPECS[name]
    fed = FedConfig(**{**kwargs, "async_rounds": True, "max_staleness": 0})
    grad_fn, batch_fn = problem
    sim = FedSim(fed, grad_fn, batch_fn, num_clients=egc.C,
                 client_weights=weights, placement=placement)
    state, history = sim.run(jnp.zeros(egc.D), egc.ROUNDS,
                             eval_fn=egc.eval_fn, eval_every=2)
    arrays = egc._leaves(state.params, "param")
    if sim.client_store is not None:
        arrays.update(egc._leaves(sim.client_store.state_dict(), "store"))
    g_arrays, g_history = egc.load_case(name, "sync", placement)
    _assert_bitwise(arrays, history, g_arrays, g_history)


@pytest.mark.parametrize("name,mode", [("fedavg", "sync"),
                                       ("scaffold", "sync"),
                                       ("fedavg_dropout", "async2"),
                                       ("fedavg_straggler", "async2")])
def test_uniform_history_schema(name, mode, problem):
    """The schema-drift fix: both modes emit ONE record schema with
    explicit defaults (bytes None without accounting, zero fault/CAS
    counters), JSON-serializable with no device arrays left inside."""
    _, _, history = egc.run_case(name, mode, "parallel", problem)
    assert len(history) == egc.ROUNDS
    for t, rec in enumerate(history):
        extra = {"eval_loss"} if (t % 2 == 0 or t == egc.ROUNDS - 1) else set()
        assert set(rec) == UNIFORM_KEYS | extra, (t, set(rec))
        assert rec["round"] == t
        assert rec["client_loss"] == rec["loss_last"]
        for k in ("staleness", "dropped", "straggled", "state_drops"):
            assert isinstance(rec[k], int), (k, type(rec[k]))
    json.dumps(history)  # end-to-end JSON-safety, both modes
