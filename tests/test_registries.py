"""Duplicate-registration behavior of the three plugin registries.

Each registry (algorithms, payload codecs, client-state stores) must
raise by name on a duplicate registration — a silent swap would change
round math / payload bytes / state placement for every config using the
name — with ``override=True`` as the explicit escape hatch.
"""
import pytest

from repro.algorithms import FedAlgorithm
from repro.algorithms import base as alg_base
from repro.algorithms.base import register_algorithm
from repro.compression import base as codec_base
from repro.compression.base import PayloadCodec, register_codec
from repro.core.client_state import (STORES, ClientStateStore,
                                     register_store)

ALG_REGISTRY = alg_base._REGISTRY
CODEC_REGISTRY = codec_base._REGISTRY


def test_register_algorithm_duplicate_raises():
    assert "fedavg" in ALG_REGISTRY
    with pytest.raises(ValueError, match="fedavg.*already registered"):
        @register_algorithm("fedavg")
        class Impostor(FedAlgorithm):
            pass
    # the original class is untouched
    assert ALG_REGISTRY["fedavg"].__name__ != "Impostor"


def test_register_algorithm_override_and_reregister():
    original = ALG_REGISTRY["fedavg"]
    # re-registering the SAME class is a no-op, not a collision
    register_algorithm("fedavg")(original)
    assert ALG_REGISTRY["fedavg"] is original

    @register_algorithm("fedavg", override=True)
    class Replacement(FedAlgorithm):
        pass
    try:
        assert ALG_REGISTRY["fedavg"] is Replacement
    finally:
        register_algorithm("fedavg", override=True)(original)
    assert ALG_REGISTRY["fedavg"] is original


def test_register_codec_duplicate_raises():
    assert "int8" in CODEC_REGISTRY
    with pytest.raises(ValueError, match="int8.*already registered"):
        @register_codec("int8")
        class Impostor(PayloadCodec):
            pass
    assert CODEC_REGISTRY["int8"].__name__ != "Impostor"


def test_register_codec_override_and_reregister():
    original = CODEC_REGISTRY["int8"]
    register_codec("int8")(original)   # same class: no-op
    assert CODEC_REGISTRY["int8"] is original

    @register_codec("int8", override=True)
    class Replacement(PayloadCodec):
        pass
    try:
        assert CODEC_REGISTRY["int8"] is Replacement
    finally:
        register_codec("int8", override=True)(original)
    assert CODEC_REGISTRY["int8"] is original


def test_register_store_duplicate_raises():
    assert "host" in STORES
    class Impostor(ClientStateStore):
        pass
    with pytest.raises(ValueError, match="host.*already registered"):
        register_store("host", Impostor)
    assert STORES["host"] is not Impostor


def test_register_store_override_and_type_check():
    original = STORES["host"]
    assert register_store("host", original) is original  # same class: no-op

    class Replacement(ClientStateStore):
        pass
    register_store("host", Replacement, override=True)
    try:
        assert STORES["host"] is Replacement
    finally:
        register_store("host", original, override=True)
    assert STORES["host"] is original

    with pytest.raises(TypeError, match="BaseClientStateStore"):
        register_store("bogus", int)
    assert "bogus" not in STORES
