"""HLO collective parser + roofline derivation units."""

from repro.configs import SHAPES, get_config
from repro.sharding.collectives import _shape_bytes, parse_collectives
from repro.sharding.roofline import V5E, derive, format_table, model_flops

HLO = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  ...
}

%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  ROOT %t = tuple(...)
}

%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(s32[] %iv, s32[] %c), direction=LT
}

ENTRY %main (a: f32[512]) -> f32[512] {
  %w = (s32[], f32[128,256]) while((s32[], f32[128,256]) %init), condition=%cond.1, body=%body.1
  %ag = bf16[1024,64]{1,0} all-gather(bf16[512,64]{1,0} %a), dimensions={0}
  %rs = f32[64]{0} reduce-scatter(f32[512]{0} %a), dimensions={0}
  %a2a = f32[16,32]{1,0} all-to-all(f32[16,32]{1,0} %b), dimensions={0}
  %cp = f32[256]{0} collective-permute(f32[256]{0} %a), source_target_pairs={{0,1}}
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert _shape_bytes("pred[]") == 1


def test_parse_kinds_and_loop_scaling():
    c = parse_collectives(HLO)
    assert c["all-gather"]["bytes"] == 1024 * 64 * 2
    assert c["reduce-scatter"]["bytes"] == 64 * 4
    assert c["all-to-all"]["bytes"] == 16 * 32 * 4
    assert c["collective-permute"]["bytes"] == 256 * 4
    # the all-reduce inside the while body is scaled by trip count 7
    assert c["all-reduce"]["bytes"] == 128 * 256 * 4 * 7
    assert c["all-reduce"]["count"] == 7
    assert c["total_bytes"] == sum(
        c[k]["bytes"] for k in ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_async_start_done_not_double_counted():
    txt = """
ENTRY %e () -> f32[8] {
  %s = f32[8]{0} all-gather-start(f32[4]{0} %a)
  %d = f32[8]{0} all-gather-done(f32[8]{0} %s)
}
"""
    c = parse_collectives(txt)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["bytes"] == 32


def test_model_flops_kinds():
    cfg = get_config("qwen3-32b")
    n = cfg.active_param_count()
    tr = model_flops(cfg, SHAPES["train_4k"], local_steps=8)
    assert tr == 6.0 * n * 256 * 4096 * 8
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    assert pf == 2.0 * n * 32 * 32768
    dc = model_flops(cfg, SHAPES["decode_32k"])
    assert dc == 2.0 * n * 128


def test_derive_and_dominant():
    cfg = get_config("qwen3-32b")
    rep = derive("qwen3-32b", SHAPES["decode_32k"], cfg, "16x16", 256,
                 {"flops": 1e12, "bytes accessed": 1e12},
                 {"total_bytes": 1e9}, hw=V5E)
    assert rep.memory_s > rep.compute_s        # 1e12B/819GB/s >> 1e12F/197T
    assert rep.dominant == "memory"
    table = format_table([rep])
    assert "qwen3-32b" in table and "memory" in table
