"""q-FFL fairness weighting (Li et al. 2020) in the unified round path.

``FedConfig.qffl_q`` tilts the cohort aggregation toward high-loss
clients: client k's weight becomes ``w_k * max(loss_first_k, 0)**q``,
renormalized over the cohort (core/round_program.py). q=0 (the default)
is the plain weighting — bitwise, enforced by the engine golden matrix
(tests/test_engine_goldens.py); these tests cover the tilt itself on a
heterogeneous least-squares population: larger q trades mean loss for
worst-client loss.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import FedSim
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches

C, D, N = 4, 3, 80
ROUNDS = 30


@pytest.fixture(scope="module")
def problem():
    # strong heterogeneity: the outlier client's optimum sits far from the
    # population mean, so plain FedAvg parks far from it (high worst loss)
    return make_federated_lsq(C, N, D, heterogeneity=30.0, seed=1)


def _grad_fn(params, batch):
    def loss(p):
        r = batch["x"] @ p - batch["y"]
        return 0.5 * jnp.mean(r * r)

    return jax.value_and_grad(loss)(params)


def _make_sim(data, q, placement=None):
    fed = FedConfig(
        algorithm="fedavg", clients_per_round=C, local_steps=8,
        client_opt="sgd", client_lr=0.05, server_opt="sgd", server_lr=1.0,
        qffl_q=q)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 16, steps, seed=r * 131 + cid)

    return FedSim(fed, _grad_fn, batch_fn, num_clients=C, seed=0,
                  placement=placement)


def _client_losses(data, params):
    return np.array([
        0.5 * float(jnp.mean((X @ params - y) ** 2)) for X, y in data
    ])


def _final_losses(data, q, placement=None):
    sim = _make_sim(data, q, placement=placement)
    state, _ = sim.run(jnp.zeros(D), ROUNDS)
    return _client_losses(data, state.params), state.params


def test_qffl_reduces_worst_client_loss(problem):
    """The satellite claim: q > 0 lowers the worst per-client loss (at the
    price of a higher population mean — the fairness trade-off)."""
    _, data = problem
    base, _ = _final_losses(data, 0.0)
    fair, _ = _final_losses(data, 2.0)
    assert fair.max() < base.max(), (base, fair)
    # the tilt is a trade, not a free lunch: it actually moved the params
    assert not np.allclose(base, fair)


def test_qffl_consistent_across_placements(problem):
    """The tilt folds identically through vmap / scan / scan-of-vmap."""
    _, data = problem
    _, p_par = _final_losses(data, 2.0, placement="parallel")
    _, p_seq = _final_losses(data, 2.0, placement="sequential")
    _, p_chk = _final_losses(data, 2.0, placement="chunked")
    np.testing.assert_allclose(p_par, p_seq, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(p_par, p_chk, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("bad", [-1.0, float("nan"), float("inf"), "2"])
def test_qffl_q_validated_eagerly(bad):
    """A bad exponent fails at config time, not rounds later as NaNs."""
    with pytest.raises(ValueError, match="qffl_q"):
        FedConfig(algorithm="fedavg", qffl_q=bad)
