"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real (single) device; only launch/dryrun.py forces 512
placeholder devices, and the dry-run test uses a subprocess."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
