"""Fault-injecting cohort subsystem: zero-rate configs reproduce the
fault-free engine bitwise; fault draws replay deterministically; the
survivor-masked partial aggregation matches an eager survivor-subset
reference across all placements and the async staleness=0 path; an
all-dropped round degrades to a zero delta; dropped clients' persistent
state never lands; heterogeneous step budgets are exact under plain SGD;
and the process-based shared-memory prefetcher honours the thread
backend's contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import FedSim
from repro.data import make_federated_lsq
from repro.data.cohort_source import CohortSource
from repro.data.prefetch import (Cohort, ProcessCohortPrefetcher,
                                 make_prefetcher)
from repro.data.sampling import ClientSampler
from repro.data.synthetic_lsq import lsq_batches

C, D, K, N = 4, 3, 8, 12

BASE = dict(clients_per_round=C, local_steps=K, server_opt="sgd",
            server_lr=0.5, client_opt="sgd", client_lr=0.01)


def _fed(**kw):
    return FedConfig(algorithm="fedavg", **{**BASE, **kw})


@pytest.fixture(scope="module")
def problem():
    clients, data = make_federated_lsq(N, 40, D, heterogeneity=10.0, seed=0)

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r) * 40
        return jax.value_and_grad(loss)(params)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 10, steps, seed=r * 131 + cid)

    return grad_fn, batch_fn


def _sim(problem, fed, **kw):
    grad_fn, batch_fn = problem
    return FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                  num_clients=N, seed=7, **kw)


# ---------------------------------------------------------------------------
# Deterministic draws
# ---------------------------------------------------------------------------

def test_zero_fault_cohorts_are_bitwise_client_sampler():
    """With every fault knob at its default the source replays
    ClientSampler's stream bitwise and ships no survivors mask."""
    src = CohortSource(_fed(), N, lambda ids, r: {"x": np.zeros(1)}, seed=3)
    ref = ClientSampler(N, C, seed=3)
    for r in range(10):
        np.testing.assert_array_equal(src.sample(r), ref.sample(r))
        ids, faults = src.draw(r)
        assert faults.survivors is None
        assert faults.budgets is None
        assert faults.extra_staleness == 0 and faults.dropped == 0
    assert not src.mask_faults


def test_fault_draws_replay_bitwise():
    """draw(r) is a pure function of (seed, round): a fresh source replays
    the full fault matrix identically."""
    fed = _fed(availability="diurnal", availability_period=6,
               availability_duty=0.6, dropout_rate=0.3, min_local_steps=2,
               straggler_rate=0.5, async_rounds=True)
    a = CohortSource(fed, N, lambda ids, r: {}, seed=11)
    b = CohortSource(fed, N, lambda ids, r: {}, seed=11)
    saw_fault = False
    for r in range(12):
        ia, fa = a.draw(r)
        ib, fb = b.draw(r)
        np.testing.assert_array_equal(ia, ib)
        np.testing.assert_array_equal(fa.survivors, fb.survivors)
        np.testing.assert_array_equal(fa.budgets, fb.budgets)
        assert fa.extra_staleness == fb.extra_staleness
        assert fa.dropped == fb.dropped
        assert fa.budgets.min() >= 2 and fa.budgets.max() <= K
        saw_fault |= fa.dropped > 0 or fa.extra_staleness > 0
    assert saw_fault  # the rates above make an all-clean run implausible


def test_different_seeds_draw_different_faults():
    fed = _fed(dropout_rate=0.5)
    a = CohortSource(fed, N, lambda ids, r: {}, seed=0)
    b = CohortSource(fed, N, lambda ids, r: {}, seed=1)
    masks_a = [tuple(a.draw(r)[1].survivors) for r in range(8)]
    masks_b = [tuple(b.draw(r)[1].survivors) for r in range(8)]
    assert masks_a != masks_b


def test_diurnal_availability_and_conscription():
    """Cohorts draw from the currently-up set; a shortfall is conscripted
    from the down set and masked out (shapes stay static)."""
    fed = _fed(availability="diurnal", availability_period=5,
               availability_duty=0.5)
    src = CohortSource(fed, 6, lambda ids, r: {}, seed=2)  # n_up spans 1..4
    saw_full, saw_shortfall = False, False
    for r in range(15):
        avail = src.available(r)
        ids, faults = src.draw(r)
        assert ids.shape == (C,) and len(set(ids.tolist())) == C
        n_up = int(avail.sum())
        assert faults.dropped == max(0, C - n_up)
        # every survivor was genuinely available; every conscript is dead
        up_ids = ids[faults.survivors > 0]
        assert avail[up_ids].all()
        if n_up >= C:
            saw_full = True
            np.testing.assert_array_equal(faults.survivors, np.ones(C))
        else:
            saw_shortfall = True
    assert saw_full and saw_shortfall


# ---------------------------------------------------------------------------
# Survivor-masked aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("placement", ["parallel", "sequential", "chunked"])
def test_masked_round_matches_survivor_subset(problem, placement):
    """One masked round == the same round run on just the survivors: the
    weighted partial aggregation renormalizes over the survivor subset
    (weights and losses), for every placement."""
    mask = np.array([1.0, 0.0, 1.0, 1.0], np.float32)
    weights = np.array([0.5, 1.5, 2.0, 1.0], np.float32)
    ids = np.arange(C)
    params = jnp.zeros(D)

    sim_m = _sim(problem, _fed(dropout_rate=0.5), placement=placement)
    batches = sim_m.stack_cohort(ids, 0)
    cohort = Cohort(0, ids, batches, weights, mask, 0, dropped=1)
    state_m, rec_m = sim_m.round(sim_m.init(params), 0, cohort)
    assert rec_m["dropped"] == 1

    sim_r = _sim(problem, _fed(), placement=placement)
    keep = mask > 0
    sub = Cohort(0, ids[keep],
                 jax.tree_util.tree_map(lambda x: x[keep], batches),
                 weights[keep], None, 0, 0)
    state_r, rec_r = sim_r.round(sim_r.init(params), 0, sub)

    np.testing.assert_allclose(np.asarray(state_m.params),
                               np.asarray(state_r.params), rtol=1e-5)
    assert rec_m["loss_first"] == pytest.approx(rec_r["loss_first"],
                                                rel=1e-5)
    assert rec_m["loss_last"] == pytest.approx(rec_r["loss_last"], rel=1e-5)


@pytest.mark.parametrize("placement", ["parallel", "sequential", "chunked"])
def test_placements_agree_under_dropout(problem, placement):
    """The fault-injected run is placement-invariant (same fault stream,
    same numbers)."""
    fed = _fed(dropout_rate=0.4)
    ref_state, ref_hist = _sim(problem, fed, placement="parallel").run(
        jnp.zeros(D), 3)
    state, hist = _sim(problem, fed, placement=placement).run(
        jnp.zeros(D), 3)
    np.testing.assert_allclose(np.asarray(ref_state.params),
                               np.asarray(state.params), rtol=1e-5)
    assert [h["dropped"] for h in hist] == [h["dropped"] for h in ref_hist]


def test_async_staleness_zero_matches_sync_under_dropout(problem):
    """max_staleness=0 still reproduces the sync path when rounds carry a
    survivors mask (same draws, same masked aggregation)."""
    st_s, h_s = _sim(problem, _fed(dropout_rate=0.4)).run(jnp.zeros(D), 4)
    st_a, h_a = _sim(problem, _fed(dropout_rate=0.4, async_rounds=True,
                                   max_staleness=0)).run(jnp.zeros(D), 4)
    np.testing.assert_array_equal(np.asarray(st_s.params),
                                  np.asarray(st_a.params))
    assert [h["dropped"] for h in h_s] == [h["dropped"] for h in h_a]


def test_all_dropped_round_is_zero_delta(problem):
    """dropout_rate=1: every round degrades to a zero pseudo-gradient (no
    NaN) and history reports full-cohort drops and 0.0 survivor losses."""
    state, hist = _sim(problem, _fed(dropout_rate=1.0)).run(jnp.zeros(D), 2)
    np.testing.assert_array_equal(np.asarray(state.params), np.zeros(D))
    assert [h["dropped"] for h in hist] == [C, C]
    assert all(h["loss_last"] == 0.0 for h in hist)


def test_fault_history_replays_identically(problem):
    """Two runs of the same faulty config produce identical params and
    identical per-round fault counts (sync and async)."""
    fed = _fed(dropout_rate=0.3, straggler_rate=0.5, async_rounds=True,
               max_staleness=1, staleness_discount=0.7)
    st1, h1 = _sim(problem, fed).run(jnp.zeros(D), 5)
    st2, h2 = _sim(problem, fed).run(jnp.zeros(D), 5)
    np.testing.assert_array_equal(np.asarray(st1.params),
                                  np.asarray(st2.params))
    keys = ("dropped", "straggled", "staleness")
    assert [[h[k] for k in keys] for h in h1] == \
        [[h[k] for k in keys] for h in h2]
    assert any(h["straggled"] > 0 for h in h1)


def test_straggler_lateness_rides_the_discount_path(problem):
    """A cohort that is always exactly one round late under max_staleness=0
    equals the on-time run with the delta pre-scaled by the discount: the
    lateness only enters through staleness_discount**s."""
    discount = 0.5
    late = _fed(async_rounds=True, max_staleness=0,
                staleness_discount=discount, straggler_rate=1.0,
                straggler_max_lateness=1)
    st_late, h_late = _sim(problem, late).run(jnp.zeros(D), 3)
    assert all(h["straggled"] == 1 and h["staleness"] == 1 for h in h_late)
    # sgd server: lr * (discount * delta) == (lr * discount) * delta
    ontime = _fed(async_rounds=True, max_staleness=0,
                  server_lr=BASE["server_lr"] * discount)
    st_ref, _ = _sim(problem, ontime).run(jnp.zeros(D), 3)
    np.testing.assert_allclose(np.asarray(st_late.params),
                               np.asarray(st_ref.params), rtol=1e-6)


# ---------------------------------------------------------------------------
# Dropped clients' persistent state must not land
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("store_placement", ["host", "device"])
@pytest.mark.parametrize("algorithm,extra", [
    ("scaffold", {}),
    ("fedep", dict(burn_in_steps=4, steps_per_sample=2, shrinkage_rho=0.5,
                   fedep_damping=0.7)),
])
def test_dropped_client_state_not_written(problem, algorithm, extra,
                                          store_placement):
    """After a masked stateful round the dropped clients' store rows are
    still the zero init with unbumped stamps; survivors' rows landed."""
    fed = FedConfig(algorithm=algorithm, **{**BASE, **extra},
                    dropout_rate=0.5,
                    client_state_placement=store_placement)
    sim = _sim(problem, fed)
    mask = np.array([1.0, 0.0, 0.0, 1.0], np.float32)
    ids = np.array([1, 4, 6, 9])
    batches = sim.stack_cohort(ids, 0)
    cohort = Cohort(0, ids, batches, None, mask, 0, dropped=2)
    sim.round(sim.init(jnp.zeros(D)), 0, cohort)

    sd = sim.client_store.state_dict()
    stamps = np.asarray(sd["stamps"])
    np.testing.assert_array_equal(stamps[ids], mask.astype(stamps.dtype))
    leaves = [np.asarray(leaf)
              for leaf in jax.tree_util.tree_leaves(sd["buffers"])]
    for cid, m in zip(ids, mask):
        if m == 0:
            for leaf in leaves:
                np.testing.assert_array_equal(leaf[cid],
                                              np.zeros_like(leaf[cid]))
        else:
            assert any(np.any(leaf[cid] != 0) for leaf in leaves)


# ---------------------------------------------------------------------------
# Heterogeneous local-step budgets
# ---------------------------------------------------------------------------

def test_budget_masking_is_exact_under_sgd(problem):
    """A client budgeted b steps out of K produces EXACTLY the delta of a
    b-step run: past the budget, gradients are masked and plain SGD params
    freeze."""
    grad_fn, batch_fn = problem
    b = 3
    params = jnp.zeros(D)
    ids = np.array([5])

    fed_b = _fed(clients_per_round=1, min_local_steps=b)
    sim_b = _sim(problem, fed_b)
    full = sim_b.stack_cohort(ids, 0)
    full = dict(full)
    full["_active"] = (np.arange(K)[None, :] < b).astype(np.float32)
    st_b, _ = sim_b.round(sim_b.init(params),
                          0, Cohort(0, ids, full, None, None, 0, 0))

    fed_r = _fed(clients_per_round=1, local_steps=b)
    sim_r = _sim(problem, fed_r)
    short = {k: v[:, :b] for k, v in sim_b.stack_cohort(ids, 0).items()}
    st_r, _ = sim_r.round(sim_r.init(params),
                          0, Cohort(0, ids, short, None, None, 0, 0))
    np.testing.assert_array_equal(np.asarray(st_b.params),
                                  np.asarray(st_r.params))


def test_full_budgets_match_unbudgeted_run(problem):
    """min_local_steps == local_steps draws every budget at K, and the
    budget-masked program reproduces the plain run bitwise."""
    st_p, _ = _sim(problem, _fed()).run(jnp.zeros(D), 3)
    st_b, _ = _sim(problem, _fed(min_local_steps=K)).run(jnp.zeros(D), 3)
    np.testing.assert_array_equal(np.asarray(st_p.params),
                                  np.asarray(st_b.params))


def test_budgets_require_dict_batches():
    fed = _fed(min_local_steps=2)
    src = CohortSource(fed, N, lambda ids, r: np.zeros((C, K, 2)), seed=0)
    with pytest.raises(TypeError, match="_active"):
        src.cohort(0)


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(availability="sometimes"), "availability"),
    (dict(availability="diurnal", availability_period=0), "period"),
    (dict(availability="diurnal", availability_duty=0.0), "duty"),
    (dict(availability="diurnal", availability_duty=1.5), "duty"),
    (dict(dropout_rate=-0.1), "dropout_rate"),
    (dict(dropout_rate=1.5), "dropout_rate"),
    (dict(straggler_rate=0.5), "async_rounds"),
    (dict(straggler_rate=0.5, async_rounds=True,
          straggler_max_lateness=0), "lateness"),
    (dict(min_local_steps=-1), "min_local_steps"),
    (dict(min_local_steps=99), "min_local_steps"),
    (dict(min_local_steps=2, client_opt="sgdm"), "sgd"),
    (dict(prefetch_backend="greenlet"), "prefetch_backend"),
])
def test_fault_knob_validation(kw, match):
    with pytest.raises(ValueError, match=match):
        _fed(**kw)


def test_budgets_require_step_budget_support():
    """Algorithms whose client step mixes non-gradient terms (scaffold's
    control variates) cannot freeze exactly via grad masking: rejected."""
    with pytest.raises(ValueError, match="budget"):
        FedConfig(algorithm="scaffold", **{**BASE, "min_local_steps": 2})


def test_fault_injection_flag():
    assert not _fed().fault_injection
    for kw in (dict(dropout_rate=0.1), dict(availability="diurnal"),
               dict(straggler_rate=0.1, async_rounds=True),
               dict(min_local_steps=1)):
        assert _fed(**kw).fault_injection


# ---------------------------------------------------------------------------
# Process-based shared-memory prefetcher
# ---------------------------------------------------------------------------

def _np_cohort(r):
    n = 3 + r  # growing leaves force arena slot regrowth
    return Cohort(r, np.arange(n), {"x": np.full((n, 2), r, np.float32)},
                  None, np.ones(n, np.float32), 0, 0)


def test_process_prefetcher_order_and_copy_stability():
    """In-order delivery; returned leaves are owned copies that survive the
    arena slot being recycled and rewritten by later rounds."""
    with ProcessCohortPrefetcher(_np_cohort, 0, 4, depth=1) as p:
        first = p.get(0)
        for r in range(1, 4):
            c = p.get(r)
            assert c.round_idx == r
            np.testing.assert_array_equal(
                c.batches["x"], np.full((3 + r, 2), r, np.float32))
            np.testing.assert_array_equal(c.survivors,
                                          np.ones(3 + r, np.float32))
        # round 0's leaves must be unaffected by the slot reuse above
        np.testing.assert_array_equal(first.batches["x"],
                                      np.zeros((3, 2), np.float32))


def test_process_prefetcher_propagates_builder_errors():
    def build(r):
        if r == 1:
            raise ValueError("boom-1")
        return _np_cohort(r)

    with ProcessCohortPrefetcher(build, 0, 3, depth=2) as p:
        p.get(0)
        with pytest.raises(RuntimeError, match="boom-1"):
            p.get(1)


def test_process_prefetcher_close_is_idempotent():
    p = ProcessCohortPrefetcher(_np_cohort, 0, 100, depth=2)
    p.get(0)
    p.close()
    p.close()


def test_make_prefetcher_rejects_unknown_backend():
    with pytest.raises(ValueError, match="prefetch_backend"):
        make_prefetcher("greenlet", _np_cohort, 0, 1)


def test_make_prefetcher_falls_back_on_jax_leaves():
    """A jax-computing build_fn cannot cross the fork: the factory probes
    one cohort and falls back to the thread backend with a warning."""
    def build(r):
        return Cohort(r, np.arange(2), {"x": jnp.zeros((2, 2))}, None)

    with pytest.warns(RuntimeWarning, match="falling back"):
        p = make_prefetcher("process", build, 0, 2)
    try:
        assert type(p).__name__ == "CohortPrefetcher"
        assert p.get(0).round_idx == 0
    finally:
        p.close()


def test_process_backend_run_matches_thread_backend(problem):
    """FedSim end-to-end: numpy-leaf cohorts through the shared-memory
    arena reproduce the thread backend's run bitwise."""
    grad_fn, batch_fn = problem

    def np_batch_fn(cid, r, steps):
        return {k: np.asarray(v) for k, v in batch_fn(cid, r, steps).items()}

    params = jnp.zeros(D)
    runs = {}
    for backend in ("thread", "process"):
        fed = _fed(dropout_rate=0.3, prefetch_rounds=2,
                   prefetch_backend=backend)
        sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=np_batch_fn,
                     num_clients=N, seed=7)
        runs[backend] = sim.run(params, 4)
    np.testing.assert_array_equal(np.asarray(runs["thread"][0].params),
                                  np.asarray(runs["process"][0].params))
    assert [h["dropped"] for h in runs["thread"][1]] == \
        [h["dropped"] for h in runs["process"][1]]


def test_cohort_source_weights_ride_the_cohort(problem):
    """Per-client population weights resolve to the cohort slice (and the
    eager positivity check still fires on the raw, pre-mask weights)."""
    fed = _fed(dropout_rate=0.5)
    w = np.linspace(1.0, 2.0, N)
    sim = _sim(problem, fed)
    src = CohortSource(fed, N, sim.stack_cohort, client_weights=w, seed=7)
    cohort = src.cohort(0)
    np.testing.assert_allclose(
        cohort.weights, w[cohort.client_ids].astype(np.float32))

    bad = CohortSource(fed, N, sim.stack_cohort,
                       client_weights=np.zeros(N), seed=7)
    with pytest.raises(ValueError, match="round 0"):
        bad.cohort(0)
