"""Theorem 3: the O(l^2 d) DP computes Sigma_hat^{-1}(x0 - xbar) exactly."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.dp_delta  # noqa: F401  (module import before package alias)
from repro.testing import given, settings, strategies as st

dp = sys.modules['repro.core.dp_delta']
from repro.core import tree_math as tm
from repro.core.shrinkage import dense_delta

jax.config.update("jax_enable_x64", True)


def _xs(seed, ell, d, scale=1.0):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.normal(size=d)),
            jnp.asarray(scale * r.normal(size=(ell, d))))


@given(st.integers(1, 10), st.integers(1, 20),
       st.floats(1e-3, 50.0), st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_dp_equals_dense(ell, d, rho, seed):
    x0, xs = _xs(seed, ell, d)
    want = np.asarray(dense_delta(x0, xs, rho))
    got = np.asarray(dp.dp_delta(x0, xs, rho))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


@given(st.integers(2, 8), st.integers(2, 12), st.floats(1e-3, 10.0),
       st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_online_equals_batch(ell, d, rho, seed):
    x0, xs = _xs(seed, ell, d)
    st_ = dp.online_dp_init(x0, ell, dtype=jnp.float64)
    for t in range(ell):
        st_ = dp.online_dp_update(st_, xs[t], rho)
    got = np.asarray(dp.online_dp_delta(st_, rho))
    want = np.asarray(dp.dp_delta(x0, xs, rho))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


def test_single_sample_is_fedavg():
    """l=1 (or the burn-in stop): Delta == theta_0 - theta — Section 4's
    'FedAvg is FedPA with identity covariance' claim."""
    x0, xs = _xs(0, 1, 7)
    got = np.asarray(dp.dp_delta(x0, xs, rho=3.0))
    np.testing.assert_allclose(got, np.asarray(x0 - xs[0]), rtol=1e-12)


def test_rho_zero_is_mean_fedavg():
    """rho=0 => Sigma_hat = I for every l: delta = x0 - xbar."""
    x0, xs = _xs(1, 5, 6)
    got = np.asarray(dp.dp_delta(x0, xs, rho=0.0))
    np.testing.assert_allclose(got, np.asarray(x0 - xs.mean(axis=0)),
                               rtol=1e-9, atol=1e-10)


def test_pytree_equals_flat():
    x0, xs = _xs(2, 4, 12)
    tree0 = {"w": x0[:4].reshape(2, 2), "b": {"x": x0[4:]}}
    trees = {"w": xs[:, :4].reshape(4, 2, 2), "b": {"x": xs[:, 4:]}}
    got = dp.dp_delta(tree0, trees, 0.4)
    flat = np.concatenate([np.asarray(got["w"]).ravel(),
                           np.asarray(got["b"]["x"]).ravel()])
    want = np.asarray(dp.dp_delta(x0, xs, 0.4))
    np.testing.assert_allclose(flat, want, rtol=1e-9)


def test_anytime_property():
    """Every prefix of the online stream equals the batch DP on that prefix
    (Appendix C: 'online as well as any-time')."""
    x0, xs = _xs(3, 6, 9)
    rho = 0.8
    st_ = dp.online_dp_init(x0, 6, dtype=jnp.float64)
    for t in range(6):
        st_ = dp.online_dp_update(st_, xs[t], rho)
        got = np.asarray(dp.online_dp_delta(st_, rho))
        want = np.asarray(dp.dp_delta(x0, xs[: t + 1], rho))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


def test_moe_sparse_coords():
    """Coordinates whose samples never move (unrouted experts) reduce to the
    FedAvg identity case: delta_j = (x0_j - xbar_j) / rho_l scaled by the
    identity part only — i.e. the DP needs no special-casing for sparse
    expert gradients (DESIGN.md §Arch-applicability)."""
    r = np.random.default_rng(7)
    d, ell, rho = 10, 5, 0.5
    x0 = jnp.asarray(r.normal(size=d))
    xs = np.tile(r.normal(size=d), (ell, 1))
    xs[:, :5] = r.normal(size=(ell, 5))        # only first 5 coords move
    xs = jnp.asarray(xs)
    got = np.asarray(dp.dp_delta(x0, xs, rho))
    want = np.asarray(dense_delta(x0, xs, rho))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)
    # frozen coords: Sigma_hat rows are rho_l on the diagonal, 0 elsewhere
    rho_l = 1.0 / (1.0 + (ell - 1) * rho)
    np.testing.assert_allclose(
        got[5:], np.asarray((x0 - xs[0])[5:]) / rho_l, rtol=1e-5
    )


def test_delta_converges_to_exact_with_gaussian_samples():
    """Delta_hat -> Sigma^{-1}(x0 - mu) as l grows (the bias-vanishes claim,
    Appendix A)."""
    r = np.random.default_rng(11)
    d = 6
    A = r.normal(size=(d, d))
    sigma = A @ A.T + 0.5 * np.eye(d)
    mu = r.normal(size=d)
    x0 = jnp.asarray(r.normal(size=d))
    exact = np.linalg.solve(sigma, np.asarray(x0) - mu)
    L = np.linalg.cholesky(sigma)
    errs = []
    for ell in (10, 100, 1000):
        xs = jnp.asarray(mu + r.normal(size=(ell, d)) @ L.T)
        got = np.asarray(dp.dp_delta(x0, xs, rho=1.0))
        errs.append(np.linalg.norm(got - exact) / np.linalg.norm(exact))
    assert errs[2] < errs[0], errs
    assert errs[2] < 0.2, errs


def test_tree_math_basics():
    a = {"x": jnp.arange(3.0), "y": jnp.ones((2, 2))}
    b = tm.tscale(2.0, a)
    assert float(tm.tvdot(a, a)) == pytest.approx(1 + 4 + 4.0)
    assert float(tm.tnorm(b)) == pytest.approx(2 * float(tm.tnorm(a)))
    c = tm.taxpy(-1.0, a, a)
    assert float(tm.tnorm(c)) == 0.0
