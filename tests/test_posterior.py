"""Propositions 1 & 2 and the federated-quadratics analysis (Section 3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posterior as po
from repro.data import make_federated_lsq, make_quadratic_clients

jax.config.update("jax_enable_x64", True)


def test_global_mode_minimizes_Q():
    clients = make_quadratic_clients(4, 5, seed=1, dtype=jnp.float64)
    mu = po.global_posterior_mode(clients)
    Q, gradQ = po.global_quadratic(clients)
    np.testing.assert_allclose(np.asarray(gradQ(mu)), 0.0, atol=1e-8)
    # and it minimizes the federated objective F as well (Prop 1 + Prop 2)
    F = po.global_objective(clients)
    for _ in range(5):
        other = mu + 0.1 * np.random.default_rng(0).normal(size=mu.shape)
        assert float(F(jnp.asarray(other))) > float(F(mu))


def test_global_mode_not_weighted_average_of_local_optima():
    """Footnote 1: the global optimum is generally NOT any convex combo of
    the local optima."""
    clients = make_quadratic_clients(2, 2, seed=3, dtype=jnp.float64)
    mu = np.asarray(po.global_posterior_mode(clients))
    a, b = np.asarray(clients[0].mu), np.asarray(clients[1].mu)
    # solve mu = t*a + (1-t)*b for t in both coordinates; inconsistent => not on segment
    t0 = (mu[0] - b[0]) / (a[0] - b[0])
    t1 = (mu[1] - b[1]) / (a[1] - b[1])
    assert abs(t0 - t1) > 1e-3


def test_client_from_data_matches_lstsq():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(40, 3))
    y = rng.normal(size=40)
    c = po.client_from_data(jnp.asarray(X), jnp.asarray(y))
    want, *_ = np.linalg.lstsq(X, y, rcond=None)
    np.testing.assert_allclose(np.asarray(c.mu), want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(c.sigma_inv), X.T @ X, rtol=1e-5,
                               atol=1e-4)


def test_fedavg_fixed_point_is_biased_and_bias_grows_with_k():
    """Fig. 1's phenomenon: more local steps push FedAvg's fixed point
    further from the global optimum (heterogeneous clients)."""
    clients, _ = make_federated_lsq(3, 30, 4, heterogeneity=30.0, seed=2,
                                    dtype=jnp.float64)
    mu = np.asarray(po.global_posterior_mode(clients))
    lr = 1e-3
    d1 = np.linalg.norm(np.asarray(po.fedavg_fixed_point(clients, 1, lr)) - mu)
    d10 = np.linalg.norm(np.asarray(po.fedavg_fixed_point(clients, 10, lr)) - mu)
    d100 = np.linalg.norm(np.asarray(po.fedavg_fixed_point(clients, 100, lr)) - mu)
    assert d1 < 1e-6          # K=1 == mini-batch SGD: unbiased fixed point
    assert d100 > d10 > d1    # bias grows with local computation


def test_exact_deltas_drive_server_to_global_optimum():
    """Proposition 2: gradient descent on Q with exact client deltas
    converges to the global posterior mode."""
    clients = make_quadratic_clients(5, 6, seed=4, dtype=jnp.float64)
    mu = np.asarray(po.global_posterior_mode(clients))
    theta = jnp.zeros(6, jnp.float64)
    _, gradQ = po.global_quadratic(clients)
    A = sum(c.weight * c.sigma_inv for c in clients)
    lr = 1.0 / float(jnp.linalg.norm(A, ord=2))
    for _ in range(2000):
        theta = theta - lr * gradQ(theta)
    np.testing.assert_allclose(np.asarray(theta), mu, rtol=1e-5, atol=1e-6)
