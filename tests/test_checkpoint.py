"""Checkpoint roundtrip + failure modes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.core.server import ServerState


def _state():
    params = {"w": jnp.arange(6.0).reshape(2, 3),
              "b": {"x": jnp.ones(4, jnp.bfloat16)}}
    opt = {"m": jnp.zeros((2, 3)), "step": jnp.asarray(7, jnp.int32)}
    return ServerState(params, opt, jnp.asarray(3, jnp.int32))


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), st, 3, {"arch": "t"})
    got, step, meta = restore_checkpoint(str(tmp_path), st)
    assert step == 3 and meta["arch"] == "t"
    np.testing.assert_array_equal(np.asarray(got.params["w"]),
                                  np.asarray(st.params["w"]))
    assert got.params["b"]["x"].dtype == jnp.bfloat16
    assert int(got.round) == 3


def test_latest_and_multiple(tmp_path):
    st = _state()
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), st, s)
    assert latest_checkpoint(str(tmp_path)) == 5
    _, step, _ = restore_checkpoint(str(tmp_path), st)
    assert step == 5
    _, step, _ = restore_checkpoint(str(tmp_path), st, step=3)
    assert step == 3


def test_shape_mismatch_fails(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), st, 1)
    bad = st._replace(params={"w": jnp.zeros((3, 3)),
                              "b": {"x": jnp.ones(4, jnp.bfloat16)}})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_missing_dir_fails(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), _state())
