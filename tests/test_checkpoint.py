"""Checkpoint roundtrip + failure modes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.core.server import ServerState


def _state():
    params = {"w": jnp.arange(6.0).reshape(2, 3),
              "b": {"x": jnp.ones(4, jnp.bfloat16)}}
    opt = {"m": jnp.zeros((2, 3)), "step": jnp.asarray(7, jnp.int32)}
    return ServerState(params, opt, jnp.asarray(3, jnp.int32))


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), st, 3, {"arch": "t"})
    got, step, meta = restore_checkpoint(str(tmp_path), st)
    assert step == 3 and meta["arch"] == "t"
    np.testing.assert_array_equal(np.asarray(got.params["w"]),
                                  np.asarray(st.params["w"]))
    assert got.params["b"]["x"].dtype == jnp.bfloat16
    assert int(got.round) == 3


def test_latest_and_multiple(tmp_path):
    st = _state()
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), st, s)
    assert latest_checkpoint(str(tmp_path)) == 5
    _, step, _ = restore_checkpoint(str(tmp_path), st)
    assert step == 5
    _, step, _ = restore_checkpoint(str(tmp_path), st, step=3)
    assert step == 3


def test_shape_mismatch_fails(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), st, 1)
    bad = st._replace(params={"w": jnp.zeros((3, 3)),
                              "b": {"x": jnp.ones(4, jnp.bfloat16)}})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_missing_dir_fails(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), _state())


# ---------------------------------------------------------------------------
# Shard-local client-store checkpoints
# ---------------------------------------------------------------------------

def _filled_host_store(n=10):
    from repro.core.client_state import make_client_store
    store = make_client_store("host", n).ensure(
        {"c": np.zeros((3,), np.float32)})
    ids = np.array([0, 3, n - 1])
    _, stamps = store.gather(ids)
    store.scatter(ids, {"c": np.arange(9, dtype=np.float32).reshape(3, 3)},
                  stamps)
    return store


def test_store_shard_roundtrip_and_latest(tmp_path):
    from repro.checkpoint import (latest_sharded_checkpoint,
                                  restore_store_sharded, save_store_sharded)
    store = _filled_host_store()
    save_store_sharded(str(tmp_path), store, 5)
    # shard files never alias the server checkpoint family
    assert latest_checkpoint(str(tmp_path)) is None
    assert latest_sharded_checkpoint(str(tmp_path)) == 5
    store2 = _filled_host_store()
    store2.reset()
    assert restore_store_sharded(str(tmp_path), store2) == 5
    a, b = store.state_dict(), store2.state_dict()
    np.testing.assert_array_equal(a["stamps"], b["stamps"])
    np.testing.assert_array_equal(a["buffers"]["c"], b["buffers"]["c"])


def test_sharded_restore_reassembles_multiple_shards(tmp_path):
    """Topology change: two saved shards, restored by one process."""
    from repro.checkpoint import restore_store_sharded, save_checkpoint_shard
    store = _filled_host_store()
    full = store.state_dict()
    for i, (lo, hi) in enumerate(((0, 5), (5, 10))):
        save_checkpoint_shard(
            str(tmp_path),
            {"stamps": full["stamps"][lo:hi],
             "buffers": {"c": full["buffers"]["c"][lo:hi]}},
            7, row_offset=lo, shard_index=i, num_shards=2)
    store2 = _filled_host_store()
    store2.reset()
    restore_store_sharded(str(tmp_path), store2)
    got = store2.state_dict()
    np.testing.assert_array_equal(got["stamps"], full["stamps"])
    np.testing.assert_array_equal(got["buffers"]["c"], full["buffers"]["c"])


def test_incomplete_shard_set_is_skipped(tmp_path):
    """A crash mid-save (some hosts wrote, some didn't) must not be
    offered for restore."""
    from repro.checkpoint import (latest_sharded_checkpoint,
                                  restore_store_sharded,
                                  save_checkpoint_shard, save_store_sharded)
    store = _filled_host_store()
    save_store_sharded(str(tmp_path), store, 2)     # complete 1-of-1
    full = store.state_dict()
    save_checkpoint_shard(str(tmp_path),
                          {"stamps": full["stamps"][:5],
                           "buffers": {"c": full["buffers"]["c"][:5]}},
                          9, row_offset=0, shard_index=0, num_shards=2)
    assert latest_sharded_checkpoint(str(tmp_path)) == 2
    store2 = _filled_host_store()
    with pytest.raises(FileNotFoundError, match="1/2 shards"):
        restore_store_sharded(str(tmp_path), store2, step=9)


def test_non_contiguous_shards_fail_loudly(tmp_path):
    from repro.checkpoint import restore_store_sharded, save_checkpoint_shard
    store = _filled_host_store()
    full = store.state_dict()
    for i, (lo, hi) in enumerate(((0, 4), (5, 10))):   # row 4 missing
        save_checkpoint_shard(
            str(tmp_path),
            {"stamps": full["stamps"][lo:hi],
             "buffers": {"c": full["buffers"]["c"][lo:hi]}},
            3, row_offset=lo, shard_index=i, num_shards=2)
    with pytest.raises(ValueError, match="not contiguous"):
        restore_store_sharded(str(tmp_path), store, step=3)


def test_shard_index_validation(tmp_path):
    from repro.checkpoint import save_checkpoint_shard
    with pytest.raises(ValueError, match="out of range"):
        save_checkpoint_shard(str(tmp_path), {"stamps": np.zeros(2)}, 0,
                              row_offset=0, shard_index=2, num_shards=2)
