"""Shrinkage estimator: closed forms and the rank-1 recursion (Appendix C.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import shrinkage as sh
from repro.testing import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)


def _samples(seed, ell, d):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(ell, d)))


@given(st.integers(2, 8), st.integers(1, 10),
       st.floats(0.01, 10.0), st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_rank1_recursion(ell, d, rho, seed):
    """Sigma~_t = Sigma~_{t-1} + gamma_t u_t u_t^T exactly (eq. 18)."""
    xs = _samples(seed, ell, d)
    for t in range(2, ell + 1):
        lhs = sh.shrinkage_cov_unnormalized(xs[:t], rho)
        u = xs[t - 1] - jnp.mean(xs[: t - 1], axis=0)
        rhs = sh.shrinkage_cov_unnormalized(xs[: t - 1], rho) \
            + sh.gamma_t(t, rho) * jnp.outer(u, u)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-9, atol=1e-9)


@given(st.integers(1, 12), st.floats(0.0, 100.0))
@settings(max_examples=50, deadline=None)
def test_rho_l_range(ell, rho):
    r = sh.rho_l(ell, rho)
    assert 0.0 < r <= 1.0
    if ell == 1:
        assert r == 1.0   # Sigma_hat_1 == I: the FedAvg special case


def test_normalized_vs_unnormalized():
    xs = _samples(3, 5, 4)
    rho = 0.7
    r = sh.rho_l(5, rho)
    np.testing.assert_allclose(
        np.asarray(sh.shrinkage_cov(xs, rho)),
        r * np.asarray(sh.shrinkage_cov_unnormalized(xs, rho)),
        rtol=1e-12,
    )


def test_shrinkage_limits():
    xs = _samples(1, 6, 3)
    # rho -> 0: Sigma_hat == I
    np.testing.assert_allclose(np.asarray(sh.shrinkage_cov(xs, 0.0)),
                               np.eye(3), atol=1e-12)
    # rho large: Sigma_hat -> sample covariance
    big = sh.shrinkage_cov(xs, 1e9)
    _, cov = sh.sample_mean_cov(xs)
    np.testing.assert_allclose(np.asarray(big), np.asarray(cov), rtol=1e-6,
                               atol=1e-6)


def test_dense_delta_identity_case():
    xs = _samples(2, 1, 4)
    x0 = jnp.asarray(np.random.default_rng(9).normal(size=4))
    # single sample: Sigma_hat = I -> delta = x0 - x1 (FedAvg)
    np.testing.assert_allclose(np.asarray(sh.dense_delta(x0, xs, 0.5)),
                               np.asarray(x0 - xs[0]), rtol=1e-10)


def test_oas_rho_bounds():
    xs = _samples(4, 8, 16)
    r = float(sh.oas_rho(xs))
    assert 0.0 <= r <= 1.0


def test_dense_delta_matches_linear_solve():
    xs = _samples(5, 6, 5)
    x0 = jnp.asarray(np.random.default_rng(10).normal(size=5))
    rho = 0.3
    want = np.linalg.solve(np.asarray(sh.shrinkage_cov(xs, rho)),
                           np.asarray(x0 - xs.mean(axis=0)))
    np.testing.assert_allclose(np.asarray(sh.dense_delta(x0, xs, rho)), want,
                               rtol=1e-8)
