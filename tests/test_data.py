"""Data pipeline: determinism, partitioning, heterogeneity, sampling."""
import numpy as np
import pytest

from repro.data import (ClientSampler, SyntheticLMData,
                        make_dirichlet_classification, make_federated_lsq)
from repro.data.synthetic_lsq import lsq_batches, make_regression


def test_lm_determinism_per_client():
    d = SyntheticLMData(vocab_size=1000, num_clients=8, seed=42)
    a = d.client_tokens(3, 500)
    b = d.client_tokens(3, 500)
    np.testing.assert_array_equal(a, b)
    c = d.client_tokens(4, 500)
    assert not np.array_equal(a, c)          # clients differ
    e = d.client_tokens(3, 500, salt=1)
    assert not np.array_equal(a, e)          # rounds differ


def test_lm_batch_layout_and_range():
    d = SyntheticLMData(vocab_size=321, num_clients=4, seed=0)
    b = d.client_batches(0, num_steps=3, batch=2, seq_len=16)
    assert b.shape == (3, 2, 17)
    assert int(b.max()) < 321 and int(b.min()) >= 0
    r = d.round_batches([0, 2], num_steps=3, batch=2, seq_len=16)
    assert r.shape == (2, 3, 2, 17)


def test_lm_client_bigram_heterogeneity():
    """Clients have distinguishable successor statistics for hot tokens —
    the non-IID-ness FedAvg stagnates on."""
    d = SyntheticLMData(vocab_size=256, num_clients=4, seed=1, hot_tokens=32)
    def succ_of_zero(cid):
        t = np.asarray(d.client_tokens(cid, 40_000))
        nxt = t[1:][t[:-1] == 0]
        vals, counts = np.unique(nxt, return_counts=True)
        return vals[np.argmax(counts)]
    s = {succ_of_zero(c) for c in range(4)}
    assert len(s) > 1


def test_frontend_embeddings_shape_and_scale():
    d = SyntheticLMData(vocab_size=100, num_clients=2, seed=0)
    e = np.asarray(d.frontend_embeddings(0, batch=3, tokens=8, d_model=64))
    assert e.shape == (3, 8, 64)
    assert 0.05 < e.std() < 0.3               # ~1/sqrt(d_model)


def test_dirichlet_label_skew():
    fc = make_dirichlet_classification(20, 10, 16, alpha=0.05, seed=0)
    assert len(fc.client_x) == 20
    # low alpha: most clients dominated by a few labels
    fracs = []
    for ys in fc.client_y:
        _, counts = np.unique(ys, return_counts=True)
        fracs.append(counts.max() / counts.sum())
    assert np.median(fracs) > 0.5
    # test set is balanced-ish
    _, tc = np.unique(np.asarray(fc.test_y), return_counts=True)
    assert tc.min() > 0.5 * tc.mean()


def test_make_regression_shapes_and_recoverable():
    X, y, w = make_regression(500, 8, noise=0.1, seed=0)
    est, *_ = np.linalg.lstsq(X, y, rcond=None)
    np.testing.assert_allclose(est, w, atol=0.05)


def test_federated_lsq_weights_sum_to_one():
    clients, data = make_federated_lsq(5, 20, 3, seed=0)
    assert sum(float(c.weight) for c in clients) == pytest.approx(1.0)
    assert len(data) == 5 and data[0][0].shape == (20, 3)


def test_lsq_batches():
    clients, data = make_federated_lsq(1, 30, 3, seed=0)
    b = lsq_batches(*data[0], batch_size=4, num_steps=7, seed=1)
    assert b["x"].shape == (7, 4, 3) and b["y"].shape == (7, 4)


def test_client_sampler():
    s = ClientSampler(100, 10, seed=0)
    ids = s.sample(0)
    assert len(ids) == 10 and len(set(ids.tolist())) == 10
    np.testing.assert_array_equal(ids, s.sample(0))   # deterministic
    assert not np.array_equal(ids, s.sample(1))
    counts = s.participation_counts(200)
    assert counts.sum() == 2000
    with pytest.raises(ValueError):
        ClientSampler(5, 10)


def test_client_sampler_names_the_bad_knob():
    with pytest.raises(ValueError, match="num_clients must be >= 1"):
        ClientSampler(0, 1)
    with pytest.raises(ValueError, match="clients_per_round must be >= 1"):
        ClientSampler(5, 0)
    with pytest.raises(ValueError, match=r"clients_per_round \(10\)"):
        ClientSampler(5, 10)
