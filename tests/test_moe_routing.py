"""Sort-based MoE routing (§Perf optimization) vs the GShard one-hot
baseline: exact equivalence under ample capacity; graceful dropping under
overflow."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import init_params, lm_loss
from repro.models.moe import (_route_chunk, _route_chunk_sort,
                              init_moe_params, moe_ffn)


def _cfg(routing="onehot", cf=8.0, experts=4, k=2):
    cfg = configs.get_smoke("qwen3-moe-30b-a3b")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, routing=routing,
                                     capacity_factor=cf,
                                     num_experts=experts, top_k=k))


@pytest.mark.parametrize("k", [1, 2])
def test_sort_equals_onehot_with_ample_capacity(k):
    cfg = _cfg(cf=8.0, k=k)
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    y1, a1 = _route_chunk(x, p, cfg.moe)
    y2, a2 = _route_chunk_sort(x, p, cfg.moe)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


def test_sort_respects_capacity():
    cfg = _cfg(cf=0.25)   # force overflow
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, cfg.d_model))
    y, _ = _route_chunk_sort(x, p, cfg.moe)
    assert np.all(np.isfinite(np.asarray(y)))
    # overflowed tokens must pass through as zeros (residual carries them)
    norms = np.linalg.norm(np.asarray(y), axis=-1)
    assert (norms == 0.0).sum() > 0


def test_full_model_with_sort_routing():
    cfg = _cfg(routing="sort")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 33), 0,
                                          cfg.vocab_size)}
    loss, _ = jax.jit(lambda p, b: lm_loss(p, b, cfg, q_chunk=16))(params,
                                                                   batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: lm_loss(p, batch, cfg, q_chunk=16)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves)


def test_moe_ffn_padding_path_sort():
    cfg = _cfg(routing="sort")
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    # B*S not a multiple of chunk: exercises the pad/trim path
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 33, cfg.d_model))
    y, aux = moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
