"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable (c): per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp_delta import dp_delta
from repro.core.shrinkage import dense_delta
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# fedpa_dp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [64, 500, 1000, 4096])
@pytest.mark.parametrize("ell", [2, 3, 6])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_dp_delta_flat_vs_core_and_dense(d, ell, dtype):
    r = np.random.default_rng(d * 31 + ell)
    x0 = jnp.asarray(r.normal(size=d), dtype)
    xs = jnp.asarray(r.normal(size=(ell, d)), dtype)
    rho = 0.4
    got = np.asarray(ops.dp_delta_flat(x0, xs, rho=rho))
    want = np.asarray(dp_delta(x0, xs, rho))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    oracle = np.asarray(dense_delta(x0, xs, rho))
    scale = max(np.abs(oracle).max(), 1.0)
    np.testing.assert_allclose(got / scale, oracle / scale, rtol=5e-4,
                               atol=5e-4)


@pytest.mark.parametrize("t", [2, 3, 5])
def test_dp_step_vs_ref(t):
    r = np.random.default_rng(t)
    d, lp, rho = 700, 6, 0.7
    u = jnp.asarray(r.normal(size=d), jnp.float32)
    delta = jnp.asarray(r.normal(size=d), jnp.float32)
    V = jnp.asarray(r.normal(size=(lp, d)), jnp.float32)
    c_hist = jnp.asarray(np.abs(r.normal(size=lp)), jnp.float32)
    v_k, d_k, a_k, c_k = ops.dp_step(u, delta, V, c_hist, t, rho=rho)
    v_r, d_r, a_r, c_r = ref.dp_step_ref(u, delta, V, c_hist, t, rho)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-5,
                               atol=1e-5)
    assert float(a_k) == pytest.approx(float(a_r), rel=1e-4)
    assert float(c_k) == pytest.approx(float(c_r), rel=1e-4)


def test_dp_reduce_partials_vs_ref():
    from repro.kernels.fedpa_dp import dp_reduce
    r = np.random.default_rng(0)
    d, lp = 1300, 4   # non-multiple of the 512 tile: exercises padding
    u = jnp.asarray(r.normal(size=d), jnp.float32)
    delta = jnp.asarray(r.normal(size=d), jnp.float32)
    V = jnp.asarray(r.normal(size=(lp, d)), jnp.float32)
    dots, uu, ud = dp_reduce(u, delta, V)
    dots_r, uu_r, ud_r = ref.dp_reduce_ref(u, delta, V)
    np.testing.assert_allclose(np.asarray(dots), np.asarray(dots_r),
                               rtol=1e-5)
    assert float(uu) == pytest.approx(float(uu_r), rel=1e-5)
    assert float(ud) == pytest.approx(float(ud_r), rel=1e-5)


# ---------------------------------------------------------------------------
# swa_decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,dh,L", [
    (1, 4, 1, 64, 512),      # MQA (granite-style)
    (2, 8, 2, 64, 1024),     # GQA
    (2, 4, 4, 128, 512),     # MHA, wide heads
])
@pytest.mark.parametrize("window", [0, 300])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_decode_sweep(B, H, KV, dh, L, window, dtype):
    r = np.random.default_rng(B * 7 + H + window)
    q = jnp.asarray(r.normal(size=(B, H, dh)), dtype)
    k = jnp.asarray(r.normal(size=(B, L, KV, dh)), dtype)
    v = jnp.asarray(r.normal(size=(B, L, KV, dh)), dtype)
    pos = L - 50
    slot = jnp.where(jnp.arange(L) <= pos, jnp.arange(L), -1).astype(jnp.int32)
    got = ops.swa_decode(q, k, v, slot, pos, window=window)
    want = ref.swa_decode_ref(q.reshape(B, KV, H // KV, dh), k, v, slot, pos,
                              window=window).reshape(B, H, dh)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol)


def test_swa_decode_ring_buffer_layout():
    """Ring cache: slots hold interleaved positions; masking must follow
    slot_pos, not slot order."""
    r = np.random.default_rng(3)
    B, H, KV, dh, L, W = 1, 2, 1, 64, 512, 256
    q = jnp.asarray(r.normal(size=(B, H, dh)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, L, KV, dh)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, L, KV, dh)), jnp.float32)
    pos = 700   # ring wrapped: slot i holds position (pos//L)*L + i or older
    slots = np.arange(L)
    slot_pos = np.where(slots <= pos % L, (pos // L) * L + slots,
                        (pos // L - 1) * L + slots).astype(np.int32)
    sp = jnp.asarray(slot_pos)
    got = ops.swa_decode(q, k, v, sp, pos, window=W)
    want = ref.swa_decode_ref(q.reshape(B, KV, H, dh), k, v, sp, pos,
                              window=W).reshape(B, H, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)
    # exactly W positions are visible
    visible = ((slot_pos >= 0) & (slot_pos <= pos)
               & (slot_pos > pos - W)).sum()
    assert visible == W


def test_swa_decode_matches_model_attention():
    """Kernel output == the model's attn_decode math (wiring check)."""
    from repro.configs import get_smoke
    from repro.models.attention import (attn_decode, init_attn_cache,
                                        init_attn_params)
    cfg = get_smoke("gemma3-27b")
    spec = cfg.pattern[0]   # swa window 32
    p = init_attn_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 32
    cache = init_attn_cache(cfg, spec, B, max_len=64, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model))
    # feed a few tokens to populate the ring
    for t in range(5):
        y, cache = attn_decode(p, x, cache, cfg, spec, jnp.asarray(t))
    assert np.all(np.isfinite(np.asarray(y)))
