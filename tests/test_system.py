"""End-to-end system behaviour: federated LM training (the production code
path at CPU scale) actually learns, FedPA >= FedAvg on heterogeneous data,
and serving works after training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import FedConfig
from repro.core.server import init_server_state
from repro.core.sharded_round import make_fed_round
from repro.data import SyntheticLMData
from repro.models import init_params, lm_loss, prefill_step, serve_step
from repro.optim import get_optimizer


def _run_training(algorithm: str, rounds: int = 12, seed: int = 0):
    cfg = configs.get_smoke("fedlm-100m")
    fed = FedConfig(algorithm=algorithm, clients_per_round=4, local_steps=6,
                    burn_in_steps=2, steps_per_sample=2, shrinkage_rho=0.01,
                    server_opt="sgdm", server_lr=0.5,
                    client_opt="sgd", client_lr=0.1)
    data = SyntheticLMData(vocab_size=cfg.vocab_size, num_clients=16,
                           seed=seed)
    B, S = 4, 64
    params = init_params(jax.random.PRNGKey(seed), cfg)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state = init_server_state(params, server_opt)
    round_fn = jax.jit(make_fed_round(cfg, fed, placement="parallel",
                                      q_chunk=32))
    eval_batch = {"tokens": data.client_batches(99, 1, B, S)[0]}
    eval_fn = jax.jit(lambda p: lm_loss(p, eval_batch, cfg, q_chunk=32)[0])
    losses = [float(eval_fn(state.params))]
    for r in range(rounds):
        ids = np.random.default_rng(r + seed).choice(16, 4, replace=False)
        batches = {"tokens": data.round_batches(ids, fed.local_steps, B, S,
                                                round_idx=r)}
        state, _ = round_fn(state, batches)
        losses.append(float(eval_fn(state.params)))
    return cfg, state, losses


@pytest.mark.slow
def test_federated_training_learns():
    cfg, state, losses = _run_training("fedpa")
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0] - 0.5, losses


@pytest.mark.slow
def test_fedavg_also_learns_same_harness():
    cfg, state, losses = _run_training("fedavg")
    assert all(np.isfinite(losses)), losses
    # SGD-M at server_lr=0.5 oscillates at this scale: the trajectory dips
    # well below start and may bounce at the cutoff round, so assert on the
    # best loss reached (FedPA's smoother trajectory keeps the last-loss
    # assertion above)
    assert min(losses) < losses[0] - 0.5, losses


@pytest.mark.slow
def test_serve_after_training():
    cfg, state, _ = _run_training("fedpa", rounds=3)
    B, S = 2, 48
    data = SyntheticLMData(vocab_size=cfg.vocab_size, num_clients=4, seed=1)
    prompts = data.client_batches(0, 1, B, S)[0][:, :-1]
    logits, dstate = prefill_step(state.params, prompts, cfg,
                                  max_len=S + 16, q_chunk=16)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for _ in range(8):
        tok, logits, dstate = serve_step(state.params, tok, dstate, cfg)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(tok.max()) < cfg.vocab_size
