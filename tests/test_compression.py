"""Compressed payloads: codec round-trip error bounds, deterministic
per-round sketches, error-feedback residual parity with an eager
reference, fedlora == fedpa_precision under the identity codec, the
heterogeneous-LSQ acceptance gate (<= 5% loss gap at >= 8x fewer bytes,
error feedback measurably helping), per-round byte accounting in both
engines' history, eager FedConfig validation of the payload knobs, and
the gemma3-27b fedlora dry-run lowering (slow lane)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import get_algorithm
from repro.compression import build_codec, parse_codec, round_bytes
from repro.configs.base import FedConfig
from repro.core import FedSim
from repro.core.server import init_server_state, normalized_weights
from repro.optim import get_optimizer

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

# fedlora knobs reused everywhere: IASG windows divide evenly, no burn-in
# unless a test opts in
LORA_KW = dict(local_steps=6, burn_in_steps=2, steps_per_sample=2,
               shrinkage_rho=0.5, server_opt="sgd", server_lr=0.1,
               client_opt="sgd", client_lr=0.01)


def _fed(codec, **kw):
    base = dict(algorithm="fedlora", payload_codec=codec, lora_rank=2,
                clients_per_round=3, **LORA_KW)
    base.update(kw)
    return FedConfig(**base)


def _tree(seed=0):
    """A mixed tree: one lowrank-eligible matrix, one passthrough vector."""
    rng = np.random.RandomState(seed)
    return {"w": jnp.asarray(rng.randn(12, 6).astype(np.float32)),
            "b": jnp.asarray(rng.randn(6).astype(np.float32))}


# ---------------------------------------------------------------------------
# Codec round trips
# ---------------------------------------------------------------------------

def test_none_codec_roundtrip_exact():
    codec = build_codec(_fed("none"))
    x = _tree()
    out = codec.decode(codec.encode(x, 3), 3, x)
    for k in x:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(x[k]))


def test_int8_roundtrip_error_bounded():
    """Symmetric quantization: |x - dec| <= scale/2 per leaf, elementwise."""
    codec = build_codec(_fed("int8"))
    x = _tree()
    enc = codec.encode(x, 0)
    out = codec.decode(enc, 0, x)
    for k in x:
        assert set(enc[k]) == {"q", "scale"}
        assert enc[k]["q"].dtype == jnp.int8
        half_step = float(np.max(np.abs(np.asarray(x[k])))) / 127.0 / 2
        err = np.max(np.abs(np.asarray(out[k]) - np.asarray(x[k])))
        assert err <= half_step + 1e-6, (k, err, half_step)


def test_int16_roundtrip_tighter_than_int8():
    x = _tree()
    errs = {}
    for bits in (8, 16):
        codec = build_codec(_fed("int8", quant_bits=bits))
        out = codec.decode(codec.encode(x, 0), 0, x)
        errs[bits] = max(
            float(np.max(np.abs(np.asarray(out[k]) - np.asarray(x[k]))))
            for k in x)
    assert errs[16] < errs[8] / 64  # 8 extra bits ~ 256x finer steps


def test_lowrank_projects_matrices_and_passes_vectors():
    """Eligible leaves land on rank-r factors; 1-D leaves are untouched;
    decode(encode(.)) is the orthogonal projection onto the sketch (so it
    is idempotent and exact for vectors already in the subspace)."""
    fed = _fed("lowrank")
    codec = build_codec(fed)
    x = _tree()
    enc = codec.encode(x, 5)
    assert enc["w"].shape == (12, fed.lora_rank)
    np.testing.assert_array_equal(np.asarray(enc["b"]), np.asarray(x["b"]))

    dec = codec.decode(enc, 5, x)
    assert dec["w"].shape == x["w"].shape
    # projection shrinks: ||P x|| <= ||x||, and strictly here (rank 2 < 6)
    assert (np.linalg.norm(np.asarray(dec["w"]))
            < np.linalg.norm(np.asarray(x["w"])))
    # idempotency: the projection of a projected tree is itself
    dec2 = codec.decode(codec.encode(dec, 5), 5, x)
    np.testing.assert_allclose(np.asarray(dec2["w"]), np.asarray(dec["w"]),
                               rtol=1e-5, atol=1e-6)


def test_lowrank_sketch_deterministic_and_rotating():
    """Same (seed, round) -> identical encoding; different round ->
    different sketch (what lets error feedback escape a fixed subspace)."""
    codec = build_codec(_fed("lowrank"))
    x = _tree()
    a = codec.encode(x, 7)
    b = codec.encode(x, 7)
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    c = codec.encode(x, 8)
    assert np.max(np.abs(np.asarray(a["w"]) - np.asarray(c["w"]))) > 1e-3


def test_composed_chain_roundtrip_error_bounded():
    """lowrank+int8: the decode error against the *projected* tree is the
    quantizer's half-step — composition adds no extra loss on top of the
    rank truncation."""
    fed = _fed("lowrank+int8")
    codec = build_codec(fed)
    x = _tree()
    projected = codec.decode_accum(
        codec.to_accum(codec.encode(x, 2)), 2, x)
    # reference: lowrank alone at the same round index
    lr_only = build_codec(_fed("lowrank"))
    want = lr_only.decode(lr_only.encode(x, 2), 2, x)
    for k in x:
        half_step = float(np.max(np.abs(np.asarray(
            lr_only.encode(x, 2)[k])))) / 127.0 / 2
        err = np.max(np.abs(np.asarray(projected[k]) - np.asarray(want[k])))
        # one quant half-step, lifted through an orthonormal basis
        assert err <= half_step * 2 + 1e-6, (k, err, half_step)


def test_parse_codec_rejects_malformed_specs():
    with pytest.raises(ValueError, match="unknown payload codec"):
        parse_codec("gzip")
    with pytest.raises(ValueError, match="cannot be composed"):
        parse_codec("none+int8")
    with pytest.raises(ValueError, match="duplicate"):
        parse_codec("int8+int8")
    with pytest.raises(ValueError, match="linear.*prefix"):
        parse_codec("int8+lowrank")
    assert parse_codec("lowrank+int8") == ("lowrank", "int8")


# ---------------------------------------------------------------------------
# FedConfig eagerly validates the payload knobs (incl. delta_dtype)
# ---------------------------------------------------------------------------

def test_fedconfig_payload_knobs_validated_eagerly():
    """Bad delta_dtype / codec / rank / bits used to surface as opaque
    trace-time errors inside the jitted round; FedConfig now rejects them
    by name at construction."""
    with pytest.raises(ValueError, match="delta_dtype"):
        FedConfig(delta_dtype="float99")
    with pytest.raises(ValueError, match="delta_dtype"):
        FedConfig(delta_dtype="int32")     # non-floating
    with pytest.raises(ValueError, match="unknown payload codec"):
        _fed("lowrank+gzip")
    with pytest.raises(ValueError, match="lora_rank"):
        _fed("lowrank", lora_rank=0)
    with pytest.raises(ValueError, match="quant_bits"):
        _fed("int8", quant_bits=7)
    # codecs only on algorithms that aggregate in the encoded space
    with pytest.raises(ValueError, match="payload_codec"):
        FedConfig(algorithm="fedavg", payload_codec="int8")
    # the good spellings construct
    FedConfig(delta_dtype="bfloat16")
    _fed("lowrank+int8", quant_bits=16)


# ---------------------------------------------------------------------------
# Engine-level parity and error feedback
# ---------------------------------------------------------------------------

C, DIN, DOUT, N = 3, 8, 6, 48


@pytest.fixture(scope="module")
def matrix_problem():
    """Heterogeneous matrix LSQ: y = X (W* + shift_c) + noise, one
    lowrank-eligible (DIN, DOUT) weight."""
    rng = np.random.RandomState(0)
    W_true = rng.randn(DIN, DOUT).astype(np.float32)
    data = {}
    for cid in range(C):
        shift = rng.randn(DIN, DOUT).astype(np.float32) * 0.5
        X = rng.randn(N, DIN).astype(np.float32)
        y = X @ (W_true + shift) + 0.1 * rng.randn(N, DOUT).astype(np.float32)
        data[cid] = (jnp.asarray(X), jnp.asarray(y))

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p["w"] - batch["y"]
            return 0.5 * jnp.mean(r * r)
        return jax.value_and_grad(loss)(params)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        rs = np.random.RandomState(r * 131 + cid)
        idx = rs.randint(0, N, size=(steps, 16))
        return {"x": X[idx], "y": y[idx]}

    return grad_fn, batch_fn, data


def test_error_feedback_residuals_match_eager_reference(matrix_problem):
    """Two participations of every client: the engine's persisted
    residuals and server params equal an eager per-client loop that
    hand-threads ``residual -> update -> state_update`` through the same
    jitted hooks."""
    grad_fn, batch_fn, _ = matrix_problem
    fed = _fed("lowrank+int8", round_placement="parallel")
    assert fed.error_feedback
    params0 = {"w": jnp.zeros((DIN, DOUT))}

    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    state = sim.init(params0)
    for r in range(2):
        state, _ = sim.round(state, r)
    got_res, _ = sim.client_store.gather(np.arange(C))

    # eager reference on the same sampled cohorts
    alg = get_algorithm(fed)
    client_opt = get_optimizer(fed.client_opt, fed.client_lr,
                               fed.client_momentum)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    update = jax.jit(alg.make_client_update(grad_fn, client_opt))
    ref = init_server_state(params0, server_opt, algorithm=alg)
    residuals = {cid: alg.init_client_state(params0) for cid in range(C)}
    for r in range(2):
        ids = [int(i) for i in sim.sampler.sample(r)]
        extras = alg.broadcast(ref, server_opt)
        payloads = []
        for cid in ids:
            res = update(ref.params, batch_fn(cid, r, fed.local_steps),
                         residuals[cid], *extras)
            payloads.append(res.payload)
            residuals[cid] = res.state_update
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                         *payloads)
        agg = alg.reduce_stacked(stacked, normalized_weights(None, C))
        agg = alg.finish_cohort(ref, agg)
        ref = alg.server_update(ref, agg, server_opt)

    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(ref.params["w"]),
                               rtol=1e-6, atol=1e-7)
    # residuals are real (compression lost something) and match per client
    for cid in range(C):
        want = np.asarray(residuals[cid]["w"])
        assert np.max(np.abs(want)) > 1e-4
        np.testing.assert_allclose(np.asarray(got_res["w"][cid]), want,
                                   rtol=1e-6, atol=1e-7)


def test_fedlora_identity_codec_matches_fedpa_precision(matrix_problem):
    """payload_codec='none', error feedback off: fedlora IS
    fedpa_precision — encode/decode are identities and finish_cohort
    computes the same precision-weighted mean."""
    grad_fn, batch_fn, _ = matrix_problem
    params0 = {"w": jnp.zeros((DIN, DOUT))}
    kw = dict(clients_per_round=C, **LORA_KW)
    lora = FedConfig(algorithm="fedlora", payload_codec="none",
                     error_feedback=False, **kw)
    dense = FedConfig(algorithm="fedpa_precision", **kw)
    outs = {}
    for name, fed in (("lora", lora), ("dense", dense)):
        sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                     num_clients=C)
        state, _ = sim.run(params0, 3)
        outs[name] = np.asarray(state.params["w"])
    np.testing.assert_allclose(outs["lora"], outs["dense"],
                               rtol=1e-6, atol=1e-7)


def _final_loss(state, data):
    l = 0.0
    for cid in data:
        X, y = data[cid]
        r = X @ state.params["w"] - y
        l += float(0.5 * jnp.mean(r * r))
    return l / len(data)


def test_fedlora_acceptance_loss_within_5pct_at_8x_fewer_bytes():
    """The PR's acceptance gate on heterogeneous matrix LSQ: fedlora with
    lowrank+int8 lands within 5% of dense fedpa_precision's final loss at
    >= 8x fewer measured uplink bytes per round, and error feedback
    closes a measurable gap."""
    C, DIN, DOUT, N = 6, 32, 16, 64
    rng = np.random.RandomState(0)
    W_true = rng.randn(DIN, DOUT).astype(np.float32)
    data = {}
    for cid in range(C):
        shift = rng.randn(DIN, DOUT).astype(np.float32) * 0.5
        X = rng.randn(N, DIN).astype(np.float32)
        y = X @ (W_true + shift) + 0.1 * rng.randn(N, DOUT).astype(
            np.float32)
        data[cid] = (jnp.asarray(X), jnp.asarray(y))

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p["w"] - batch["y"]
            return 0.5 * jnp.mean(r * r)
        return jax.value_and_grad(loss)(params)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        rs = np.random.RandomState(r * 131 + cid)
        idx = rs.randint(0, N, size=(steps, 16))
        return {"x": X[idx], "y": y[idx]}

    kw = dict(clients_per_round=C, local_steps=12, burn_in_steps=4,
              steps_per_sample=2, shrinkage_rho=0.3, burn_in_rounds=2,
              server_opt="sgd", server_lr=0.5, client_opt="sgd",
              client_lr=0.05)

    def run(fed):
        sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                     num_clients=C)
        return sim.run({"w": jnp.zeros((DIN, DOUT))}, 50)

    s_dense, h_dense = run(FedConfig(algorithm="fedpa_precision", **kw))
    s_lora, h_lora = run(FedConfig(algorithm="fedlora",
                                   payload_codec="lowrank+int8",
                                   lora_rank=4, **kw))
    s_noef, _ = run(FedConfig(algorithm="fedlora",
                              payload_codec="lowrank+int8", lora_rank=4,
                              error_feedback=False, **kw))

    dense_loss = _final_loss(s_dense, data)
    lora_loss = _final_loss(s_lora, data)
    noef_loss = _final_loss(s_noef, data)
    assert lora_loss <= dense_loss * 1.05, (lora_loss, dense_loss)

    # measured (history) uplink bytes, sampling rounds only (burn is dense)
    ratio = h_dense[-1]["bytes_up"] / h_lora[-1]["bytes_up"]
    assert ratio >= 8.0, ratio
    # error feedback is load-bearing, not decorative
    assert noef_loss > lora_loss * 1.2, (noef_loss, lora_loss)


# ---------------------------------------------------------------------------
# Byte accounting in history, both engines
# ---------------------------------------------------------------------------

def test_history_reports_bytes_for_all_algorithms(matrix_problem):
    """Every algorithm stamps exact per-round bytes_up/bytes_down into
    history as JSON-safe ints, matching ``round_bytes`` on the live
    params; stateful broadcasts (scaffold) pay a bigger downlink."""
    grad_fn, batch_fn, _ = matrix_problem
    params0 = {"w": jnp.zeros((DIN, DOUT))}
    feds = {
        "fedavg": FedConfig(algorithm="fedavg", clients_per_round=C,
                            local_steps=4, client_opt="sgd",
                            client_lr=0.05),
        "scaffold": FedConfig(algorithm="scaffold", clients_per_round=C,
                              local_steps=4, client_opt="sgd",
                              client_lr=0.05),
        "fedlora": _fed("lowrank+int8"),
    }
    down = {}
    for name, fed in feds.items():
        sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                     num_clients=C)
        _, hist = sim.run(params0, 2)
        want = round_bytes(fed, params0)
        for h in hist:
            assert type(h["bytes_up"]) is int      # json-safe
            assert h["bytes_up"] == want["bytes_up"]
            assert h["bytes_down"] == want["bytes_down"]
        json.dumps(hist)                           # round-trips as JSON
        down[name] = hist[0]["bytes_down"]
    # scaffold ships its control variate down; fedlora only an i32 round
    assert down["scaffold"] > down["fedavg"]
    assert down["fedlora"] == down["fedavg"] + C * 4


def test_burn_rounds_account_dense_bytes(matrix_problem):
    """fedlora burn-in rounds run dense fedavg: uplink bytes in history
    jump down when the compressed sampling regime starts."""
    grad_fn, batch_fn, _ = matrix_problem
    fed = _fed("lowrank+int8", burn_in_rounds=1)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=C)
    _, hist = sim.run({"w": jnp.zeros((DIN, DOUT))}, 3)
    assert hist[0]["bytes_up"] > hist[1]["bytes_up"]
    assert hist[1]["bytes_up"] == hist[2]["bytes_up"]
    dense = round_bytes(fed, {"w": jnp.zeros((DIN, DOUT))},
                        use_sampling=False)
    assert hist[0]["bytes_up"] == dense["bytes_up"]


def test_async_engine_reports_bytes(matrix_problem):
    """The async engine stamps the same byte accounting into history."""
    grad_fn, batch_fn, _ = matrix_problem
    fed = dataclasses.replace(_fed("lowrank+int8"), async_rounds=True,
                              max_staleness=0, prefetch_rounds=2)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=C)
    params0 = {"w": jnp.zeros((DIN, DOUT))}
    _, hist = sim.run(params0, 3)
    want = round_bytes(fed, params0)
    for h in hist:
        assert type(h["bytes_up"]) is int
        assert h["bytes_up"] == want["bytes_up"]
        assert h["bytes_down"] == want["bytes_down"]


# ---------------------------------------------------------------------------
# 27B dry-run lowering (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_lowers_fedlora_gemma27b_with_payload_bytes(tmp_path):
    """A fedlora round lowers for gemma3-27b on the 16x16 abstract mesh,
    and the dry-run record carries exact per-round payload bytes with the
    compressed uplink far below the dense downlink."""
    out_path = str(tmp_path / "dryrun.jsonl")
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "gemma3-27b", "--shape", "train_4k",
         "--algorithm", "fedlora", "--payload-codec", "lowrank+int8",
         "--lora-rank", "4", "--no-compile", "--out", out_path],
        capture_output=True, text=True, timeout=540, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    with open(out_path) as f:
        recs = [json.loads(ln) for ln in f if ln.strip()]
    assert recs and all(r["status"] in ("ok", "lowered") for r in recs), \
        out.stdout
    rec = recs[0]
    assert rec["payload_codec"] == "lowrank+int8"
    pb = rec["payload_bytes"]
    # uplink (rank-4 factors + quantized precision) vs dense fp32 downlink
    assert pb["bytes_up_per_client"] * 8 < pb["bytes_down_per_client"]
