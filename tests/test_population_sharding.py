"""Population sharding acceptance: sharded-vs-replicated bitwise parity
across the full (algorithm x placement x engine) matrix on 8 fake devices,
the 2-process ``jax.distributed`` train driver against a single-process
reference, and the 1M-client scaffold dry-run lowering. All subprocess
tests (device count locks at first jax import) in the nightly slow lane."""
import json
import os
import socket
import subprocess
import sys

import numpy as np
import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def _run(cmd, env=None, timeout=540):
    full_env = dict(os.environ, PYTHONPATH=SRC)
    full_env.pop("XLA_FLAGS", None)
    if env:
        full_env.update(env)
    return subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=full_env)


@pytest.mark.slow
def test_sharded_round_matrix_bitwise():
    """scaffold/fedep x {parallel, sequential, chunked} x {sync, async
    staleness=0}: population-sharded store == replicated store, bitwise
    (params + full store), with bounded per-device memory."""
    script = os.path.join(HERE, "_population_sharding_script.py")
    out = _run([sys.executable, script])
    assert out.returncode == 0, out.stderr[-4000:]
    markers = [ln for ln in out.stdout.splitlines()
               if ln.startswith("MARKER")]
    parity = [m for m in markers if m.startswith("MARKER parity")]
    assert len(parity) == 12, markers          # 2 algs x 3 placements x 2
    assert all(m.endswith("OK") for m in parity)
    assert sum(m.startswith("MARKER mem") for m in markers) == 2
    assert "MARKER all-ok" in markers


def _train_cmd(algorithm, extra):
    return [sys.executable, "-m", "repro.launch.train",
            "--arch", "fedlm-100m", "--smoke", "--rounds", "2",
            "--clients", "4", "--num-clients", "8",
            "--local-steps", "3", "--burn-in-steps", "2",
            "--steps-per-sample", "1", "--burn-in-rounds", "1",
            "--algorithm", algorithm, "--client-opt", "sgd",
            "--client-state-placement", "device",
            "--prefetch-rounds", "0", "--seed", "0",
            "--ckpt-every", "2"] + extra


def _free_port():
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["scaffold", "fedep"])
def test_two_process_train_matches_single_process(algorithm, tmp_path):
    """2 CPU processes under ``jax.distributed`` (gloo collectives), one
    device each, must reproduce a single-process run on the same 2-device
    ("data",) mesh bitwise: identical server checkpoint, and store shards
    that concatenate to the reference store. Exercises per-host cohort
    feeding, replicated-input lifting, and shard-local checkpointing."""
    port = _free_port()
    mh = str(tmp_path / "mh")
    dist = ["--coordinator", f"localhost:{port}", "--num-processes", "2"]
    procs = []
    for pid in (0, 1):
        cmd = _train_cmd(algorithm, dist + ["--process-id", str(pid),
                                            "--ckpt-dir", mh])
        full_env = dict(os.environ, PYTHONPATH=SRC)
        full_env.pop("XLA_FLAGS", None)
        procs.append(subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                      stderr=subprocess.STDOUT, text=True,
                                      env=full_env))
    outs = [p.communicate(timeout=540)[0] for p in procs]
    for p, o in zip(procs, outs):
        assert p.returncode == 0, o[-4000:]
    # process 0 logs rounds; process 1 stays silent
    assert '"round": 1' in outs[0] and '"round"' not in outs[1]

    ref = str(tmp_path / "ref")
    out = _run(_train_cmd(algorithm,
                          ["--shard-population", "--ckpt-dir", ref]),
               env={"XLA_FLAGS":
                    "--xla_force_host_platform_device_count=2"})
    assert out.returncode == 0, out.stderr[-4000:]

    a = np.load(os.path.join(mh, "ckpt_00000002.npz"))
    b = np.load(os.path.join(ref, "ckpt_00000002.npz"))
    assert set(a.files) == set(b.files)
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"server {k}")
    s0 = np.load(os.path.join(mh, "ckpt_00000002.shard0of2.npz"))
    s1 = np.load(os.path.join(mh, "ckpt_00000002.shard1of2.npz"))
    r = np.load(os.path.join(ref, "ckpt_00000002.shard0of1.npz"))
    for k in r.files:
        np.testing.assert_array_equal(
            np.concatenate([s0[k], s1[k]], axis=0), r[k],
            err_msg=f"store {k}")


@pytest.mark.slow
def test_dryrun_lowers_million_client_scaffold_store(tmp_path):
    """A 1M-client scaffold round lowers on the 16x16 abstract mesh with
    the store sharded over the 16-wide client axis (no OOM: lowering
    only, ``--no-compile``)."""
    out_path = str(tmp_path / "dryrun.jsonl")
    out = _run([sys.executable, "-m", "repro.launch.dryrun",
                "--arch", "xlstm-125m", "--shape", "train_4k",
                "--algorithm", "scaffold",
                "--client-state-placement", "device",
                "--num-clients", "1000000", "--no-compile",
                "--out", out_path])
    assert out.returncode == 0, out.stderr[-4000:]
    with open(out_path) as f:
        recs = [json.loads(ln) for ln in f if ln.strip()]
    assert recs and all(r["status"] in ("ok", "lowered") for r in recs), \
        out.stdout
    pop = recs[0]["store_population"]
    assert pop["num_clients"] == 1_000_000
    assert pop["padded_num_clients"] == 1_000_000   # 16 | 1M: no padding
    assert pop["shard_extent"] == 16
    assert pop["rows_per_device"] == 62_500
