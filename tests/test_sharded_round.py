"""Distribution tests: the sharded federated round executes with real
collectives on 8 fake devices (subprocess — device count is locked at jax
init, so it cannot run in the main test process)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_sharded_round_executes_on_8_devices():
    script = os.path.join(os.path.dirname(__file__),
                          "_sharded_round_script.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MARKER parallel" in out.stdout and "finite=True" in out.stdout
    assert "MARKER sequential" in out.stdout
    assert "moved=True" in out.stdout
    assert "all_reduce=True" in out.stdout
    assert "MARKER done" in out.stdout
    # both placements reported finite losses
    lines = [l for l in out.stdout.splitlines() if l.startswith("MARKER")]
    assert all("finite=True" in l for l in lines if "loss" in l), lines
