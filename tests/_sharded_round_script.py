"""Subprocess body for test_sharded_round: executes one federated round on
8 fake host devices with a (4 data x 2 model) mesh — real collectives, both
placements, parallel FedPA + sequential FSDP FedPA. Prints MARKER lines the
test asserts on."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import FedConfig
from repro.core.server import init_server_state
from repro.core.sharded_round import make_fed_round
from repro.models import init_params
from repro.optim import get_optimizer
from repro.sharding import (axis_rules, fsdp_shardings, make_mesh_compat,
                            param_shardings)

assert jax.device_count() == 8, jax.device_count()
mesh = make_mesh_compat((4, 2), ("data", "model"))

cfg = configs.get_smoke("fedlm-100m")
fed = FedConfig(algorithm="fedpa", clients_per_round=4, local_steps=4,
                burn_in_steps=2, steps_per_sample=1, shrinkage_rho=0.1,
                server_opt="sgdm", server_lr=0.5,
                client_opt="sgd", client_lr=0.05)

params = init_params(jax.random.PRNGKey(0), cfg)
server_opt = get_optimizer(fed.server_opt, fed.server_lr, fed.server_momentum)

C, K, B, S = 4, fed.local_steps, 2, 32
tokens = jax.random.randint(jax.random.PRNGKey(1), (C, K, B, S + 1), 0,
                            cfg.vocab_size)

# ---------------- parallel placement ----------------
state = init_server_state(params, server_opt)
p_sh = param_shardings(params, mesh)
opt_by_shape = {s.shape: sh for s, sh in zip(
    jax.tree_util.tree_leaves(jax.eval_shape(lambda: params)),
    jax.tree_util.tree_leaves(p_sh))}
opt_sh = jax.tree_util.tree_map(
    lambda l: opt_by_shape.get(l.shape, NamedSharding(mesh, P())),
    state.opt_state)
state_sh = type(state)(p_sh, opt_sh, NamedSharding(mesh, P()))
batch_sh = {"tokens": NamedSharding(mesh, P("data", None, None, None))}

round_fn = make_fed_round(cfg, fed, placement="parallel", spmd_axes="data",
                          q_chunk=16)
with axis_rules(mesh, {"batch": (), "clients": ("data",)}):
    jfn = jax.jit(round_fn, in_shardings=(state_sh, batch_sh),
                  out_shardings=(state_sh, None))
    new_state, metrics = jfn(state, {"tokens": tokens})
ll = float(metrics["loss_last"])
moved = float(sum(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
                  for a, b in zip(jax.tree_util.tree_leaves(new_state.params),
                                  jax.tree_util.tree_leaves(state.params))))
print(f"MARKER parallel loss={ll:.4f} finite={np.isfinite(ll)} moved={moved > 0}")

# ---------------- sequential (FSDP) placement ----------------
state = init_server_state(params, server_opt)
f_sh = fsdp_shardings(params, mesh)
opt_by_shape = {s.shape: sh for s, sh in zip(
    jax.tree_util.tree_leaves(jax.eval_shape(lambda: params)),
    jax.tree_util.tree_leaves(f_sh))}
opt_shf = jax.tree_util.tree_map(
    lambda l: opt_by_shape.get(l.shape, NamedSharding(mesh, P())),
    state.opt_state)
state_shf = type(state)(f_sh, opt_shf, NamedSharding(mesh, P()))
batch_shf = {"tokens": NamedSharding(mesh, P(None, None, "data", None))}
tokens_seq = jax.random.randint(jax.random.PRNGKey(2), (2, K, 4, S + 1), 0,
                                cfg.vocab_size)

round_fn_seq = make_fed_round(cfg, fed, placement="sequential", q_chunk=16)
with axis_rules(mesh):
    jfn2 = jax.jit(round_fn_seq, in_shardings=(state_shf, batch_shf),
                   out_shardings=(state_shf, None))
    new_state2, metrics2 = jfn2(state, {"tokens": tokens_seq})
ll2 = float(metrics2["loss_last"])
moved2 = float(sum(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
                   for a, b in zip(jax.tree_util.tree_leaves(new_state2.params),
                                   jax.tree_util.tree_leaves(state.params))))
print(f"MARKER sequential loss={ll2:.4f} finite={np.isfinite(ll2)} moved={moved2 > 0}")

# collective check: the compiled parallel round must contain exactly the
# cross-client reductions (all-reduce) and no surprise all-to-alls
txt = jfn.lower(state, {"tokens": tokens}).compile().as_text()
has_ar = "all-reduce" in txt
print(f"MARKER collectives all_reduce={has_ar}")
print("MARKER done")
