"""Async double-buffered engine: staleness=0 reproduces the sync round
engine numerically; staleness discounting, pipeline bookkeeping, history
serializability, and the host-side cohort prefetcher behave as
specified."""
import dataclasses
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import FedSim
from repro.core.async_engine import AsyncRoundEngine
from repro.data import make_federated_lsq
from repro.data.prefetch import Cohort, CohortPrefetcher
from repro.data.synthetic_lsq import lsq_batches

C, D, ROUNDS = 4, 3, 6

FEDS = {
    "fedavg": FedConfig(algorithm="fedavg", clients_per_round=C,
                        local_steps=12, server_opt="sgdm", server_lr=0.5,
                        client_opt="sgd", client_lr=0.01),
    "fedpa": FedConfig(algorithm="fedpa", clients_per_round=C,
                       local_steps=12, burn_in_steps=4, steps_per_sample=2,
                       shrinkage_rho=0.5, server_opt="sgd", server_lr=0.1,
                       client_opt="sgd", client_lr=0.01, burn_in_rounds=2),
    "fedpa_stream": FedConfig(algorithm="fedpa", streaming_dp=True,
                              clients_per_round=C, local_steps=12,
                              burn_in_steps=4, steps_per_sample=2,
                              shrinkage_rho=0.5, server_opt="sgd",
                              server_lr=0.1, client_opt="sgd",
                              client_lr=0.01),
}


@pytest.fixture(scope="module")
def problem():
    clients, data = make_federated_lsq(C, 50, D, heterogeneity=20.0, seed=0)

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r) * 50
        return jax.value_and_grad(loss)(params)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 10, steps, seed=r * 131 + cid)

    return grad_fn, batch_fn


def _run(fed, problem, **replace):
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(fed, **replace)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    return sim.run(jnp.zeros(D), ROUNDS)


@pytest.mark.parametrize("alg", list(FEDS))
@pytest.mark.parametrize("prefetch", [0, 2])
def test_staleness_zero_matches_sync(problem, alg, prefetch):
    """max_staleness=0 async == the fused synchronous round engine, for
    fedavg / fedpa (incl. burn-in rounds) / streaming fedpa, with and
    without the background cohort prefetcher."""
    want, _ = _run(FEDS[alg], problem)
    got, hist = _run(FEDS[alg], problem, async_rounds=True, max_staleness=0,
                     prefetch_rounds=prefetch)
    np.testing.assert_allclose(np.asarray(got.params),
                               np.asarray(want.params), rtol=1e-6, atol=1e-7)
    assert [h["staleness"] for h in hist] == [0] * ROUNDS


def test_staleness_ramp_and_history(problem):
    """Pipeline depth max_staleness+1: staleness ramps 0,1,..,s and stays;
    history carries loss_first/loss_last per applied round."""
    _, hist = _run(FEDS["fedavg"], problem, async_rounds=True,
                   max_staleness=2, prefetch_rounds=2)
    assert [h["staleness"] for h in hist] == [0, 1, 2, 2, 2, 2]
    for h in hist:
        assert np.isfinite(h["loss_first"]) and np.isfinite(h["loss_last"])


def test_staleness_discount_downweights_stale_deltas(problem):
    """discount=0 zeroes every stale delta: with an SGD server, params can
    only move on staleness-0 rounds (the first one)."""
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(FEDS["fedavg"], server_opt="sgd",
                              async_rounds=True, max_staleness=1,
                              staleness_discount=0.0)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    state, hist = sim.run(jnp.zeros(D), 4)
    assert [h["staleness"] for h in hist] == [0, 1, 1, 1]

    # reference: exactly one synchronous round from the same init
    sync = FedSim(fed=dataclasses.replace(FEDS["fedavg"], server_opt="sgd"),
                  grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    one, _ = sync.round(sync.init(jnp.zeros(D)), 0)
    np.testing.assert_allclose(np.asarray(state.params),
                               np.asarray(one.params), rtol=1e-6)


def test_history_eval_metrics_are_synced_and_json_serializable(problem):
    """eval_fn results used to be spliced into history as raw device
    arrays — breaking ``json.dumps(history)`` and hiding a blocking sync
    on first consumer access. They are now converted in the same single
    end-of-loop sync as the losses."""
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(FEDS["fedavg"], async_rounds=True,
                              max_staleness=1)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)

    def eval_fn(params):
        # jax scalar + jax vector, as a real eval_fn would return
        return {"eval_loss": jnp.sum(params * params),
                "param_head": params[:2]}

    _, hist = sim.run(jnp.zeros(D), 4, eval_fn=eval_fn, eval_every=2)
    json.dumps(hist)   # the regression: TypeError on jax.Array before
    for h in hist:
        for v in h.values():
            assert isinstance(v, (int, float, list)), (type(v), h)
    assert "eval_loss" in hist[0] and "eval_loss" not in hist[1]
    assert isinstance(hist[0]["eval_loss"], float)
    # non-scalar eval metrics come back as plain lists
    assert isinstance(hist[0]["param_head"], list)
    assert len(hist[0]["param_head"]) == 2


def test_sync_history_eval_metrics_are_synced_and_json_serializable(problem):
    """The synchronous ``FedSim.run`` loop had the same bug the async
    engine was cured of: ``eval_fn`` results spliced into history as raw
    device arrays, breaking ``json.dumps(history)``. Both paths now
    convert through the shared ``core.history.json_scalar``."""
    grad_fn, batch_fn = problem
    sim = FedSim(fed=FEDS["fedavg"], grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=C)

    def eval_fn(params):
        return {"eval_loss": jnp.sum(params * params),
                "param_head": params[:2]}

    _, hist = sim.run(jnp.zeros(D), 4, eval_fn=eval_fn, eval_every=2)
    json.dumps(hist)   # the regression: TypeError on jax.Array before
    for h in hist:
        for v in h.values():
            assert isinstance(v, (int, float, list)), (type(v), h)
    assert "eval_loss" in hist[0] and "eval_loss" not in hist[1]
    assert isinstance(hist[0]["eval_loss"], float)
    assert isinstance(hist[0]["param_head"], list)
    assert len(hist[0]["param_head"]) == 2


@pytest.mark.parametrize("async_mode", [False, True], ids=["sync", "async"])
@pytest.mark.parametrize("eval_every", [0, -1])
def test_run_rejects_nonpositive_eval_every(problem, async_mode, eval_every):
    """eval_every <= 0 used to surface as a bare ZeroDivisionError from
    ``t % eval_every`` deep in the round loop (after rounds already ran,
    in the async case); both engines now validate it eagerly, by name."""
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(FEDS["fedavg"], async_rounds=async_mode)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    with pytest.raises(ValueError, match="eval_every"):
        sim.run(jnp.zeros(D), 2, eval_fn=lambda p: {"e": 0.0},
                eval_every=eval_every)
    # with evaluation disabled, eval_every is unused and must not reject
    _, hist = sim.run(jnp.zeros(D), 1, eval_fn=None, eval_every=eval_every)
    assert len(hist) == 1


def test_engine_validates_knobs(problem):
    grad_fn, _ = problem
    with pytest.raises(ValueError):
        AsyncRoundEngine(cohort_fn=lambda *a: None, server_fn=lambda *a: None,
                         max_staleness=-1)
    with pytest.raises(ValueError):
        AsyncRoundEngine(cohort_fn=lambda *a: None, server_fn=lambda *a: None,
                         staleness_discount=1.5)
    with pytest.raises(ValueError):
        FedConfig(max_staleness=-1)
    with pytest.raises(ValueError):
        FedConfig(staleness_discount=-0.1)
    with pytest.raises(ValueError):
        FedConfig(prefetch_rounds=-1)


def test_prefetcher_preserves_order_and_contents():
    built = []

    def build(r):
        built.append(r)
        return Cohort(r, None, {"x": np.full((2,), r)}, None)

    with CohortPrefetcher(build, 0, 8, depth=3) as pf:
        for r in range(8):
            c = pf.get(r)
            assert c.round_idx == r
            np.testing.assert_array_equal(c.batches["x"], np.full((2,), r))
    assert built == list(range(8))


def test_prefetcher_propagates_builder_errors():
    def build(r):
        if r == 2:
            raise RuntimeError("boom at round 2")
        return Cohort(r, None, {}, None)

    with CohortPrefetcher(build, 0, 5, depth=2) as pf:
        pf.get(0)
        pf.get(1)
        with pytest.raises(RuntimeError, match="boom at round 2"):
            pf.get(2)


def test_prefetcher_close_is_prompt():
    """close() mid-stream neither deadlocks nor requires draining, actually
    stops the worker thread, and leaves no re-enqueued cohort behind (the
    old single drain-then-join raced a worker mid-put)."""
    pf = CohortPrefetcher(lambda r: Cohort(r, None, {}, None), 0, 1000,
                          depth=2)
    pf.get(0)
    pf.close()
    assert not pf._thread.is_alive()
    assert pf._q.empty()
    pf.close()  # idempotent


def test_prefetcher_close_raises_on_hung_builder():
    """A build_fn that never returns used to leave a silent zombie thread
    (the join timeout result was ignored); close() now raises, naming the
    likely culprit."""
    release = threading.Event()
    entered = threading.Event()

    def build(r):
        if r >= 1:
            entered.set()
            release.wait()          # hangs until the test releases it
        return Cohort(r, None, {}, None)

    pf = CohortPrefetcher(build, 0, 10, depth=1, close_timeout=0.5)
    assert pf.get(0).round_idx == 0
    assert entered.wait(timeout=5.0)   # worker is now stuck inside build(1)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="did not exit"):
        pf.close()
    assert time.monotonic() - t0 < 5.0
    release.set()                      # let the daemon thread die
    pf._thread.join(timeout=5.0)
    assert not pf._thread.is_alive()
    pf.close()                         # now a clean no-op
