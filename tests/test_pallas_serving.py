"""The Pallas-kernel serving path (use_pallas=True) must produce the same
logits as the pure-jnp decode path it is validated against."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import init_decode_state, init_params
from repro.models.model import decode_step


@pytest.mark.parametrize("arch", ["gemma3-27b", "qwen3-32b"])
def test_pallas_decode_matches_jnp(arch):
    cfg = configs.get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0,
                              cfg.vocab_size)
    s_ref = init_decode_state(cfg, B, max_len=128, cache_dtype=jnp.float32)
    s_pal = init_decode_state(cfg, B, max_len=128, cache_dtype=jnp.float32)
    for t in range(6):
        l_ref, s_ref = decode_step(params, toks[:, t], s_ref, cfg,
                                   compute_dtype=jnp.float32)
        l_pal, s_pal = decode_step(params, toks[:, t], s_pal, cfg,
                                   compute_dtype=jnp.float32,
                                   use_pallas=True)
        np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                                   rtol=2e-3, atol=2e-3)
