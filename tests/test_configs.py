"""Config registry + invariants the dry-run relies on."""
import pytest

from repro import configs
from repro.configs.base import (INPUT_SHAPES, MULTI_POD, SINGLE_POD,
                                FedConfig, LayerSpec)


def test_registry_complete():
    assert len(configs.ASSIGNED_ARCHS) == 10
    for a in configs.ASSIGNED_ARCHS:
        cfg = configs.get_config(a)
        assert cfg.name == a
        assert cfg.citation
    with pytest.raises(KeyError):
        configs.get_config("gpt-5")


def test_assigned_spec_numbers():
    """Each config matches its assigned (L, d_model, H, kv, vocab)."""
    want = {
        "xlstm-125m": (12, 768, 4, 4, 50304),
        "minitron-4b": (32, 3072, 24, 8, 256000),
        "musicgen-medium": (48, 1536, 24, 24, 2048),
        "internvl2-26b": (48, 6144, 48, 8, 92553),
        "recurrentgemma-9b": (38, 4096, 16, 1, 256000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 151936),
        "granite-34b": (88, 6144, 48, 1, 49152),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 202048),
        "gemma3-27b": (62, 5376, 32, 16, 262144),
        "qwen3-32b": (64, 5120, 64, 8, 151936),
    }
    for a, (L, d, h, kv, v) in want.items():
        c = configs.get_config(a)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.vocab_size) == (L, d, h, kv, v), a


def test_moe_specs():
    q = configs.get_config("qwen3-moe-30b-a3b")
    assert q.moe.num_experts == 128 and q.moe.top_k == 8
    l4 = configs.get_config("llama4-scout-17b-a16e")
    assert l4.moe.num_experts == 16 and l4.moe.top_k == 1


def test_vocab_padding_shards_16_ways():
    for a in configs.ALL_ARCHS:
        c = configs.get_config(a)
        assert c.padded_vocab % 128 == 0
        assert c.padded_vocab >= c.vocab_size
        assert c.padded_vocab - c.vocab_size < 128


def test_smoke_reduction_invariants():
    for a in configs.ALL_ARCHS:
        s = configs.get_smoke(a)
        f = configs.get_config(a)
        assert s.num_layers == 2
        assert s.d_model <= 512
        if s.moe.enabled:
            assert s.moe.num_experts <= 4
        # same family: smoke mixers are a subset of the full pattern's
        assert {sp.mixer for sp in s.layers()} <= {sp.mixer
                                                   for sp in f.layers()}
        assert s.arch_type == f.arch_type


def test_input_shapes():
    names = [s.name for s in INPUT_SHAPES]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert configs.SHAPES["long_500k"].seq_len == 524_288
    assert configs.SHAPES["train_4k"].global_batch == 256


def test_mesh_configs():
    assert SINGLE_POD.num_devices == 256 and SINGLE_POD.data_extent == 16
    assert MULTI_POD.num_devices == 512 and MULTI_POD.data_extent == 32
    assert MULTI_POD.model_extent == 16


def test_fed_config_validation():
    with pytest.raises(ValueError):
        FedConfig(algorithm="fedsgd")
    with pytest.raises(ValueError):
        FedConfig(algorithm="fedpa", local_steps=4, burn_in_steps=4,
                  steps_per_sample=2)
    f = FedConfig(algorithm="fedpa", local_steps=10, burn_in_steps=4,
                  steps_per_sample=2)
    assert f.num_samples == 3


def test_layer_spec_validation():
    with pytest.raises(ValueError):
        LayerSpec(mixer="swa", window=0)
    with pytest.raises(ValueError):
        LayerSpec(mixer="ssm2")


def test_long_decode_support_flags():
    long_ok = {a for a in configs.ASSIGNED_ARCHS
               if configs.get_config(a).supports_long_decode}
    assert long_ok == {"xlstm-125m", "recurrentgemma-9b", "gemma3-27b",
                       "llama4-scout-17b-a16e"}


def test_fed_config_round_validation():
    """The _validate_round checks added with fedlint FL005: every knob the
    engine reads is range/name-checked at construction time."""
    for bad in (dict(clients_per_round=0),
                dict(burn_in_rounds=-1),
                dict(shrinkage_rho=0.0),
                dict(shrinkage_rho=1.5),
                dict(server_lr=0.0),
                dict(client_lr=-0.1),
                dict(server_momentum=1.5),
                dict(client_momentum=-0.1),
                dict(server_opt="nadam"),
                dict(client_opt="lion"),
                dict(error_feedback=1),
                dict(algorithm="mime", mime_beta=1.5)):
        with pytest.raises(ValueError):
            FedConfig(**bad)
    # the boundary values are all valid
    FedConfig(clients_per_round=1, burn_in_rounds=0, shrinkage_rho=1.0,
              server_momentum=0.0, client_momentum=1.0)
    FedConfig(algorithm="mime", mime_beta=0.0)
    FedConfig(algorithm="mime", mime_beta=1.0)
