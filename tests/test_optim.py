"""Optimizer math vs closed forms; schedules."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.optim import apply_updates


def _step(opt, params, grads, n=1):
    state = opt.init(params)
    for _ in range(n):
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    return params, state


def test_sgd():
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -1.0])}
    out, _ = _step(optim.sgd(0.1), p, g)
    np.testing.assert_allclose(np.asarray(out["w"]), [0.95, 2.1], rtol=1e-6)


def test_sgdm_accumulates():
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    out, _ = _step(optim.sgdm(0.1, 0.9), p, g, n=3)
    # momentum: m1=1, m2=1.9, m3=2.71 -> sum = 5.61
    np.testing.assert_allclose(np.asarray(out["w"]), [-0.561], rtol=1e-5)


def test_adam_first_step_is_lr_sized():
    p = {"w": jnp.zeros(2)}
    g = {"w": jnp.asarray([1e-3, 1.0])}
    out, _ = _step(optim.adam(0.1, eps=0.0), p, g)
    # bias-corrected first step: -lr * g/|g|
    np.testing.assert_allclose(np.asarray(out["w"]), [-0.1, -0.1], rtol=1e-5)


def test_adagrad():
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.asarray([2.0])}
    out, _ = _step(optim.adagrad(0.1, eps=0.0), p, g)
    np.testing.assert_allclose(np.asarray(out["w"]), [-0.1], rtol=1e-6)


def test_yogi_moves_against_gradient():
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    out, _ = _step(optim.yogi(0.05), p, g, n=2)
    assert np.all(np.sign(np.asarray(out["w"])) == -np.sign(np.asarray(g["w"])))


def test_get_optimizer_registry():
    for name in ("sgd", "sgdm", "adam", "adagrad", "yogi"):
        assert optim.get_optimizer(name, 0.1) is not None
    with pytest.raises(KeyError):
        optim.get_optimizer("lion", 0.1)


def test_schedules():
    s = optim.inverse_time_decay(1.0, 1.0)
    assert float(s(jnp.asarray(0))) == 1.0
    assert float(s(jnp.asarray(9))) == pytest.approx(0.1)
    c = optim.cosine_decay(1.0, 100)
    assert float(c(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(c(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    w = optim.warmup_cosine(1.0, 10, 110)
    assert float(w(jnp.asarray(0))) == pytest.approx(0.1)
    assert float(w(jnp.asarray(9))) == pytest.approx(1.0)


def test_schedule_inside_optimizer():
    opt = optim.sgd(optim.inverse_time_decay(1.0, 1.0))
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    state = opt.init(p)
    u1, state = opt.update(g, state, p)
    u2, state = opt.update(g, state, p)
    assert abs(float(u2["w"][0])) == pytest.approx(abs(float(u1["w"][0])) / 2)
