"""Subprocess body for test_population_sharding: the full sharded-vs-
replicated parity matrix on 8 fake host devices.

For scaffold and fedep, across {parallel, sequential, chunked} x {sync,
async staleness=0}, a FedSim whose DeviceClientStateStore shards the
population over the 8-device ("data",) mesh must reproduce the unsharded
device-store run BITWISE — server params and the full store (stamps +
every buffer row). The population (10) deliberately does not divide the
mesh (8): the padded rows must stay dead. Prints MARKER lines the test
asserts on, plus the per-device memory ratio of the sharded store.
"""
import dataclasses
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import FedSim
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.launch.mesh import make_host_mesh

assert jax.device_count() == 8, jax.device_count()
mesh = make_host_mesh()

C, D, N, ROUNDS = 4, 3, 10, 4

SCAFFOLD = FedConfig(algorithm="scaffold", clients_per_round=C,
                     local_steps=6, server_opt="sgd", server_lr=0.1,
                     client_opt="sgd", client_lr=0.01,
                     client_state_placement="device")
FEDEP = FedConfig(algorithm="fedep", clients_per_round=C, local_steps=6,
                  burn_in_steps=4, steps_per_sample=2, shrinkage_rho=0.5,
                  burn_in_rounds=2, fedep_damping=0.7, server_opt="sgd",
                  server_lr=0.1, client_opt="sgd", client_lr=0.01,
                  client_state_placement="device")

clients, data = make_federated_lsq(N, 50, D, heterogeneity=20.0, seed=0)


def grad_fn(params, batch):
    def loss(p):
        r = batch["x"] @ p - batch["y"]
        return 0.5 * jnp.mean(r * r) * 50
    return jax.value_and_grad(loss)(params)


def batch_fn(cid, r, steps):
    X, y = data[cid]
    return lsq_batches(X, y, 10, steps, seed=r * 131 + cid)


def run(fed, use_mesh):
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=N, mesh=mesh if use_mesh else None)
    state, _ = sim.run(jnp.zeros(D), ROUNDS)
    store = jax.tree_util.tree_map(np.asarray,
                                   sim.client_store.state_dict())
    return np.asarray(state.params), store, sim.client_store


def mem_ratio(store):
    """max per-device sharded bytes / single-device replicated bytes."""
    dev = store.device_state()
    per_dev = {}
    total = 0
    for leaf in jax.tree_util.tree_leaves(dev):
        total += leaf.nbytes
        for s in leaf.addressable_shards:
            per_dev[s.device] = per_dev.get(s.device, 0) + s.data.nbytes
    return max(per_dev.values()) / total


for alg_name, base in (("scaffold", SCAFFOLD), ("fedep", FEDEP)):
    for placement, chunk in (("parallel", 0), ("sequential", 0),
                             ("chunked", 3)):
        for mode in ("sync", "async0"):
            fed = dataclasses.replace(
                base, round_placement=placement, round_chunk_size=chunk,
                **(dict(async_rounds=True, max_staleness=0,
                        prefetch_rounds=2) if mode == "async0" else {}))
            want_p, want_s, _ = run(fed, use_mesh=False)
            got_p, got_s, sharded = run(fed, use_mesh=True)
            np.testing.assert_array_equal(got_p, want_p)
            jax.tree_util.tree_map(np.testing.assert_array_equal,
                                   got_s, want_s)
            lay = sharded.layout
            assert lay.extent == 8 and lay.padded_num_clients == 16, lay
            # dead padding rows: stamps live only for real clients
            stamps = np.asarray(sharded.device_state()["stamps"])
            assert (stamps[N:] == -1).all(), stamps
            print(f"MARKER parity {alg_name} {placement} {mode} OK",
                  flush=True)
    # per-device memory: <= (1/8 + padding) of the replicated footprint
    _, _, sharded = run(dataclasses.replace(base,
                                            round_placement="parallel"),
                        use_mesh=True)
    ratio = mem_ratio(sharded)
    bound = (1.0 / 8) * (16 / N)     # even shards of the padded buffers
    assert ratio <= bound + 1e-9, (ratio, bound)
    print(f"MARKER mem {alg_name} ratio={ratio:.4f} bound={bound:.4f} OK",
          flush=True)

print("MARKER all-ok", flush=True)
