"""Forward (train/prefill) vs decode equivalence — the strongest model
correctness property: the chunkwise/scan forward implementations and the
single-token recurrent/cached decode paths must produce identical logits on
the same token stream."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, prefill)

# archs chosen to cover every mixer/ffn kind; frontend archs are covered via
# the prefill test path of plain attention (their decoders are identical).
ARCHS = ["xlstm-125m", "recurrentgemma-9b", "gemma3-27b", "qwen3-32b",
         "qwen3-moe-30b-a3b", "llama4-scout-17b-a16e"]

S = 48
B = 2


def _cfg(arch):
    cfg = configs.get_smoke(arch)
    if cfg.moe.enabled:
        # generous capacity so no tokens drop: forward chunks and decode
        # chunks would otherwise drop different tokens (documented behaviour)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_equals_decode_chain(arch):
    cfg = _cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits_fwd, _ = forward(params, tokens, cfg,
                            compute_dtype=jnp.float32, q_chunk=16,
                            remat="none")
    state = init_decode_state(cfg, B, max_len=S + 8, cache_dtype=jnp.float32)
    step = jax.jit(lambda p, t, s: decode_step(p, t, s, cfg,
                                               compute_dtype=jnp.float32))
    outs = []
    for t in range(S):
        lg, state = step(params, tokens[:, t], state)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_fwd), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("arch", ["gemma3-27b", "qwen3-32b", "xlstm-125m",
                                  "recurrentgemma-9b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                                cfg.vocab_size)
    # ground truth: forward over S+1 tokens, logits at position S-1 and S
    logits_fwd, _ = forward(params, tokens, cfg, compute_dtype=jnp.float32,
                            q_chunk=16, remat="none")
    lp, state = prefill(params, tokens[:, :S], cfg, max_len=S + 8,
                        compute_dtype=jnp.float32, q_chunk=16,
                        cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_fwd[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
    ld, state = decode_step(params, tokens[:, S], state,
                            cfg, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(logits_fwd[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_old_tokens():
    """A swa layer must ignore tokens beyond the window: changing a token
    older than the window leaves later logits unchanged."""
    cfg = _cfg("gemma3-27b")  # pattern = (swa(32), attn) — take swa only
    cfg = dataclasses.replace(cfg, pattern=(cfg.pattern[0],), repeats=1,
                              tail=())
    w = cfg.pattern[0].window
    assert w == 32
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0,
                                cfg.vocab_size)
    tokens2 = tokens.at[0, 0].set((tokens[0, 0] + 1) % cfg.vocab_size)
    l1, _ = forward(params, tokens, cfg, compute_dtype=jnp.float32,
                    q_chunk=16, remat="none")
    l2, _ = forward(params, tokens2, cfg, compute_dtype=jnp.float32,
                    q_chunk=16, remat="none")
    # positions >= w + something can't see token 0
    np.testing.assert_allclose(np.asarray(l1[:, w + 1:]),
                               np.asarray(l2[:, w + 1:]), atol=1e-5)
    assert float(jnp.abs(l1[:, 1] - l2[:, 1]).max()) > 1e-4


def test_causality():
    """Changing a future token never changes past logits (all mixers)."""
    for arch in ("xlstm-125m", "recurrentgemma-9b", "qwen3-32b"):
        cfg = _cfg(arch)
        params = init_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(4), (1, S), 0,
                                    cfg.vocab_size)
        tokens2 = tokens.at[0, -1].set((tokens[0, -1] + 1) % cfg.vocab_size)
        l1, _ = forward(params, tokens, cfg, compute_dtype=jnp.float32,
                        q_chunk=16, remat="none")
        l2, _ = forward(params, tokens2, cfg, compute_dtype=jnp.float32,
                        q_chunk=16, remat="none")
        np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                                   np.asarray(l2[:, :-1]), atol=1e-5,
                                   err_msg=arch)


def test_mlstm_chunk_size_invariance():
    """The chunkwise mLSTM recurrence must be exact: different chunk sizes
    give identical outputs."""
    from repro.models.xlstm import init_mlstm_params, mlstm_forward
    cfg = configs.get_smoke("xlstm-125m")
    p = init_mlstm_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 64, cfg.d_model))
    y8, _ = mlstm_forward(p, x, cfg, chunk=8)
    y64, _ = mlstm_forward(p, x, cfg, chunk=64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), rtol=1e-4,
                               atol=1e-4)
