"""Per-client persistent state: the host ClientStateStore and the
device-resident DeviceClientStateStore (lazy init, gather/scatter, overlap
CAS semantics, duplicate-id rejection), the stateful round programs in
both placements, the async engine's tagged write-back, host-vs-device
bitwise parity across placements and engines, and the ServerState + store
checkpoint round-trip (bitwise-identical continuation, cross-placement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import get_algorithm
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import FedConfig
from repro.core import FedSim, make_round_program
from jax.sharding import PartitionSpec

from repro.core.client_state import (BaseClientStateStore, ClientStateStore,
                                     DeviceClientStateStore,
                                     make_client_store, population_layout)
from repro.core.server import init_server_state
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import get_optimizer

C, D = 4, 3

BOTH_STORES = pytest.mark.parametrize(
    "store_cls", [ClientStateStore, DeviceClientStateStore],
    ids=["host", "device"])

SCAFFOLD = FedConfig(algorithm="scaffold", clients_per_round=C,
                     local_steps=12, server_opt="sgd", server_lr=0.1,
                     client_opt="sgd", client_lr=0.01)
FEDEP = FedConfig(algorithm="fedep", clients_per_round=C, local_steps=12,
                  burn_in_steps=4, steps_per_sample=2, shrinkage_rho=0.5,
                  burn_in_rounds=2, fedep_damping=0.7, server_opt="sgd",
                  server_lr=0.1, client_opt="sgd", client_lr=0.01)


@pytest.fixture(scope="module")
def problem():
    clients, data = make_federated_lsq(C, 50, D, heterogeneity=20.0, seed=0)

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r) * 50
        return jax.value_and_grad(loss)(params)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 10, steps, seed=r * 131 + cid)

    return grad_fn, batch_fn


# ---------------------------------------------------------------------------
# Store unit behavior
# ---------------------------------------------------------------------------

@BOTH_STORES
def test_store_lazy_init_gather_scatter(store_cls):
    store = store_cls(6)
    assert not store.initialized
    with pytest.raises(RuntimeError, match="uninitialized"):
        store.gather([0])
    template = {"c": jnp.zeros(2), "n": jnp.zeros((), jnp.int32)}
    store.ensure(template)
    store.ensure(template)  # idempotent
    assert store.initialized

    states, stamps = store.gather([1, 4])
    np.testing.assert_array_equal(states["c"], np.zeros((2, 2)))
    np.testing.assert_array_equal(stamps, [0, 0])

    upd = {"c": np.asarray([[1.0, 2.0], [3.0, 4.0]]),
           "n": np.asarray([7, 8], np.int32)}
    assert store.scatter([1, 4], upd, stamps) == 0
    got, stamps2 = store.gather([4, 1])
    np.testing.assert_array_equal(got["c"], [[3.0, 4.0], [1.0, 2.0]])
    np.testing.assert_array_equal(got["n"], [8, 7])
    np.testing.assert_array_equal(stamps2, [1, 1])
    # untouched clients stay zero
    np.testing.assert_array_equal(store.gather([0])[0]["c"], np.zeros((1, 2)))


@BOTH_STORES
def test_store_overlap_write_is_dropped_not_clobbered(store_cls):
    """Two cohorts gather the same client before either writes: the write
    applied second (based on the pre-first-write state) is dropped, so the
    first applied update is never lost — identical CAS semantics in the
    host store (numpy) and the device store (on-device stamps)."""
    store = store_cls(3).ensure(jnp.zeros(1))
    _, stamps_a = store.gather([0, 1])
    _, stamps_b = store.gather([0, 2])          # overlaps client 0

    assert store.scatter([0, 1], np.asarray([[1.0], [1.0]]), stamps_a) == 0
    # cohort B gathered before A wrote: its client-0 write must be dropped
    assert store.scatter([0, 2], np.asarray([[9.0], [2.0]]), stamps_b) == 1
    states, _ = store.gather([0, 1, 2])
    np.testing.assert_array_equal(np.ravel(states), [1.0, 1.0, 2.0])

    # a gather AFTER A's write sees the new stamp and may overwrite
    _, stamps_c = store.gather([0])
    assert store.scatter([0], np.asarray([[5.0]]), stamps_c) == 0
    np.testing.assert_array_equal(np.ravel(store.gather([0])[0]), [5.0])


@BOTH_STORES
def test_store_reset_and_unconditional_scatter(store_cls):
    store = store_cls(2).ensure(jnp.zeros(1))
    store.scatter([0], np.asarray([[3.0]]))      # stamps=None: always write
    np.testing.assert_array_equal(np.ravel(store.gather([0])[0]), [3.0])
    store.reset()
    states, stamps = store.gather([0, 1])
    np.testing.assert_array_equal(states, np.zeros((2, 1)))
    np.testing.assert_array_equal(stamps, [0, 0])


@BOTH_STORES
def test_store_scatter_rejects_duplicate_client_ids(store_cls):
    """Duplicate ids in one scatter are ill-defined (numpy's buffered fancy
    indexing and XLA's scatter both silently pick one winner and the stamp
    bumps once) — the stores must refuse them loudly, with and without CAS
    stamps."""
    store = store_cls(4).ensure(jnp.zeros(1))
    upd = np.asarray([[1.0], [2.0], [3.0]])
    with pytest.raises(ValueError, match="duplicate client ids"):
        store.scatter([1, 2, 1], upd)
    _, stamps = store.gather([1, 2, 1])
    with pytest.raises(ValueError, match="duplicate client ids"):
        store.scatter([1, 2, 1], upd, stamps)
    # the failed scatters must not have written or bumped anything
    states, stamps = store.gather([1, 2])
    np.testing.assert_array_equal(states, np.zeros((2, 1)))
    np.testing.assert_array_equal(stamps, [0, 0])
    # unique ids still work
    assert store.scatter([1, 2], upd[:2]) == 0


def test_device_store_prepare_ids_validates():
    store = DeviceClientStateStore(4).ensure(jnp.zeros(1))
    with pytest.raises(ValueError, match="duplicate client ids"):
        store.prepare_ids([0, 0, 1])
    with pytest.raises(ValueError, match="out of range"):
        store.prepare_ids([0, 4])
    # gather must reject out-of-range ids too (XLA would silently clamp
    # buffers[ids] to the last client where numpy raises IndexError)
    with pytest.raises(ValueError, match="out of range"):
        store.gather([4])
    ids = store.prepare_ids([2, 0])
    assert ids.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(ids), [2, 0])


def test_persistent_state_is_fp32_even_for_bf16_configs():
    """Control variates / EP sites are running statistics updated every
    participation: re-rounding them to bf16 per round would drop
    corrections below one ulp (the same per-fold re-rounding the fp32
    accumulator contract forbids). Only shipped payloads get the wire
    dtype."""
    params = jnp.zeros(4, jnp.bfloat16)
    for fed in (SCAFFOLD, FEDEP):
        alg = get_algorithm(dataclasses.replace(fed,
                                                delta_dtype="bfloat16"))
        for leaf in jax.tree_util.tree_leaves(alg.init_client_state(params)):
            assert leaf.dtype == jnp.float32, fed.algorithm
        for leaf in jax.tree_util.tree_leaves(alg.init_algo_state(params)):
            assert leaf.dtype == jnp.float32, fed.algorithm


@BOTH_STORES
def test_store_load_rejects_wrong_population(store_cls):
    store = store_cls(2).ensure(jnp.zeros(1))
    other = store_cls(3).ensure(jnp.zeros(1))
    with pytest.raises(ValueError, match="population"):
        store.load_state_dict(other.state_dict())


def test_make_client_store_resolves_placement():
    assert isinstance(make_client_store("host", 2), ClientStateStore)
    assert isinstance(make_client_store("device", 2), DeviceClientStateStore)
    with pytest.raises(ValueError, match="client_state_placement"):
        make_client_store("tpu", 2)
    with pytest.raises(ValueError, match="client_state_placement"):
        FedConfig(client_state_placement="tpu")


# ---------------------------------------------------------------------------
# Engine plumbing
# ---------------------------------------------------------------------------

def test_stateful_round_requires_client_states(problem):
    grad_fn, _ = problem
    round_fn = make_round_program(grad_fn, SCAFFOLD)
    opt = get_optimizer("sgd", 0.1)
    state = init_server_state(jnp.zeros(D), opt,
                              algorithm=get_algorithm(SCAFFOLD))
    batches = {"x": jnp.zeros((C, 12, 10, D)), "y": jnp.zeros((C, 12, 10))}
    with pytest.raises(ValueError, match="stateful"):
        round_fn(state, batches)


@pytest.mark.parametrize("store_place", ["host", "device"])
@pytest.mark.parametrize("fed", [SCAFFOLD, FEDEP], ids=["scaffold", "fedep"])
def test_state_persists_across_rounds_and_resets_on_init(fed, store_place,
                                                         problem):
    """Round t+1's clients see the state round t wrote (the store is not
    zero after a round), and FedSim.init starts every run from zeros."""
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(fed, client_state_placement=store_place)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    state = sim.init(jnp.zeros(D))
    for r in range(3):
        state, _ = sim.round(state, r)
    buffers = jax.tree_util.tree_leaves(sim.client_store.state_dict())
    assert any(np.abs(b).sum() > 0 for b in buffers)
    sim.init(jnp.zeros(D))
    assert all(np.abs(b).sum() == 0
               for b in jax.tree_util.tree_leaves(
                   sim.client_store.state_dict()))


@pytest.mark.parametrize("store_place", ["host", "device"])
def test_async_overlapping_cohorts_do_not_lose_applied_updates(store_place,
                                                               problem):
    """Full participation + max_staleness=1: every odd round's cohort
    gathered before the previous round's write landed, so its C stale
    writes are dropped (surfaced as ``state_drops``) instead of clobbering
    the applied state; even rounds gather fresh and write cleanly. The
    device store reproduces the pattern with its CAS running against the
    on-device stamps (drops synced once, at end of loop)."""
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(SCAFFOLD, async_rounds=True, max_staleness=1,
                              client_state_placement=store_place)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    _, hist = sim.run(jnp.zeros(D), 6)
    assert [h["staleness"] for h in hist] == [0, 1, 1, 1, 1, 1]
    assert [h["state_drops"] for h in hist] == [0, C, 0, C, 0, C]
    assert all(isinstance(h["state_drops"], int) for h in hist)


# ---------------------------------------------------------------------------
# Host store vs device store: bitwise parity across placements and engines
# ---------------------------------------------------------------------------

def _store_dict_np(store):
    return jax.tree_util.tree_map(np.asarray, store.state_dict())


@pytest.mark.parametrize("mode", ["sync", "async"])
@pytest.mark.parametrize("placement,chunk",
                         [("parallel", None), ("sequential", None),
                          ("chunked", 3)])  # 3 !| 4: pads
@pytest.mark.parametrize("fed", [SCAFFOLD, FEDEP], ids=["scaffold", "fedep"])
def test_host_vs_device_store_bitwise_parity(fed, placement, chunk, mode,
                                             problem):
    """The device store's in-jit gather/CAS-scatter is pure data movement:
    server params AND the full per-client state buffers must match the
    host store BITWISE after multi-round runs (incl. fedep's stateless
    burn rounds), for every placement, sync and async (staleness=0)."""
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(
        fed, round_placement=placement,
        round_chunk_size=chunk if chunk is not None else 0,
        **(dict(async_rounds=True, max_staleness=0, prefetch_rounds=2)
           if mode == "async" else {}))
    host = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                  num_clients=C)
    dev = FedSim(fed=dataclasses.replace(fed,
                                         client_state_placement="device"),
                 grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    want, _ = host.run(jnp.zeros(D), 4)
    got, _ = dev.run(jnp.zeros(D), 4)
    np.testing.assert_array_equal(np.asarray(got.params),
                                  np.asarray(want.params))
    jax.tree_util.tree_map(
        np.testing.assert_array_equal,
        _store_dict_np(dev.client_store), _store_dict_np(host.client_store))


# ---------------------------------------------------------------------------
# Checkpoint round-trip: save, reload, continue — bitwise identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fed", [SCAFFOLD, FEDEP], ids=["scaffold", "fedep"])
def test_checkpoint_roundtrip_bitwise_continuation(fed, problem, tmp_path):
    """ServerState (incl. scaffold's algo_state control variate) + the
    ClientStateStore survive a save/reload and the next round is bitwise
    identical to the uninterrupted run."""
    grad_fn, batch_fn = problem
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    state = sim.init(jnp.zeros(D))
    for r in range(3):
        state, _ = sim.round(state, r)
    save_checkpoint(str(tmp_path),
                    {"server": state,
                     "clients": sim.client_store.state_dict()}, 3,
                    {"algorithm": fed.algorithm})

    # uninterrupted reference: one more round
    ref_state, _ = sim.round(state, 3)
    ref_store = jax.tree_util.tree_map(
        np.copy, sim.client_store.state_dict())

    # cold start: fresh FedSim, restore, continue
    sim2 = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                  num_clients=C)
    st2 = sim2.init(jnp.zeros(D))
    restored, step, meta = restore_checkpoint(
        str(tmp_path),
        {"server": st2, "clients": sim2.client_store.state_dict()})
    assert step == 3 and meta["algorithm"] == fed.algorithm
    sim2.client_store.load_state_dict(restored["clients"])
    got_state, _ = sim2.round(restored["server"], 3)

    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        (got_state.params, got_state.algo_state,
         sim2.client_store.state_dict()),
        (ref_state.params, ref_state.algo_state, ref_store))
    assert int(got_state.round) == int(ref_state.round)


@pytest.mark.parametrize("restore_place", ["host", "device"])
@pytest.mark.parametrize("fed", [SCAFFOLD, FEDEP], ids=["scaffold", "fedep"])
def test_device_store_checkpoint_restores_into_either_placement(
        fed, restore_place, problem, tmp_path):
    """A ``{"server", "clients"}`` checkpoint written from DEVICE buffers
    (``state_dict()`` is the one device->host pull) restores into either
    placement and the next round is bitwise identical to the uninterrupted
    device-store run — the store placement is a runtime knob, not a
    checkpoint format."""
    grad_fn, batch_fn = problem
    fed_dev = dataclasses.replace(fed, client_state_placement="device")
    sim = FedSim(fed=fed_dev, grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=C)
    state = sim.init(jnp.zeros(D))
    for r in range(3):
        state, _ = sim.round(state, r)
    save_checkpoint(str(tmp_path),
                    {"server": state,
                     "clients": sim.client_store.state_dict()}, 3,
                    {"algorithm": fed.algorithm})

    # uninterrupted reference: one more device-store round
    ref_state, _ = sim.round(state, 3)
    ref_store = _store_dict_np(sim.client_store)

    sim2 = FedSim(fed=dataclasses.replace(
                      fed, client_state_placement=restore_place),
                  grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    st2 = sim2.init(jnp.zeros(D))
    restored, step, meta = restore_checkpoint(
        str(tmp_path),
        {"server": st2, "clients": sim2.client_store.state_dict()})
    assert step == 3 and meta["algorithm"] == fed.algorithm
    sim2.client_store.load_state_dict(restored["clients"])
    got_state, _ = sim2.round(restored["server"], 3)

    np.testing.assert_array_equal(np.asarray(got_state.params),
                                  np.asarray(ref_state.params))
    jax.tree_util.tree_map(np.testing.assert_array_equal,
                           _store_dict_np(sim2.client_store), ref_store)
    assert int(got_state.round) == int(ref_state.round)


# ---------------------------------------------------------------------------
# Population layout arithmetic + store ABC dispatch
# ---------------------------------------------------------------------------

class _FakeMesh:
    """Duck-typed mesh: population_layout only reads shape/axis_names."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_population_layout_pads_to_axis_extent():
    mesh = _FakeMesh({"data": 8, "model": 2})
    lay = population_layout(mesh, 10)
    assert (lay.extent, lay.padded_num_clients, lay.padding) == (8, 16, 6)
    assert lay.spec == PartitionSpec("data")
    # divisible populations pad nothing
    assert population_layout(mesh, 16).padding == 0
    # "model" never carries clients
    assert population_layout(_FakeMesh({"model": 4}), 10).extent == 1


def test_population_layout_multi_axis_and_identity():
    mesh = _FakeMesh({"pod": 2, "data": 4, "model": 2})
    lay = population_layout(mesh, 9)
    assert (lay.extent, lay.padded_num_clients) == (8, 16)
    assert lay.spec == PartitionSpec(("pod", "data"))
    none = population_layout(None, 9)
    assert (none.extent, none.padded_num_clients) == (1, 9)
    assert none.spec == PartitionSpec()


def test_population_layout_validates():
    with pytest.raises(ValueError, match="not in mesh"):
        population_layout(_FakeMesh({"data": 4}), 8,
                          population_spec=PartitionSpec("tensor"))
    with pytest.raises(ValueError, match="num_clients"):
        population_layout(_FakeMesh({"data": 4}), 0)


def test_make_client_store_dispatches_on_abc():
    for placement, cls in (("host", ClientStateStore),
                           ("device", DeviceClientStateStore)):
        store = make_client_store(placement, C)
        assert isinstance(store, cls)
        assert isinstance(store, BaseClientStateStore)
    with pytest.raises(ValueError, match="unknown client_state_placement"):
        make_client_store("gpu", C)
    # a mesh makes no sense for the host store: loud, not silently ignored
    with pytest.raises(ValueError, match="shard"):
        make_client_store("host", C, mesh=_FakeMesh({"data": 4}))


def test_store_registry_rejects_non_store_classes():
    from repro.core.client_state import STORES
    STORES["bogus"] = dict
    try:
        with pytest.raises(TypeError):
            make_client_store("bogus", C)
    finally:
        del STORES["bogus"]


def test_base_store_subclass_inherits_ensure_contract():
    class _Recording(BaseClientStateStore):
        def _allocate(self, template):
            return jax.tree_util.tree_map(
                lambda x: np.zeros((self.num_clients,) + np.shape(x)), template)

        def reset(self):
            self._buffers = None

        def gather(self, client_ids):
            raise NotImplementedError

        def scatter(self, *a, **k):
            raise NotImplementedError

        def state_dict(self):
            raise NotImplementedError

        def load_state_dict(self, state):
            raise NotImplementedError

    s = _Recording(3)
    assert not s.initialized
    s.ensure({"v": np.ones(2)})
    assert s.initialized and s._buffers["v"].shape == (3, 2)
    with pytest.raises(ValueError):
        _Recording(0)
