"""Round-engine parity: the compiled one-jit round (parallel / sequential /
chunked placements) reproduces the legacy per-client-loop round — same
losses, same server params — for fedavg, fedpa, and mime, including
weighted aggregation and chunk padding; and, for every registered
algorithm, an eager per-client reference built from the FedAlgorithm hooks
plus the async ``max_staleness=0`` path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import get_algorithm
from repro.configs.base import FedConfig
from repro.core import FedSim, make_round_program
from repro.core.client import make_client_update
from repro.core.server import (aggregate_deltas, aggregate_deltas_list,
                               init_server_state, normalized_weights,
                               server_update, weighted_sum)
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import get_optimizer

C, D, STEPS = 4, 3, 12

FEDS = {
    "fedavg": FedConfig(algorithm="fedavg", clients_per_round=C,
                        local_steps=STEPS, server_opt="sgdm", server_lr=0.5,
                        client_opt="sgd", client_lr=0.01),
    "fedpa": FedConfig(algorithm="fedpa", clients_per_round=C,
                       local_steps=STEPS, burn_in_steps=4,
                       steps_per_sample=2, shrinkage_rho=0.5,
                       server_opt="sgd", server_lr=0.1,
                       client_opt="sgd", client_lr=0.01),
    "mime": FedConfig(algorithm="mime", clients_per_round=C,
                      local_steps=STEPS, server_opt="sgdm", server_lr=0.5,
                      client_opt="sgd", client_lr=0.01, mime_beta=0.5),
    # streaming (any-time) DP client: same posterior math, no sample buffer
    "fedpa_stream": FedConfig(algorithm="fedpa", streaming_dp=True,
                              clients_per_round=C, local_steps=STEPS,
                              burn_in_steps=4, steps_per_sample=2,
                              shrinkage_rho=0.5, server_opt="sgd",
                              server_lr=0.1, client_opt="sgd",
                              client_lr=0.01),
    # delta-payload algorithm registered after the refactor
    "fedprox": FedConfig(algorithm="fedprox", fedprox_mu=0.5,
                         clients_per_round=C, local_steps=STEPS,
                         server_opt="sgdm", server_lr=0.5,
                         client_opt="sgd", client_lr=0.01),
}

# every registered algorithm, incl. the non-delta-payload one and the two
# stateful ones; FEDS stays the delta-payload subset the pre-refactor
# legacy loop can reproduce
ALL_FEDS = {
    **FEDS,
    "fedpa_precision": FedConfig(algorithm="fedpa_precision",
                                 clients_per_round=C, local_steps=STEPS,
                                 burn_in_steps=4, steps_per_sample=2,
                                 shrinkage_rho=0.5, burn_in_rounds=2,
                                 server_opt="sgd", server_lr=0.1,
                                 client_opt="sgd", client_lr=0.01),
    # stateful: per-client persistent state threaded through every placement
    "scaffold": FedConfig(algorithm="scaffold", clients_per_round=C,
                          local_steps=STEPS, server_opt="sgdm",
                          server_lr=0.5, client_opt="sgd", client_lr=0.01),
    "fedep": FedConfig(algorithm="fedep", clients_per_round=C,
                       local_steps=STEPS, burn_in_steps=4,
                       steps_per_sample=2, shrinkage_rho=0.5,
                       burn_in_rounds=2, fedep_damping=0.7,
                       server_opt="sgd", server_lr=0.1,
                       client_opt="sgd", client_lr=0.01),
    # compressed payloads: 1-D test params make lowrank a passthrough, so
    # this exercises the quantizer + error-feedback state + finish_cohort
    # decode across every placement and the async engine
    "fedlora": FedConfig(algorithm="fedlora",
                         payload_codec="lowrank+int8", lora_rank=2,
                         clients_per_round=C, local_steps=STEPS,
                         burn_in_steps=4, steps_per_sample=2,
                         shrinkage_rho=0.5, burn_in_rounds=2,
                         server_opt="sgd", server_lr=0.1,
                         client_opt="sgd", client_lr=0.01),
}


def _stacked_init_states(fed, params):
    """The cohort's gathered client-state slice for a fresh store (zeros)."""
    alg = get_algorithm(fed)
    one = alg.init_client_state(params)
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *([one] * C))


@pytest.fixture(scope="module")
def problem():
    clients, data = make_federated_lsq(C, 50, D, heterogeneity=20.0, seed=0)

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r) * 50
        return jax.value_and_grad(loss)(params)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 10, steps, seed=r * 131 + cid)

    return grad_fn, batch_fn


def _stack(batch_fn, round_idx, steps):
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[batch_fn(cid, round_idx, steps) for cid in range(C)])


def _legacy_round(fed, grad_fn, batch_fn, state, round_idx, weights=None):
    """The pre-engine FedSim.round: per-client jitted dispatch + eager
    list aggregation + eager server update."""
    client_opt = get_optimizer(fed.client_opt, fed.client_lr,
                               fed.client_momentum)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    update = jax.jit(make_client_update(grad_fn, fed, client_opt))
    extra = ()
    if fed.algorithm == "mime":
        opt = state.opt_state
        extra = (opt["m"] if isinstance(opt, dict) and "m" in opt
                 else jax.tree_util.tree_map(jnp.zeros_like, state.params),)
    deltas, losses = [], []
    for cid in range(C):
        res = update(state.params,
                     batch_fn(cid, round_idx, fed.local_steps), *extra)
        deltas.append(res.payload)
        losses.append(float(res.metrics["loss_last"]))
    mean_delta = aggregate_deltas_list(
        deltas, None if weights is None else list(weights))
    return server_update(state, mean_delta, server_opt), float(np.mean(losses))


@pytest.mark.parametrize("alg", list(FEDS))
@pytest.mark.parametrize("placement,chunk", [("parallel", None),
                                             ("sequential", None),
                                             ("chunked", 2),
                                             ("chunked", 3)])  # 3 !| 4: pads
def test_engine_matches_legacy_loop(problem, alg, placement, chunk):
    grad_fn, batch_fn = problem
    fed = FEDS[alg]
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state0 = init_server_state(jnp.zeros(D), server_opt)
    want, want_loss = _legacy_round(fed, grad_fn, batch_fn, state0, 0)

    round_fn = jax.jit(make_round_program(grad_fn, fed, placement=placement,
                                          chunk_size=chunk,
                                          server_opt=server_opt))
    got, metrics = round_fn(state0, _stack(batch_fn, 0, fed.local_steps))
    np.testing.assert_allclose(np.asarray(got.params),
                               np.asarray(want.params), rtol=1e-5, atol=1e-6)
    assert float(metrics["loss_last"]) == pytest.approx(want_loss, rel=1e-5)
    assert int(got.round) == 1


@pytest.mark.parametrize("placement", ["parallel", "chunked"])
def test_weighted_aggregation_matches_legacy(problem, placement):
    grad_fn, batch_fn = problem
    fed = FEDS["fedavg"]
    weights = np.asarray([1.0, 2.0, 3.0, 4.0], np.float32)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state0 = init_server_state(jnp.zeros(D), server_opt)
    want, _ = _legacy_round(fed, grad_fn, batch_fn, state0, 0, weights)
    round_fn = jax.jit(make_round_program(grad_fn, fed, placement=placement,
                                          chunk_size=3,
                                          server_opt=server_opt))
    got, _ = round_fn(state0, _stack(batch_fn, 0, fed.local_steps), weights)
    np.testing.assert_allclose(np.asarray(got.params),
                               np.asarray(want.params), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("alg", ["fedavg", "fedpa"])
def test_fedsim_multi_round_matches_legacy(problem, alg):
    """Five FedSim rounds (incl. a FedPA burn-in round) == five legacy
    rounds on the same sampled cohorts."""
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(FEDS[alg],
                              **({"burn_in_rounds": 2} if alg == "fedpa"
                                 else {}))
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C)
    state = sim.init(jnp.zeros(D))
    ref = sim.init(jnp.zeros(D))
    for r in range(5):
        # legacy runs the burn-in regime the same way FedSim does
        eff = fed
        if alg == "fedpa" and r < fed.burn_in_rounds:
            eff = dataclasses.replace(fed, algorithm="fedavg")
        cohort_batch_fn = (
            lambda i, ri, steps: batch_fn(int(sim.sampler.sample(ri)[i]),
                                          ri, steps))
        ref, _ = _legacy_round(eff, grad_fn, cohort_batch_fn, ref, r)
        state, _ = sim.round(state, r)
    np.testing.assert_allclose(np.asarray(state.params),
                               np.asarray(ref.params), rtol=1e-5, atol=1e-6)


def test_placements_agree_pairwise(problem):
    """parallel == sequential == chunked on identical inputs (fedpa)."""
    grad_fn, batch_fn = problem
    fed = FEDS["fedpa"]
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state0 = init_server_state(jnp.zeros(D), server_opt)
    batches = _stack(batch_fn, 3, fed.local_steps)
    outs = {}
    for place in ("parallel", "sequential", "chunked"):
        rf = jax.jit(make_round_program(grad_fn, fed, placement=place,
                                        server_opt=server_opt))
        outs[place] = rf(state0, batches)[0].params
    for place in ("sequential", "chunked"):
        np.testing.assert_allclose(np.asarray(outs["parallel"]),
                                   np.asarray(outs[place]),
                                   rtol=1e-5, atol=1e-7)


def _eager_round(fed, grad_fn, batch_fn, state, round_idx, weights=None):
    """Eager per-client reference built from the FedAlgorithm hooks: one
    jitted client dispatch per client, stacked payloads, eager aggregation
    and server step — the strategy-API analogue of ``_legacy_round`` that
    also covers non-delta payloads (fedpa_precision) and per-client state
    (scaffold/fedep: each client gets its zero initial state and the
    returned state updates are stacked for comparison)."""
    alg = get_algorithm(fed)
    client_opt = get_optimizer(fed.client_opt, fed.client_lr,
                               fed.client_momentum)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    update = jax.jit(alg.make_client_update(grad_fn, client_opt))
    extras = alg.broadcast(state, server_opt)
    cstate0 = alg.init_client_state(state.params)
    payloads, losses, new_states = [], [], []
    for cid in range(C):
        res = update(state.params, batch_fn(cid, round_idx, fed.local_steps),
                     *((cstate0,) if alg.stateful else ()), *extras)
        payloads.append(res.payload)
        losses.append(float(res.metrics["loss_last"]))
        new_states.append(res.state_update)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *payloads)
    w = normalized_weights(
        None if weights is None else np.asarray(weights, np.float32), C)
    agg = alg.reduce_stacked(stacked, w)
    agg = alg.finish_cohort(state, agg)
    states = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_states)
              if alg.stateful else None)
    return (alg.server_update(state, agg, server_opt), float(np.mean(losses)),
            states)


@pytest.mark.parametrize("alg_name", list(ALL_FEDS))
@pytest.mark.parametrize("placement,chunk", [("parallel", None),
                                             ("sequential", None),
                                             ("chunked", 3)])  # 3 !| 4: pads
def test_engine_matches_eager_hooks_all_registered(problem, alg_name,
                                                   placement, chunk):
    """Every registered algorithm x every placement == the eager per-client
    reference assembled from the same FedAlgorithm hooks — incl. the
    stacked per-client state updates of the stateful algorithms."""
    grad_fn, batch_fn = problem
    fed = ALL_FEDS[alg_name]
    alg = get_algorithm(fed)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state0 = init_server_state(jnp.zeros(D), server_opt, algorithm=alg)
    want, want_loss, want_states = _eager_round(fed, grad_fn, batch_fn,
                                                state0, 0)

    round_fn = jax.jit(make_round_program(grad_fn, fed, placement=placement,
                                          chunk_size=chunk,
                                          server_opt=server_opt))
    batches = _stack(batch_fn, 0, fed.local_steps)
    if alg.stateful:
        got, metrics, got_states = round_fn(
            state0, batches, None, _stacked_init_states(fed, state0.params))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
            got_states, want_states)
    else:
        got, metrics = round_fn(state0, batches)
    np.testing.assert_allclose(np.asarray(got.params),
                               np.asarray(want.params), rtol=1e-5, atol=1e-6)
    assert float(metrics["loss_last"]) == pytest.approx(want_loss, rel=1e-5)


@pytest.mark.parametrize("alg_name", list(ALL_FEDS))
def test_async_staleness_zero_matches_sync_all_registered(problem, alg_name):
    """max_staleness=0 async == the fused synchronous engine for every
    registered algorithm (incl. fedpa_precision's dict aggregate and its
    fedavg burn-in rounds through the split burn server stage)."""
    grad_fn, batch_fn = problem
    fed = ALL_FEDS[alg_name]
    sync = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                  num_clients=C)
    want, _ = sync.run(jnp.zeros(D), 4)
    fed_async = dataclasses.replace(fed, async_rounds=True, max_staleness=0,
                                    prefetch_rounds=2)
    sim = FedSim(fed=fed_async, grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=C)
    got, hist = sim.run(jnp.zeros(D), 4)
    np.testing.assert_allclose(np.asarray(got.params),
                               np.asarray(want.params), rtol=1e-6, atol=1e-7)
    assert [h["staleness"] for h in hist] == [0] * 4


def test_fedconfig_round_knobs_validated():
    with pytest.raises(ValueError):
        FedConfig(round_placement="warp")
    with pytest.raises(ValueError):
        FedConfig(round_chunk_size=-1)


def test_fedconfig_rejects_ragged_iasg_windows():
    """(local_steps - burn_in_steps) % steps_per_sample != 0 used to surface
    as an opaque 'need N batches, got M' ValueError at trace time inside the
    jitted round; FedConfig now rejects it eagerly, naming the knobs."""
    with pytest.raises(ValueError,
                       match="local_steps.*steps_per_sample"):
        FedConfig(algorithm="fedpa", local_steps=9, burn_in_steps=4,
                  steps_per_sample=2)
    # whole windows are fine, and non-fedpa algorithms don't care
    assert FedConfig(algorithm="fedpa", local_steps=10, burn_in_steps=4,
                     steps_per_sample=2).num_samples == 3
    FedConfig(algorithm="fedavg", local_steps=9, burn_in_steps=4,
              steps_per_sample=2)


def test_fedsim_history_surfaces_first_and_last_losses(problem):
    """loss_first vs loss_last is the only signal separating burn-in-round
    progress from sampling-round progress; FedSim must surface both."""
    grad_fn, batch_fn = problem
    sim = FedSim(fed=FEDS["fedavg"], grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=C)
    _, hist = sim.run(jnp.zeros(D), 2)
    for h in hist:
        assert {"loss_first", "loss_last", "client_loss"} <= set(h)
        assert h["client_loss"] == h["loss_last"]
        # local SGD makes progress within a round on this problem
        assert h["loss_last"] < h["loss_first"]


def test_zero_weight_cohort_fails_loudly(problem):
    """An all-zero (or negative-sum) weight vector used to silently divide
    by zero and poison the server params with NaN rounds later."""
    grad_fn, batch_fn = problem
    fed = FEDS["fedavg"]
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state0 = init_server_state(jnp.zeros(D), server_opt)
    batches = _stack(batch_fn, 0, fed.local_steps)
    round_fn = make_round_program(grad_fn, fed, server_opt=server_opt)

    # host-side (eager weights): raise before any NaN can be produced
    for bad in (np.zeros((C,), np.float32),
                np.asarray([1.0, -1.0, 0.0, 0.0], np.float32)):
        with pytest.raises(ValueError, match="positive total"):
            round_fn(state0, batches, bad)
        with pytest.raises(ValueError, match="positive total"):
            aggregate_deltas_list([jnp.ones(D)] * C, list(bad))

    # FedSim path: per-client weights gathered for the cohort
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=C,
                 client_weights=np.zeros((C,), np.float32))
    with pytest.raises(ValueError, match="positive total"):
        sim.run(jnp.zeros(D), 1)

    # traced weights (inside jit): degrade to a zero delta, never NaN
    jitted = jax.jit(round_fn)
    got, _ = jitted(state0, batches, jnp.zeros((C,), jnp.float32))
    assert np.all(np.isfinite(np.asarray(got.params)))
    np.testing.assert_allclose(np.asarray(got.params),
                               np.asarray(state0.params))


def test_bf16_weighted_aggregation_parity_with_fp32_reference():
    """Normalized weights must stay fp32 through the reduction: casting
    them to bf16 first (the old behavior) loses ~2 decimal digits of
    realistic example-count weights. With cancellation (701/1000 * 1 +
    299/1000 * -2 = 0.103) the old path lands ~3 bf16 ulps off; the fixed
    path is the correctly-rounded fp32 result."""
    counts = np.asarray([701.0, 299.0], np.float32)
    w = jnp.asarray(counts / counts.sum(), jnp.float32)
    deltas = {"w": jnp.stack([jnp.full((9,), 1.0),
                              jnp.full((9,), -2.0)]).astype(jnp.bfloat16)}

    got = weighted_sum(deltas, w)
    assert got["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got["w"], np.float32), 0.103,
                               rtol=2**-8)  # half a bf16 ulp

    agg = aggregate_deltas(deltas, jnp.asarray(counts))
    assert agg["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(agg["w"], np.float32), 0.103,
                               rtol=2**-8)

    # and fp32 aggregation is untouched by the fix
    d32 = {"w": jnp.asarray(np.asarray(deltas["w"], np.float32))}
    np.testing.assert_allclose(np.asarray(weighted_sum(d32, w)["w"]), 0.103,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Accumulator dtype contract: fp32 regardless of delta_dtype
# ---------------------------------------------------------------------------

def test_accumulator_is_fp32_for_every_registered_algorithm():
    """``init_accum`` used to zero the accumulator in ``delta_dtype``, so
    bf16 configs folded the sequential/chunked placements in bf16 —
    re-rounding on every client fold. The accumulator space is fp32 for
    every algorithm; ``finalize`` owns the single cast back."""
    from repro.algorithms import algorithm_names
    params = jnp.zeros(5, jnp.bfloat16)
    for name in algorithm_names():
        fed = ALL_FEDS.get(name)
        if fed is None:   # out-of-package test algorithms etc.
            continue
        alg = get_algorithm(dataclasses.replace(fed,
                                                delta_dtype="bfloat16"))
        acc = alg.init_accum(params)
        for leaf in jax.tree_util.tree_leaves(acc):
            assert leaf.dtype == jnp.float32, (name, leaf.dtype)
        # finalize casts the fp32 accumulator once, to the delta dtype
        for leaf in jax.tree_util.tree_leaves(alg.finalize(acc)):
            assert leaf.dtype == jnp.bfloat16, (name, leaf.dtype)


@pytest.mark.parametrize("alg_name", ["fedavg", "fedpa_precision"])
def test_bf16_sequential_and_chunked_match_stacked_fp32_path(problem,
                                                             alg_name):
    """delta_dtype=bf16: the sequential and chunked placements must match
    the parallel (stacked, fp32-reduced) path to fp32-accumulation
    tolerance — one terminal bf16 rounding, not one per folded client."""
    grad_fn, batch_fn = problem
    fed = dataclasses.replace(ALL_FEDS[alg_name], delta_dtype="bfloat16")
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state0 = init_server_state(jnp.zeros(D), server_opt)
    batches = _stack(batch_fn, 1, fed.local_steps)
    weights = np.asarray([701.0, 299.0, 1303.0, 97.0], np.float32)
    outs = {}
    for place, chunk in (("parallel", None), ("sequential", None),
                         ("chunked", 3)):
        rf = jax.jit(make_round_program(grad_fn, fed, placement=place,
                                        chunk_size=chunk,
                                        server_opt=server_opt))
        outs[place] = np.asarray(rf(state0, batches, weights)[0].params,
                                 np.float32)
    for place in ("sequential", "chunked"):
        # within ~1 bf16 ulp of the stacked path (fp32 reduction-order only)
        np.testing.assert_allclose(outs[place], outs["parallel"],
                                   rtol=2**-8, atol=1e-6)
