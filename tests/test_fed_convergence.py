"""The paper's central empirical claims, on exactly solvable problems:

1. FedAvg with many local steps stagnates at a biased fixed point.
2. FedPA's fixed point approaches the global optimum as samples grow, so
   more local computation HELPS FedPA and HURTS FedAvg (Fig. 1 / Fig. 3).
3. The full IASG-based FedPA pipeline (Algorithm 1+3+4) beats the FedAvg
   fixed point on a heterogeneous federated least-squares problem.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import (FedSim, aggregate_deltas_list, dp_delta,
                        fedavg_fixed_point, global_posterior_mode)
from repro.core.server import init_server_state, server_update
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import sgd


@pytest.fixture(scope="module")
def problem():
    clients, data = make_federated_lsq(2, 50, 2, heterogeneity=40.0, seed=3)
    mu = np.asarray(global_posterior_mode(clients))
    return clients, data, mu


def _exact_gaussian_samples(c, ell, rng):
    cov = np.linalg.inv(np.asarray(c.sigma_inv, np.float64))
    L = np.linalg.cholesky(cov)
    z = rng.standard_normal((ell, cov.shape[0]))
    return jnp.asarray(np.asarray(c.mu)[None] + z @ L.T, jnp.float32)


def _run_fedpa_exact(clients, mu, ell, rounds=300, lr=0.02, rho=1.0, seed=0):
    rng = np.random.default_rng(seed)
    opt = sgd(lr)
    st = init_server_state(jnp.zeros(2), opt)
    dp = jax.jit(lambda x0, xs: dp_delta(x0, xs, rho))
    for _ in range(rounds):
        deltas = [dp(st.params, _exact_gaussian_samples(c, ell, rng))
                  for c in clients]
        st = server_update(st, aggregate_deltas_list(deltas), opt)
    return float(np.linalg.norm(np.asarray(st.params) - mu))


def test_more_samples_help_fedpa(problem):
    """Fig. 1 right: 10 -> 100 samples moves FedPA closer to the optimum."""
    clients, _, mu = problem
    d10 = _run_fedpa_exact(clients, mu, ell=10)
    d100 = _run_fedpa_exact(clients, mu, ell=100)
    fedavg_bias = float(np.linalg.norm(
        np.asarray(fedavg_fixed_point(clients, 300, 0.005)) - mu))
    assert d100 < d10, (d10, d100)
    assert d100 < fedavg_bias, (d100, fedavg_bias)


def test_more_local_steps_hurt_fedavg(problem):
    """Fig. 1 middle / Fig. 3a: FedAvg's fixed-point bias grows with K."""
    clients, _, mu = problem
    dist = [float(np.linalg.norm(
        np.asarray(fedavg_fixed_point(clients, k, 0.005)) - mu))
        for k in (1, 10, 100)]
    assert dist[0] < 1e-4
    assert dist[2] > dist[1] > dist[0]


def _grad_fn(n):
    def fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r) * n
        return jax.value_and_grad(loss)(params)
    return fn


def test_full_iasg_fedpa_beats_fedavg_fixed_point(problem):
    """End-to-end Algorithm 1 + IASG + shrinkage-DP on the federated LSQ."""
    clients, data, mu = problem

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 25, steps, seed=r * 131 + cid)

    fed = FedConfig(algorithm="fedpa", clients_per_round=2, local_steps=300,
                    burn_in_steps=100, steps_per_sample=20,
                    shrinkage_rho=1.0, server_opt="sgd", server_lr=0.05,
                    client_opt="sgd", client_lr=0.005)
    sim = FedSim(fed=fed, grad_fn=_grad_fn(50), batch_fn=batch_fn,
                 num_clients=2)
    st, _ = sim.run(jnp.zeros(2), 100)
    d_pa = float(np.linalg.norm(np.asarray(st.params) - mu))
    d_avg = float(np.linalg.norm(
        np.asarray(fedavg_fixed_point(clients, 300, 0.005)) - mu))
    assert d_pa < d_avg, (d_pa, d_avg)


def test_burn_in_rounds_run_fedavg_regime(problem):
    """During burn-in rounds FedPA must be algorithmically identical to
    FedAvg (Section 5.2)."""
    clients, data, mu = problem

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 25, steps, seed=r * 131 + cid)

    base = dict(clients_per_round=2, local_steps=60, server_opt="sgd",
                server_lr=0.5, client_opt="sgd", client_lr=0.005)
    fed_pa = FedConfig(algorithm="fedpa", burn_in_steps=20,
                       steps_per_sample=20, burn_in_rounds=5, **base)
    fed_avg = FedConfig(algorithm="fedavg", **base)
    sims = [FedSim(fed=f, grad_fn=_grad_fn(50), batch_fn=batch_fn,
                   num_clients=2) for f in (fed_pa, fed_avg)]
    states = [s.run(jnp.zeros(2), 5)[0] for s in sims]
    np.testing.assert_allclose(np.asarray(states[0].params),
                               np.asarray(states[1].params), rtol=1e-5)
