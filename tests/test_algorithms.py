"""The FedAlgorithm strategy API: registry, config validation, the
Optimizer.momentum accessor, out-of-package registration, the two new
registered algorithms' convergence, and the precision-weighted per-parameter
staleness discount."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import (ClientResult, FedAlgorithm, algorithm_names,
                              get_algorithm, phase_name, register_algorithm)
from repro.configs.base import FedConfig
from repro.core import FedSim, global_posterior_mode
from repro.core.iasg import sgd_steps
from repro.core.server import init_server_state
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import adagrad, adam, get_optimizer, sgd, sgdm, yogi


# ---------------------------------------------------------------------------
# Registry + validation
# ---------------------------------------------------------------------------

def test_builtin_algorithms_registered():
    assert {"fedavg", "fedpa", "mime", "fedprox", "fedpa_precision",
            "scaffold", "fedep"} <= set(algorithm_names())


def test_unknown_algorithm_rejected_with_registry_names():
    with pytest.raises(ValueError, match="fedavg.*fedpa"):
        FedConfig(algorithm="fedsgd")


def test_duplicate_registration_rejected():
    """A name collision would silently swap the round math of every config
    using it; re-registering must raise unless override=True is explicit."""
    with pytest.raises(ValueError, match="already registered"):
        @register_algorithm("fedavg")
        class ShadowFedAvg(FedAlgorithm):
            """Would shadow the built-in fedavg."""

    @register_algorithm("fedavg", override=True)
    class SameFedAvg(get_algorithm(FedConfig(algorithm="fedavg")).__class__):
        """Explicit override is allowed (restore the built-in below)."""

    from repro.algorithms import FedAvg
    register_algorithm("fedavg", override=True)(FedAvg)
    assert get_algorithm(FedConfig(algorithm="fedavg")).__class__ is FedAvg


@pytest.mark.parametrize("alg", ["fedavg", "mime", "fedprox",
                                 "fedpa_precision"])
def test_streaming_dp_rejected_outside_fedpa(alg):
    """streaming_dp=True used to be silently ignored for fedavg/mime; it
    must now fail eagerly at config construction for every non-fedpa
    algorithm."""
    kw = ({"burn_in_steps": 4, "steps_per_sample": 2}
          if alg == "fedpa_precision" else {})
    with pytest.raises(ValueError, match="streaming_dp"):
        FedConfig(algorithm=alg, streaming_dp=True, **kw)
    # and fedpa itself still accepts it
    FedConfig(algorithm="fedpa", streaming_dp=True)


def test_fedprox_mu_validated():
    with pytest.raises(ValueError, match="fedprox_mu"):
        FedConfig(algorithm="fedprox", fedprox_mu=-0.1)
    FedConfig(algorithm="fedprox", fedprox_mu=0.0)  # 0 == fedavg, fine


def test_scaffold_knobs_validated():
    """Option II's closed form assumes vanilla SGD local steps, and the
    server control-variate scale is a |S|/N fraction."""
    with pytest.raises(ValueError, match="client_opt"):
        FedConfig(algorithm="scaffold")              # default sgdm clients
    with pytest.raises(ValueError, match="scaffold_c_scale"):
        FedConfig(algorithm="scaffold", client_opt="sgd",
                  scaffold_c_scale=0.0)
    FedConfig(algorithm="scaffold", client_opt="sgd", scaffold_c_scale=0.25)


def test_fedep_damping_validated():
    kw = dict(burn_in_steps=4, steps_per_sample=2)
    with pytest.raises(ValueError, match="fedep_damping"):
        FedConfig(algorithm="fedep", fedep_damping=0.0, **kw)
    with pytest.raises(ValueError, match="fedep_damping"):
        FedConfig(algorithm="fedep", fedep_damping=1.5, **kw)
    # and it inherits FedPA's whole-window checks
    with pytest.raises(ValueError, match="steps_per_sample"):
        FedConfig(algorithm="fedep", local_steps=9, **kw)


def test_fedpa_single_window_boundary_constructs():
    """local_steps == burn_in_steps + steps_per_sample is exactly one IASG
    window (l = 1) and must construct; the < case names the >= bound."""
    f = FedConfig(algorithm="fedpa", local_steps=6, burn_in_steps=4,
                  steps_per_sample=2)
    assert f.num_samples == 1
    with pytest.raises(ValueError, match=">="):
        FedConfig(algorithm="fedpa", local_steps=5, burn_in_steps=4,
                  steps_per_sample=2)


def test_fedpa_precision_inherits_fedpa_window_checks():
    with pytest.raises(ValueError, match="steps_per_sample"):
        FedConfig(algorithm="fedpa_precision", local_steps=9,
                  burn_in_steps=4, steps_per_sample=2)
    f = FedConfig(algorithm="fedpa_precision", local_steps=10,
                  burn_in_steps=4, steps_per_sample=2)
    assert f.num_samples == 3


def test_phase_name_helper():
    fed = FedConfig(algorithm="fedpa", burn_in_rounds=3)
    assert phase_name(fed, 0) == "fedavg (burn-in)"
    assert phase_name(fed, 3) == "fedpa"
    # algorithms without a burn regime never display a burn-in phase
    fed = FedConfig(algorithm="fedavg", burn_in_rounds=3)
    assert phase_name(fed, 0) == "fedavg"


# ---------------------------------------------------------------------------
# Optimizer.momentum accessor (replaces the opt_state["m"] dict probe)
# ---------------------------------------------------------------------------

def test_optimizer_momentum_accessor():
    params = {"w": jnp.ones(3), "b": jnp.zeros(2)}
    grads = {"w": jnp.full(3, 2.0), "b": jnp.ones(2)}
    for make in (sgdm(0.1, 0.9), adam(0.1), yogi(0.1)):
        state = make.init(params)
        np.testing.assert_array_equal(
            np.asarray(make.momentum(state, params)["w"]), np.zeros(3))
        _, state = make.update(grads, state, params)
        m = make.momentum(state, params)
        assert float(np.abs(np.asarray(m["w"])).sum()) > 0
    for make in (sgd(0.1), adagrad(0.1)):
        state = make.init(params)
        _, state = make.update(grads, state, params)
        m = make.momentum(state, params)
        np.testing.assert_array_equal(np.asarray(m["w"]), np.zeros(3))
        np.testing.assert_array_equal(np.asarray(m["b"]), np.zeros(2))


# ---------------------------------------------------------------------------
# Shared toy problem
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    clients, data = make_federated_lsq(2, 50, 2, heterogeneity=40.0, seed=3)
    mu = np.asarray(global_posterior_mode(clients))

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r) * 50
        return jax.value_and_grad(loss)(params)

    def batch_fn(cid, r, steps):
        X, y = data[cid]
        return lsq_batches(X, y, 25, steps, seed=r * 131 + cid)

    return grad_fn, batch_fn, mu


def _dist(fed, problem, rounds=80):
    grad_fn, batch_fn, mu = problem
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=2)
    st, _ = sim.run(jnp.zeros(2), rounds)
    return float(np.linalg.norm(np.asarray(st.params) - mu))


# ---------------------------------------------------------------------------
# The API pays for itself: the two new algorithms beat fedavg
# ---------------------------------------------------------------------------

def test_new_algorithms_converge_at_least_as_fast_as_fedavg(problem):
    """fedprox and fedpa_precision on the heterogeneous synthetic
    least-squares benchmark: no worse than fedavg after the same round
    budget (both in fact land measurably closer to the global posterior
    mode in this regime)."""
    base = dict(clients_per_round=2, local_steps=60, server_opt="sgd",
                server_lr=0.1, client_opt="sgd", client_lr=0.005)
    d_avg = _dist(FedConfig(algorithm="fedavg", **base), problem)
    d_prox = _dist(FedConfig(algorithm="fedprox", fedprox_mu=3.0, **base),
                   problem)
    d_prec = _dist(FedConfig(algorithm="fedpa_precision", burn_in_steps=20,
                             steps_per_sample=10, shrinkage_rho=1.0,
                             burn_in_rounds=5, **base), problem)
    assert d_prox < d_avg, (d_prox, d_avg)
    assert d_prec < d_avg, (d_prec, d_avg)


def test_stateful_algorithms_beat_fedavg(problem):
    """The per-client-state subsystem pays for itself: SCAFFOLD's control
    variates cancel the client-drift bias outright, and FedEP's damped
    persistent sites land closer to the global posterior mode than fedavg
    on the same heterogeneous least-squares round budget."""
    base = dict(clients_per_round=2, local_steps=60, server_opt="sgd",
                server_lr=0.1, client_opt="sgd", client_lr=0.005)
    d_avg = _dist(FedConfig(algorithm="fedavg", **base), problem)
    d_scaf = _dist(FedConfig(algorithm="scaffold", **base), problem)
    d_ep = _dist(FedConfig(algorithm="fedep", burn_in_steps=20,
                           steps_per_sample=10, shrinkage_rho=1.0,
                           burn_in_rounds=5, fedep_damping=0.5, **base),
                 problem)
    assert d_scaf < d_avg, (d_scaf, d_avg)
    assert d_ep < d_avg, (d_ep, d_avg)
    # drift correction is the stronger mechanism on this bias-dominated
    # problem: scaffold should in fact roughly close the gap
    assert d_scaf < 0.5 * d_avg, (d_scaf, d_avg)


# ---------------------------------------------------------------------------
# Precision-weighted aggregation + per-parameter staleness discount
# ---------------------------------------------------------------------------

def test_precision_weighted_aggregation_favors_confident_clients():
    fed = FedConfig(algorithm="fedpa_precision", burn_in_steps=4,
                    steps_per_sample=2)
    alg = get_algorithm(fed)
    # two clients, opposite deltas; client 0 is 9x more confident
    stacked = {"delta": jnp.asarray([[1.0, 1.0], [-1.0, -1.0]]),
               "prec": jnp.asarray([[9.0, 1.0], [1.0, 1.0]])}
    w = jnp.full((2,), 0.5, jnp.float32)
    pseudo = alg.aggregate(stacked, w)
    # coord 0: (9 - 1)/(9 + 1) = 0.8; coord 1: equal precision -> mean = 0
    np.testing.assert_allclose(np.asarray(pseudo), [0.8, 0.0],
                               rtol=1e-5, atol=1e-6)


def test_precision_staleness_discount_is_per_parameter():
    """The scalar staleness discount bends per parameter: sharply-determined
    coordinates (high aggregated precision) forget stale updates faster;
    discount=1.0 stays a no-op."""
    fed = FedConfig(algorithm="fedpa_precision", burn_in_steps=4,
                    steps_per_sample=2, server_opt="sgd", server_lr=1.0)
    alg = get_algorithm(fed)
    server_opt = get_optimizer("sgd", 1.0)
    state = init_server_state(jnp.zeros(3), server_opt)
    agg = {"num": jnp.asarray([0.1, 1.0, 10.0]),
           "den": jnp.asarray([0.1, 1.0, 10.0])}  # pseudo-grad = 1 each

    full = alg.server_update(state, agg, server_opt)
    np.testing.assert_allclose(np.asarray(full.params), [-1.0, -1.0, -1.0],
                               rtol=1e-5)
    same = alg.server_update(state, agg, server_opt, discount=1.0)
    np.testing.assert_array_equal(np.asarray(same.params),
                                  np.asarray(full.params))

    stale = alg.server_update(state, agg, server_opt, discount=0.5)
    step = -np.asarray(stale.params)  # sgd lr=1: params = -discounted grad
    assert step[0] > step[1] > step[2]          # more precision, more discount
    assert np.all(step > 0) and np.all(step < 1)
    # exponents are the clipped precision/mean ratios
    rel = np.clip(np.asarray(agg["den"]) / np.mean(np.asarray(agg["den"])),
                  0.25, 4.0)
    np.testing.assert_allclose(step, 0.5 ** rel, rtol=1e-5)


# ---------------------------------------------------------------------------
# Out-of-package registration: no repro-internal edits required
# ---------------------------------------------------------------------------

@register_algorithm("toy_halfavg")
class ToyHalfAvg(FedAlgorithm):
    """FedAvg whose clients ship half the delta (a lr-halved pseudo-grad)."""

    def make_client_update(self, grad_fn, client_opt):
        """K local SGD steps; payload = (theta_0 - theta_K) / 2."""

        def update(params, batches):
            opt_state = client_opt.init(params)
            final, _, losses = sgd_steps(params, client_opt, opt_state,
                                         grad_fn, batches)
            delta = jax.tree_util.tree_map(
                lambda a, b: 0.5 * (a - b), params, final)
            return ClientResult(delta, {"loss_first": losses[0],
                                        "loss_last": losses[-1]})

        return update


def test_external_algorithm_runs_end_to_end(problem):
    """A FedAlgorithm registered from OUTSIDE the repro package (this test
    module) drives config validation, the compiled round engine, and FedSim
    with no repro-internal edits."""
    grad_fn, batch_fn, _ = problem
    assert "toy_halfavg" in algorithm_names()
    fed = FedConfig(algorithm="toy_halfavg", clients_per_round=2,
                    local_steps=12, server_opt="sgd", server_lr=1.0,
                    client_opt="sgd", client_lr=0.005)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn, num_clients=2)
    st, hist = sim.run(jnp.zeros(2), 6)
    assert np.all(np.isfinite(np.asarray(st.params)))
    assert hist[-1]["loss_last"] < hist[0]["loss_first"]

    # half the delta at server lr 1.0 == the full fedavg delta at lr 0.5
    fed_avg = dataclasses.replace(fed, algorithm="fedavg", server_lr=0.5)
    ref = FedSim(fed=fed_avg, grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=2)
    a, _ = ref.run(jnp.zeros(2), 4)
    b, _ = sim.run(jnp.zeros(2), 4)
    np.testing.assert_allclose(np.asarray(a.params), np.asarray(b.params),
                               rtol=1e-5, atol=1e-7)
