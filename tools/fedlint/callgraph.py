"""Traced-region discovery: which functions execute under a JAX trace.

Roots come from four places:

* explicit jit wrapping — ``jax.jit(f)`` / ``jit_donating_store(f, n)``
  calls and ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators;
* one-hop factory resolution — ``round_fn = make_round_program(...)``
  followed by ``jax.jit(round_fn)`` roots the nested defs that
  ``make_round_program`` returns (the repo's dominant jit idiom);
* structural transforms — functions passed to ``vmap``/``grad``/
  ``lax.scan``/``lax.cond``/… are traced even without a jit in sight;
* contract roots — traced hook methods of ``FedAlgorithm``/``PayloadCodec``
  subclasses, plus every closure built inside an algorithm method (client
  updates are closures returned by ``make_client_update`` and friends).

From the roots, tracing propagates through any call the project can
resolve (locals, module functions, imports, ``self.`` methods).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from fedlint.project import (FuncInfo, Module, Project, dotted_name,
                             iter_scope_nodes)

#: Callables whose first argument is jit-compiled.
JIT_WRAPPERS = ("jax.jit", "jax.pmap")
#: ``jit_donating_store(fn, argnum, ...)`` — matched by last path segment
#: so fixture files resolve without the real module on the path.
DONATING_WRAPPER = "jit_donating_store"
#: transform canonical name -> positions of traced function arguments.
TRANSFORM_ARGS: Dict[str, Tuple[int, ...]] = {
    "jax.vmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.jacfwd": (0,),
    "jax.jacrev": (0,),
    "jax.hessian": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.eval_shape": (0,),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.cond": (1, 2),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.associative_scan": (0,),
}
#: FedAlgorithm methods that run inside the jitted round program.
ALG_TRACED_HOOKS = frozenset({
    "broadcast", "init_accum", "payload_accum", "accumulate",
    "reduce_stacked", "finalize", "finish_cohort", "server_update",
    "aggregate", "abstract_payload", "abstract_broadcast_extras",
})
#: Algorithm methods whose closures are build-time, not traced.
ALG_HOST_METHODS = frozenset({"validate", "__init__", "burn_algorithm"})
#: PayloadCodec methods applied to traced payloads inside the round.
CODEC_TRACED_HOOKS = frozenset({
    "encode", "decode", "accum_like", "project_precision", "to_accum",
})


def traced_functions(project: Project) -> Dict[int, Tuple[FuncInfo, str]]:
    """Map ``id(func node) -> (FuncInfo, reason)`` for traced functions."""
    traced: Dict[int, Tuple[FuncInfo, str]] = {}
    queue: List[FuncInfo] = []

    def mark(info: Optional[FuncInfo], reason: str):
        """Record ``info`` as traced (once) and enqueue it for propagation."""
        if info is not None and id(info.node) not in traced:
            traced[id(info.node)] = (info, reason)
            queue.append(info)

    for mod in project.modules.values():
        _collect_jit_roots(project, mod, mark)
        _collect_decorator_roots(project, mod, mark)
    _collect_contract_roots(project, mark)
    _propagate(project, traced, queue, mark)
    return traced


# ---------------------------------------------------------------------------
# Root collection
# ---------------------------------------------------------------------------

def _collect_jit_roots(project: Project, mod: Module, mark):
    """Roots from jit/transform *call* sites in one module."""
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        canonical = mod.call_canonical(node) or ""
        where = f"{mod.relpath}:{node.lineno}"
        if canonical in JIT_WRAPPERS or _is_donating(canonical):
            if node.args:
                _mark_target(project, mod, node, node.args[0], mark,
                             f"jitted at {where}")
        elif canonical in TRANSFORM_ARGS:
            short = canonical.rsplit(".", 1)[-1]
            for pos in TRANSFORM_ARGS[canonical]:
                if pos < len(node.args):
                    _mark_target(project, mod, node, node.args[pos], mark,
                                 f"traced by {short} at {where}")


def _is_donating(canonical: str) -> bool:
    """True for ``jit_donating_store`` however it was imported."""
    return canonical.rsplit(".", 1)[-1] == DONATING_WRAPPER


def _collect_decorator_roots(project: Project, mod: Module, mark):
    """Roots from ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators."""
    for info in mod.func_index.values():
        for deco in getattr(info.node, "decorator_list", []):
            target = deco.func if isinstance(deco, ast.Call) else deco
            canonical = mod.canonical(dotted_name(target)) or ""
            if canonical in ("functools.partial", "partial") and (
                    isinstance(deco, ast.Call) and deco.args):
                canonical = mod.canonical(dotted_name(deco.args[0])) or ""
            if canonical in JIT_WRAPPERS or canonical in TRANSFORM_ARGS:
                mark(info, f"decorated with {canonical}")


def _collect_contract_roots(project: Project, mark):
    """Roots from FedAlgorithm/PayloadCodec hook contracts."""
    for cls in project.subclasses_of("FedAlgorithm", include_marker=True):
        for name, info in cls.methods.items():
            if name in ALG_TRACED_HOOKS:
                mark(info, f"{cls.name}.{name} round hook")
            if name not in ALG_HOST_METHODS:
                for nested in _nested_funcs(info):
                    mark(nested, f"closure built by {cls.name}.{name}")
    for cls in project.subclasses_of("PayloadCodec", include_marker=True):
        for name, info in cls.methods.items():
            if name in CODEC_TRACED_HOOKS:
                mark(info, f"{cls.name}.{name} codec hook")


def _nested_funcs(info: FuncInfo) -> List[FuncInfo]:
    """FuncInfos for defs/lambdas nested directly under ``info``."""
    out = []
    for node in iter_scope_nodes(info.node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            nested = info.module.func_index.get(id(node))
            if nested is not None:
                out.append(nested)
    return out


# ---------------------------------------------------------------------------
# Target resolution
# ---------------------------------------------------------------------------

def _mark_target(project: Project, mod: Module, call: ast.Call,
                 target, mark, reason: str):
    """Resolve the function expression handed to a jit/transform call."""
    if isinstance(target, ast.Lambda):
        mark(mod.func_index.get(id(target)), reason)
        return
    scope = _enclosing_scope(mod, call)
    info = project.resolve_call(mod, scope, target)
    if info is not None:
        mark(info, reason)
        return
    if isinstance(target, ast.Name):
        for returned in _factory_returns(project, mod, scope, target.id):
            mark(returned, reason + " (factory-built)")
        return
    dotted = dotted_name(target)
    if dotted and dotted.startswith("self."):
        for returned in _self_attr_factory(project, mod, scope, dotted):
            mark(returned, reason + " (factory-built attr)")


def _enclosing_scope(mod: Module, node) -> Tuple:
    """Chain of function nodes lexically enclosing ``node``."""
    chain = []
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            chain.append(cur)
        cur = getattr(cur, "parent", None)
    return tuple(reversed(chain))


def _factory_returns(project: Project, mod: Module, scope,
                     name: str) -> List[FuncInfo]:
    """One-hop factory resolution for ``x = make_thing(...); jit(x)``.

    Finds the assignment of ``name`` from a resolvable call and returns
    the nested defs the callee returns by name.
    """
    bodies = [s.body for s in scope
              if not isinstance(s, ast.Lambda)] or [mod.tree.body]
    for body in reversed(bodies):
        for stmt in _flat_stmts(body):
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and stmt.targets[0].id == name
                    and isinstance(stmt.value, ast.Call)):
                factory = project.resolve_call(mod, scope, stmt.value.func)
                if factory is not None:
                    return _returned_defs(factory)
    return []


def _self_attr_factory(project: Project, mod: Module, scope,
                       dotted: str) -> List[FuncInfo]:
    """Factory returns for ``self.attr`` assigned anywhere in the class."""
    if not scope:
        return []
    info = mod.func_index.get(id(scope[-1]))
    if info is None or info.cls is None:
        return []
    for method in info.cls.methods.values():
        for stmt in _flat_stmts(method.node.body):
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and dotted_name(stmt.targets[0]) == dotted
                    and isinstance(stmt.value, ast.Call)):
                factory = project.resolve_call(mod, (method.node,),
                                               stmt.value.func)
                if factory is not None:
                    return _returned_defs(factory)
    return []


def _flat_stmts(body) -> List:
    """Statements of a body, flattened through compound statements."""
    out = []
    stack = list(body)
    while stack:
        stmt = stack.pop()
        out.append(stmt)
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if isinstance(sub, list):
                stack.extend(s for s in sub if isinstance(s, ast.stmt))
    return out


def _returned_defs(factory: FuncInfo) -> List[FuncInfo]:
    """Nested defs of ``factory`` that it returns by bare name."""
    returned = set()
    for node in iter_scope_nodes(factory.node):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Name):
            returned.add(node.value.id)
    return [f for f in _nested_funcs(factory) if f.name in returned]


# ---------------------------------------------------------------------------
# Propagation
# ---------------------------------------------------------------------------

def _propagate(project: Project, traced, queue, mark):
    """Breadth-first closure over calls resolvable from traced bodies."""
    while queue:
        info = queue.pop()
        reason = f"called from traced `{info.qualname}`"
        for node in iter_scope_nodes(info.node):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                mark(info.module.func_index.get(id(node)),
                     f"nested in traced `{info.qualname}`")
            elif isinstance(node, ast.Call):
                callee = _resolve_from(project, info, node)
                if callee is not None:
                    mark(callee, reason)
                elif isinstance(node.func, ast.Name):
                    # a call through a factory-built local closure:
                    # `cohort_fn = make_cohort_program(...); cohort_fn(...)`
                    scope = info.scope_chain + (info.node,)
                    for returned in _factory_returns(
                            project, info.module, scope, node.func.id):
                        mark(returned, reason + " (factory-built)")


def _resolve_from(project: Project, info: FuncInfo,
                  call: ast.Call) -> Optional[FuncInfo]:
    """Resolve a call made inside ``info`` (incl. ``self.method()``)."""
    scope = info.scope_chain + (info.node,)
    callee = project.resolve_call(info.module, scope, call.func)
    if callee is not None:
        return callee
    dotted = dotted_name(call.func)
    if dotted and dotted.startswith("self.") and dotted.count(".") == 1:
        return _self_method(project, info, dotted.split(".")[1])
    return None


def _self_method(project: Project, info: FuncInfo,
                 name: str) -> Optional[FuncInfo]:
    """Resolve ``self.name()`` through the enclosing class and ancestors."""
    if info.cls is None:
        return None
    for cls in project.class_chain(info.cls, stop="object"):
        if name in cls.methods:
            return cls.methods[name]
    return None
