"""Parsed-module and whole-project indexes the rules are written against.

A :class:`Module` wraps one parsed file with the lookups every rule needs:
parent links, import alias maps (so ``tm.tzeros_like`` canonicalizes to
``repro.core.tree_math.tzeros_like`` without ever importing anything), and
an index of every function/lambda/class with its lexical scope chain. A
:class:`Project` aggregates modules and resolves names across them.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def dotted_name(node) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def iter_scope_nodes(func_node):
    """Walk a function body without descending into nested functions.

    Nested FunctionDef/Lambda nodes are yielded (so callers can treat them
    as separate scopes) but their children are not.
    """
    if isinstance(func_node, ast.Lambda):
        stack = [func_node.body]
    else:
        stack = list(func_node.body)
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _SCOPE_NODES):
            stack.extend(ast.iter_child_nodes(node))


def assigned_names(stmt) -> set:
    """Plain names bound by an assignment-like statement."""
    names = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    for t in targets:
        for sub in ast.walk(t):
            if isinstance(sub, ast.Name):
                names.add(sub.id)
    return names


class FuncInfo:
    """One function/lambda definition with its lexical context."""

    def __init__(self, node, module: "Module", qualname: str,
                 cls: Optional["ClassInfo"], scope_chain: Tuple):
        """Record the def ``node`` plus enclosing class and scope chain."""
        self.node = node
        self.module = module
        self.qualname = qualname
        self.cls = cls
        #: Enclosing function nodes, outermost first (for local lookups).
        self.scope_chain = scope_chain

    @property
    def name(self) -> str:
        """Bare function name (``<lambda>`` for lambdas)."""
        return getattr(self.node, "name", "<lambda>")

    def __repr__(self):
        """Debug representation naming the module and qualname."""
        return f"FuncInfo({self.module.relpath}:{self.qualname})"


class ClassInfo:
    """One class definition: bases, decorators, and direct methods."""

    def __init__(self, node: ast.ClassDef, module: "Module"):
        """Index the class ``node``'s bases, decorators, and methods."""
        self.node = node
        self.module = module
        self.name = node.name
        self.bases = [dotted_name(b) for b in node.bases]
        self.decorators = node.decorator_list
        self.methods: Dict[str, FuncInfo] = {}

    def base_names(self) -> List[str]:
        """Last path segment of each base (``pkg.Base`` -> ``Base``)."""
        return [b.rsplit(".", 1)[-1] for b in self.bases if b]


class Module:
    """One parsed source file with parent links and symbol indexes."""

    def __init__(self, path: Path, relpath: str, source: str):
        """Parse ``source`` and build the import/function/class indexes."""
        self.path = path
        self.relpath = relpath
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.modname = _modname(relpath)
        #: ``import numpy as np`` -> {"np": "numpy"}
        self.import_aliases: Dict[str, str] = {}
        #: ``from a.b import c as d`` -> {"d": "a.b.c"}
        self.from_imports: Dict[str, str] = {}
        self.functions: Dict[str, FuncInfo] = {}   # top-level defs by name
        self.classes: Dict[str, ClassInfo] = {}    # top-level classes
        self.func_index: Dict[int, FuncInfo] = {}  # id(node) -> FuncInfo
        _link_parents(self.tree)
        self._index_imports()
        _SymbolIndexer(self).visit(self.tree)

    def _index_imports(self):
        """Populate the import alias maps from every import statement."""
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.import_aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                base = self._from_base(node)
                for a in node.names:
                    if a.name != "*":
                        self.from_imports[a.asname or a.name] = (
                            f"{base}.{a.name}" if base else a.name)

    def _from_base(self, node: ast.ImportFrom) -> str:
        """Absolute dotted base of a (possibly relative) from-import."""
        if not node.level:
            return node.module or ""
        parts = self.modname.split(".")
        parts = parts[: len(parts) - node.level]
        if node.module:
            parts.append(node.module)
        return ".".join(parts)

    def canonical(self, dotted: Optional[str]) -> Optional[str]:
        """Expand the first segment of ``dotted`` through the import maps.

        ``tm.tzeros_like`` -> ``repro.core.tree_math.tzeros_like``; names
        with no import mapping pass through unchanged.
        """
        if not dotted:
            return dotted
        head, _, rest = dotted.partition(".")
        target = self.from_imports.get(head) or self.import_aliases.get(head)
        if not target:
            return dotted
        return f"{target}.{rest}" if rest else target

    def call_canonical(self, call: ast.Call) -> Optional[str]:
        """Canonical dotted name of a call's callee (None if dynamic)."""
        return self.canonical(dotted_name(call.func))


class _SymbolIndexer(ast.NodeVisitor):
    """Single-pass builder of a module's function/class/scope indexes."""

    def __init__(self, module: Module):
        """Start indexing at module scope."""
        self.m = module
        self.scope: List = []       # enclosing function nodes
        self.cls: Optional[ClassInfo] = None
        self.qual: List[str] = []

    def _add_func(self, node, name: str):
        """Register one function/lambda node under the current scope."""
        qualname = ".".join(self.qual + [name])
        info = FuncInfo(node, self.m, qualname, self.cls, tuple(self.scope))
        self.m.func_index[id(node)] = info
        if not self.scope:
            if self.cls is None:
                self.m.functions.setdefault(name, info)
            else:
                self.cls.methods.setdefault(name, info)
        return info

    def _visit_func(self, node):
        """Index a def and recurse with it pushed onto the scope chain."""
        self._add_func(node, getattr(node, "name", "<lambda>"))
        self.scope.append(node)
        self.qual.append(getattr(node, "name", "<lambda>"))
        self.generic_visit(node)
        self.qual.pop()
        self.scope.pop()

    def visit_FunctionDef(self, node):
        """Index a function definition."""
        self._visit_func(node)

    def visit_AsyncFunctionDef(self, node):
        """Index an async function definition."""
        self._visit_func(node)

    def visit_Lambda(self, node):
        """Index a lambda as an anonymous function."""
        self._visit_func(node)

    def visit_ClassDef(self, node):
        """Index a class; its methods land in ``ClassInfo.methods``."""
        info = ClassInfo(node, self.m)
        if self.cls is None and not self.scope:
            self.m.classes.setdefault(node.name, info)
        prev, self.cls = self.cls, info
        self.qual.append(node.name)
        self.generic_visit(node)
        self.qual.pop()
        self.cls = prev


def _link_parents(tree):
    """Attach ``.parent`` backlinks to every node (lexical-context walks)."""
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node


def _modname(relpath: str) -> str:
    """Dotted module name for a repo-relative path (src-layout aware)."""
    parts = list(Path(relpath).with_suffix("").parts)
    if parts and parts[0] in ("src", "tools"):
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class Project:
    """Every parsed module plus cross-module name resolution."""

    def __init__(self, files: Sequence[Path], root: Path):
        """Parse ``files`` (skipping unreadable ones) relative to ``root``."""
        self.root = root
        self.modules: Dict[str, Module] = {}
        self.by_modname: Dict[str, Module] = {}
        for f in files:
            try:
                rel = f.resolve().relative_to(root.resolve()).as_posix()
            except ValueError:
                rel = f.as_posix()
            mod = Module(f, rel, f.read_text())
            self.modules[rel] = mod
            self.by_modname[mod.modname] = mod

    def lines_for_path(self, relpath: str) -> Optional[List[str]]:
        """Source lines of an analyzed file (None if not in the project)."""
        mod = self.modules.get(relpath)
        return mod.lines if mod else None

    def find_function(self, canonical: str) -> Optional[FuncInfo]:
        """Top-level function for a canonical dotted name, if analyzed."""
        if "." not in canonical:
            return None
        modname, _, fname = canonical.rpartition(".")
        mod = self.by_modname.get(modname)
        return mod.functions.get(fname) if mod else None

    def resolve_call(self, module: Module, scope_chain,
                     name_node) -> Optional[FuncInfo]:
        """Resolve a callee Name/Attribute to an analyzed FuncInfo.

        Checks, in order: functions defined in enclosing scopes, module
        top-level functions, and cross-module from-imports/aliases.
        """
        dotted = dotted_name(name_node)
        if not dotted:
            return None
        if "." not in dotted:
            local = self._local_function(module, scope_chain, dotted)
            if local is not None:
                return local
            if dotted in module.functions:
                return module.functions[dotted]
        return self.find_function(module.canonical(dotted))

    def _local_function(self, module: Module, scope_chain,
                        name: str) -> Optional[FuncInfo]:
        """A def named ``name`` in any enclosing function scope."""
        for scope in reversed(scope_chain or ()):
            for node in iter_scope_nodes(scope):
                if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and node.name == name):
                    return module.func_index.get(id(node))
        return None

    def all_classes(self) -> List[ClassInfo]:
        """Every top-level class in the project."""
        return [c for m in self.modules.values() for c in m.classes.values()]

    def subclasses_of(self, marker: str,
                      include_marker: bool = False) -> List[ClassInfo]:
        """Classes whose transitive base-name chain reaches ``marker``.

        Resolution is by simple class name (last dotted segment), which is
        what makes fixture files with stub base classes analyzable without
        importing anything.
        """
        by_name = {c.name: c for c in self.all_classes()}
        out = []
        for cls in by_name.values():
            if cls.name == marker:
                if include_marker:
                    out.append(cls)
                continue
            seen, frontier = set(), list(cls.base_names())
            while frontier:
                base = frontier.pop()
                if base in seen:
                    continue
                seen.add(base)
                if base == marker:
                    out.append(cls)
                    frontier = []
                elif base in by_name:
                    frontier.extend(by_name[base].base_names())
        return out

    def class_chain(self, cls: ClassInfo, stop: str) -> List[ClassInfo]:
        """``cls`` plus its project-resolvable ancestors, up to ``stop``.

        The ``stop`` class itself is excluded — its defaults are the
        contract, not an implementation of it.
        """
        by_name = {c.name: c for c in self.all_classes()}
        chain, frontier, seen = [], [cls.name], set()
        while frontier:
            name = frontier.pop(0)
            if name in seen or name == stop or name not in by_name:
                continue
            seen.add(name)
            chain.append(by_name[name])
            frontier.extend(by_name[name].base_names())
        return chain
