"""fedlint: AST-based static analysis for this repo's JAX invariants.

Self-contained over stdlib ``ast`` — it never imports ``repro`` — so it
runs in the CI lint lane with no dependencies installed. The rules encode
the round engine's conventions as lint-time checks: trace purity (FL001),
donation safety (FL002), the fp32 accumulator contract (FL003), PRNG key
discipline (FL004), registry/config contracts (FL005), and sharding pins
on donating jits (FL006). See the README's "Static analysis" section.
"""
from fedlint.core import Finding, Rule, all_rules, register_rule
from fedlint.runner import run, run_paths

__all__ = ["Finding", "Rule", "all_rules", "register_rule", "run",
           "run_paths"]
__version__ = "0.1.0"
