"""FL007: history records are assembled in ``core/history.py`` — only.

The sync/async round-loop unification exists because history assembly
kept drifting apart: PR 4 fixed JSON-breaking device arrays in async
history only, PR 5 re-fixed the same bug for sync, and PR 8 threaded the
byte accounting through both loops by hand. ``core.history.RoundRecorder``
is now the single place round records are built (uniform schema, one
end-of-loop ``json_scalar`` sync), and this rule keeps it that way: any
``json_scalar`` call, or any dict literal that looks like a hand-rolled
round record (two or more of the recorder's schema-marker keys), outside
``core/history.py`` is a finding. Frontends that *log* per-round lines
may copy single fields off the recorder's record; what they must not do
is rebuild the record — that is the duplication this rule exists to stop
regrowing.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterator

from fedlint.core import Finding, Rule, register_rule

#: Keys that mark a dict literal as a round-history record. Two or more
#: together only ever appear in the recorder's uniform schema — a log line
#: borrowing one field (e.g. "staleness") stays clean, byte-accounting
#: dicts ({"bytes_up", "bytes_down"}) stay clean.
_MARKERS = frozenset({"client_loss", "staleness", "state_drops", "straggled"})

#: The one module allowed to assemble records / call json_scalar.
_EXEMPT_SUFFIX = "repro/core/history.py"


@register_rule
class HistoryOutsideRecorder(Rule):
    """Flag history-record assembly outside the shared RoundRecorder."""

    id = "FL007"
    name = "history-outside-recorder"
    description = ("history records (and json_scalar conversion) must be "
                   "assembled by core.history.RoundRecorder, not "
                   "hand-rolled in round loops")

    def check(self, project) -> Iterator[Finding]:
        """Scan calls and dict literals everywhere but core/history.py."""
        for mod in project.modules.values():
            if Path(mod.relpath).as_posix().endswith(_EXEMPT_SUFFIX):
                continue
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call):
                    problem = _json_scalar_call(mod, node)
                elif isinstance(node, ast.Dict):
                    problem = _record_literal(node)
                else:
                    continue
                if problem:
                    yield Finding(
                        self.id, mod.relpath, node.lineno,
                        node.col_offset + 1, problem)


def _json_scalar_call(mod, call: ast.Call) -> str:
    """A json_scalar call outside the recorder ('' when fine)."""
    name = mod.call_canonical(call) or _dotted(call.func) or ""
    if name.rsplit(".", 1)[-1] == "json_scalar":
        return ("json_scalar call outside core/history.py; history "
                "conversion happens once, in RoundRecorder.history() — "
                "consume its records instead of re-converting")
    return ""


def _record_literal(node: ast.Dict) -> str:
    """A dict literal that rebuilds the recorder's schema ('' when fine)."""
    keys = {k.value for k in node.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)}
    hits = sorted(keys & _MARKERS)
    if len(hits) >= 2:
        return (f"hand-rolled history record (schema keys: "
                f"{', '.join(hits)}); round records are assembled by "
                f"core.history.RoundRecorder only")
    return ""


def _dotted(expr) -> str:
    """Best-effort dotted name of a callee (attribute chains only)."""
    parts = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        parts.append(expr.id)
        return ".".join(reversed(parts))
    return ""
