"""FL004: a PRNG key consumed twice without an intervening split.

JAX key discipline: a key feeds exactly one sampler (or is split /
folded into fresh subkeys); reusing a consumed key correlates draws that
should be independent — silently, since nothing fails at runtime. This
rule tracks, per function scope, which key *expressions* (``rng``,
``ks[0]``, …) have been consumed by a ``jax.random.*`` sampler and flags

* a second sampler consumption of the same expression, and
* a later ``split``/``fold_in`` of an already-consumed expression (the
  split belongs *before* the first consumption);
* a sampler consuming a loop-invariant key name inside a loop body
  (every iteration would redraw the same numbers).

Reassigning the key's base name (``rng, sub = split(rng)``) resets it.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional

from fedlint.core import Finding, Rule, register_rule
from fedlint.project import assigned_names

#: jax.random functions that derive keys rather than consuming entropy.
_DERIVERS = frozenset({"split", "fold_in", "key", "PRNGKey", "key_data",
                       "wrap_key_data", "clone"})
_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


@register_rule
class PrngKeyReuse(Rule):
    """Flag PRNG keys consumed more than once without a split."""

    id = "FL004"
    name = "prng-key-reuse"
    description = ("a PRNGKey/fold_in value must be consumed by at most "
                   "one sampler; split first")

    def check(self, project) -> Iterator[Finding]:
        """Run the per-scope key tracker over every function."""
        for mod in project.modules.values():
            for info in mod.func_index.values():
                if isinstance(info.node, ast.Lambda):
                    continue
                yield from _Tracker(self.id, mod).scan(info.node)


class _Tracker:
    """Tracks consumed key expressions through one function scope."""

    def __init__(self, rule_id: str, mod):
        """Track key consumption for module ``mod``."""
        self.rule_id = rule_id
        self.mod = mod
        self.consumed: Dict[str, int] = {}   # key expr text -> line
        self.findings: List[Finding] = []

    def scan(self, func_node) -> List[Finding]:
        """Process the scope's statements in source order."""
        for stmt in func_node.body:
            self._stmt(stmt)
        return self.findings

    def _stmt(self, stmt):
        """Handle one statement: events in order, then rebind resets."""
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            self._events(stmt, loop=stmt)
            self._reset(assigned_names(stmt))
            return
        if hasattr(stmt, "body") and not isinstance(stmt, _FUNC_NODES):
            for field in ("body", "orelse", "finalbody"):
                for sub in getattr(stmt, field, []) or []:
                    self._stmt(sub)
            for handler in getattr(stmt, "handlers", []):
                for sub in handler.body:
                    self._stmt(sub)
            return
        self._events(stmt, loop=None)
        self._reset(assigned_names(stmt))

    def _events(self, stmt, loop):
        """Replay sampler/deriver calls inside ``stmt`` in source order."""
        calls = [n for n in _walk_scope(stmt) if isinstance(n, ast.Call)]
        calls.sort(key=lambda c: (c.lineno, c.col_offset))
        loop_assigned = assigned_names(loop) if loop is not None else set()
        if loop is not None:
            for sub in ast.walk(loop):
                loop_assigned |= assigned_names(sub)
        for call in calls:
            kind = self._random_kind(call)
            if kind is None or not call.args:
                continue
            expr = ast.unparse(call.args[0])
            if kind == "sampler":
                self._consume(call, expr, loop, loop_assigned)
            elif expr in self.consumed:
                self._flag(call, f"`{kind}({expr}, ...)` derives from a key "
                                 f"already consumed at line "
                                 f"{self.consumed[expr]}; split before the "
                                 f"first consumption")

    def _consume(self, call, expr: str, loop, loop_assigned):
        """Record a sampler consumption, flagging reuse."""
        if expr in self.consumed:
            self._flag(call, f"PRNG key `{expr}` already consumed at line "
                             f"{self.consumed[expr]} is consumed again; "
                             f"split it instead")
            return
        base = _base_name(call.args[0])
        if (loop is not None and isinstance(call.args[0], ast.Name)
                and base not in loop_assigned):
            self._flag(call, f"PRNG key `{expr}` is consumed inside a loop "
                             f"without a per-iteration split; every "
                             f"iteration redraws the same numbers")
        self.consumed[expr] = call.lineno

    def _random_kind(self, call) -> Optional[str]:
        """'sampler', a deriver's name, or None for non-jax.random calls."""
        canonical = self.mod.call_canonical(call) or ""
        head, _, tail = canonical.rpartition(".")
        if head == "jax.random":
            return tail if tail in _DERIVERS else "sampler"
        if tail in ("fold_in", "split") and not head:
            return tail  # from-imported derivers
        return None

    def _reset(self, names):
        """Forget consumptions whose base name was rebound."""
        if names:
            self.consumed = {e: ln for e, ln in self.consumed.items()
                             if _expr_base(e) not in names}

    def _flag(self, call, message: str):
        """Emit one finding at the offending call."""
        self.findings.append(Finding(
            self.rule_id, self.mod.relpath, call.lineno,
            call.col_offset + 1, message))


def _base_name(node) -> Optional[str]:
    """Leftmost data name of a key expression (``ks[0]`` -> ``ks``)."""
    while True:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, (ast.Subscript, ast.Attribute, ast.Starred)):
            node = node.value
        elif isinstance(node, ast.Call) and node.args:
            node = node.args[0]
        else:
            return None


def _expr_base(expr: str) -> str:
    """Base identifier of a stored key-expression string."""
    for i, ch in enumerate(expr):
        if not (ch.isalnum() or ch == "_"):
            return expr[:i]
    return expr


def _walk_scope(node):
    """Walk a subtree without descending into nested functions."""
    stack = [node]
    while stack:
        cur = stack.pop()
        yield cur
        if not isinstance(cur, _FUNC_NODES):
            stack.extend(ast.iter_child_nodes(cur))
