"""FL005: registry and FedConfig contract drift.

Three contracts that otherwise only fail at run (or accounting) time:

* a ``@register_algorithm`` class that is stateful must define
  ``init_client_state``; one that reshapes its payload (overriding any of
  the accumulator-space hooks) must define ``abstract_payload``; one that
  overrides ``broadcast`` must define ``abstract_broadcast_extras`` —
  otherwise the bytes accounting silently reports the wrong uplink or the
  state store has no template;
* every attribute read off a ``FedConfig``-typed expression must name a
  declared field/property (typos read as ``AttributeError`` deep inside a
  traced round otherwise);
* every ``FedConfig`` field that is *read* anywhere must also be
  *validated by name* somewhere in the validation scope —
  ``__post_init__`` / ``_validate_*`` / an algorithm ``validate()`` —
  so bad knob values surface at construction, not trace time.

Fed-typed expressions are recognized by convention: a name or parameter
called ``fed``, anything assigned from ``*.fed``, and ``self`` inside
``FedConfig``'s own methods.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from fedlint.core import Finding, Rule, register_rule
from fedlint.project import ClassInfo, Project, dotted_name

#: Overriding any of these means the payload left bare-delta space.
_PAYLOAD_HOOKS = frozenset({"init_accum", "payload_accum", "accumulate",
                            "reduce_stacked"})
#: Attributes allowed on fed-typed expressions beyond declared fields.
_DUNDER_OK = frozenset({"__class__", "__dict__", "replace"})


@register_rule
class RegistryContractDrift(Rule):
    """Flag algorithm-registry and FedConfig contract violations."""

    id = "FL005"
    name = "registry-contract-drift"
    description = ("registered algorithms must declare their payload/state "
                   "contracts; FedConfig fields must be validated by name")

    def check(self, project) -> Iterator[Finding]:
        """Run the class-contract and config-field checks."""
        yield from self._check_algorithm_contracts(project)
        cfg = _find_fedconfig(project)
        if cfg is not None:
            yield from self._check_config_fields(project, cfg)

    # -- (a) registered algorithm class contracts ---------------------------
    def _check_algorithm_contracts(self, project) -> Iterator[Finding]:
        """Stateful/payload/broadcast contracts of registered classes."""
        for cls in project.subclasses_of("FedAlgorithm"):
            if not _is_registered(cls):
                continue
            chain = project.class_chain(cls, stop="FedAlgorithm")
            defined = {m for c in chain for m in c.methods}
            loc = (cls.module.relpath, cls.node.lineno)
            if _is_stateful(chain) and "init_client_state" not in defined:
                yield self._cls_finding(
                    cls, f"stateful algorithm `{cls.name}` does not define "
                         f"init_client_state; the client store has no "
                         f"state template", loc)
            if defined & _PAYLOAD_HOOKS and "abstract_payload" not in defined:
                yield self._cls_finding(
                    cls, f"`{cls.name}` reshapes its payload "
                         f"({sorted(defined & _PAYLOAD_HOOKS)}) but does "
                         f"not define abstract_payload; bytes accounting "
                         f"will report the wrong uplink", loc)
            if ("broadcast" in defined
                    and "abstract_broadcast_extras" not in defined):
                yield self._cls_finding(
                    cls, f"`{cls.name}` overrides broadcast but not "
                         f"abstract_broadcast_extras; downlink accounting "
                         f"will miss the extras", loc)

    def _cls_finding(self, cls: ClassInfo, message: str, loc) -> Finding:
        """Finding anchored at the class definition line."""
        return Finding(self.id, loc[0], loc[1], 1, message)

    # -- (b)+(c) FedConfig field reads --------------------------------------
    def _check_config_fields(self, project, cfg: ClassInfo
                             ) -> Iterator[Finding]:
        """Unknown-field reads and read-but-unvalidated fields."""
        fields = _config_fields(cfg)
        allowed = fields | set(cfg.methods) | _DUNDER_OK
        validation_funcs = _validation_scope(project, cfg)
        validated: Set[str] = set()
        reads: Dict[str, Tuple[str, int]] = {}
        for mod in project.modules.values():
            for attr, node, in_validation in _fed_attr_reads(
                    mod, cfg, validation_funcs):
                if attr not in allowed:
                    yield Finding(
                        self.id, mod.relpath, node.lineno,
                        node.col_offset + 1,
                        f"unknown FedConfig field `{attr}`; declared "
                        f"fields: check configs/base.py")
                elif attr in fields:
                    if in_validation:
                        validated.add(attr)
                    else:
                        reads.setdefault(attr, (mod.relpath, node.lineno))
        for field in sorted(set(reads) - validated):
            path, line = reads[field]
            yield Finding(
                self.id, path, line, 1,
                f"FedConfig.{field} is read here but never validated by "
                f"name in __post_init__/_validate_*/validate(); bad values "
                f"surface only at trace time")


# ---------------------------------------------------------------------------
# FedConfig discovery
# ---------------------------------------------------------------------------

def _find_fedconfig(project: Project) -> Optional[ClassInfo]:
    """The class literally named FedConfig, if analyzed."""
    for cls in project.all_classes():
        if cls.name == "FedConfig":
            return cls
    return None


def _config_fields(cfg: ClassInfo) -> Set[str]:
    """Declared dataclass fields (annotated class-level names)."""
    fields = set()
    for stmt in cfg.node.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            fields.add(stmt.target.id)
    return fields


def _validation_scope(project: Project, cfg: ClassInfo) -> Set[int]:
    """id() of every function node that counts as validation code."""
    funcs: Set[int] = set()
    for name, info in cfg.methods.items():
        if name == "__post_init__" or name.startswith("_validate"):
            funcs.add(id(info.node))
    for cls in project.subclasses_of("FedAlgorithm", include_marker=True):
        if "validate" in cls.methods:
            funcs.add(id(cls.methods["validate"].node))
    return funcs


# ---------------------------------------------------------------------------
# Fed-typed expression scanning
# ---------------------------------------------------------------------------

def _fed_attr_reads(mod, cfg: ClassInfo, validation_funcs: Set[int]):
    """Yield (attr, node, in_validation) for reads off fed-typed exprs."""
    in_cfg_module = cfg.module is mod
    for info in mod.func_index.values():
        fed_names = _fed_locals(info)
        if in_cfg_module and info.cls is cfg:
            fed_names.add("self")
        in_validation = id(info.node) in validation_funcs
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Attribute):
                continue
            base = ast.unparse(node.value)
            if base in fed_names or base == "fed" or base.endswith(".fed"):
                yield node.attr, node, in_validation


def _fed_locals(info) -> Set[str]:
    """Local names statically known to hold a FedConfig in ``info``."""
    names: Set[str] = set()
    args = getattr(info.node, "args", None)
    if args is not None:
        for a in list(args.args) + list(args.kwonlyargs):
            ann = ast.unparse(a.annotation) if a.annotation else ""
            if a.arg == "fed" or "FedConfig" in ann:
                names.add(a.arg)
    for node in ast.walk(info.node):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            value = dotted_name(node.value) or ""
            if value == "fed" or value.endswith(".fed"):
                names.add(node.targets[0].id)
    return names


def _is_registered(cls: ClassInfo) -> bool:
    """True when the class carries a ``@register_algorithm`` decorator."""
    for deco in cls.decorators:
        target = deco.func if isinstance(deco, ast.Call) else deco
        name = dotted_name(target) or ""
        if name.rsplit(".", 1)[-1] == "register_algorithm":
            return True
    return False


def _is_stateful(chain: List[ClassInfo]) -> bool:
    """True when the class (chain) is, or can switch itself, stateful."""
    for cls in chain:
        for stmt in cls.node.body:
            if (isinstance(stmt, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "stateful"
                            for t in stmt.targets)
                    and isinstance(stmt.value, ast.Constant)
                    and stmt.value.value is True):
                return True
        init = cls.methods.get("__init__")
        if init is not None:
            for node in ast.walk(init.node):
                if (isinstance(node, ast.Assign)
                        and any(dotted_name(t) == "self.stateful"
                                for t in node.targets)):
                    return True
    return False
