"""FL003: the fp32 accumulator contract for algorithms and codecs.

The aggregation path accumulates client payloads in fp32 regardless of
``fed.delta_dtype`` and casts exactly once, in ``finalize`` — the bf16
weight-cast bug fixed in PR 2 (and re-fixed for the sequential fold in
PR 4) came from violating this. Three checks:

* accumulator constructors (``init_accum`` / ``accum_like`` /
  ``accum_zeros``) must pin their zeros to ``jnp.float32``;
* the linear path (``payload_accum`` / ``accumulate`` /
  ``reduce_stacked``) must not cast out of fp32 — ``.astype(acc.dtype)``
  and casts *to* fp32 are fine, the terminal cast belongs in
  ``finalize``;
* ``lax.scan`` carries seeded from a zeros tree inside client-update
  closures must pin fp32 explicitly — an un-pinned ``tzeros_like(p)``
  inherits the (possibly bf16) param dtype and re-rounds every step.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from fedlint.core import Finding, Rule, register_rule
from fedlint.project import dotted_name, iter_scope_nodes

#: Methods that construct accumulator zeros.
_INIT_METHODS = frozenset({"init_accum", "accum_like", "accum_zeros"})
#: Methods forming the linear accumulator path (casts forbidden).
_LINEAR_METHODS = frozenset({"payload_accum", "accumulate",
                             "reduce_stacked"})
#: Zero-constructing callables (by canonical name / last segment).
_ZERO_CALLS = frozenset({"tzeros_like", "zeros", "zeros_like"})


@register_rule
class Fp32Accumulator(Rule):
    """Enforce fp32 accumulators with one terminal cast in finalize."""

    id = "FL003"
    name = "fp32-accumulator"
    description = ("accumulator init and scan carries must be fp32 with a "
                   "single terminal cast in finalize")

    def check(self, project) -> Iterator[Finding]:
        """Check algorithm and codec classes in the project."""
        classes = (project.subclasses_of("FedAlgorithm", True)
                   + project.subclasses_of("PayloadCodec", True)
                   + project.subclasses_of("CodecChain", True))
        for cls in classes:
            for name, info in cls.methods.items():
                if name in _INIT_METHODS:
                    yield from self._check_zeros(info, ctx=f"{cls.name}.{name}")
                if name in _LINEAR_METHODS:
                    yield from self._check_casts(info, ctx=f"{cls.name}.{name}")
                yield from self._check_scan_carries(info, cls)

    # -- accumulator constructors -------------------------------------------
    def _check_zeros(self, info, ctx: str) -> Iterator[Finding]:
        """Every zeros call in an init method must pin jnp.float32."""
        for node in ast.walk(info.node):
            if isinstance(node, ast.Call) and _is_zero_call(info.module, node):
                problem = _dtype_problem(node)
                if problem:
                    yield Finding(
                        self.id, info.module.relpath, node.lineno,
                        node.col_offset + 1,
                        f"accumulator zeros in `{ctx}` {problem}; the "
                        f"accumulator space is fp32 by contract "
                        f"(finalize owns the single cast)")

    # -- linear path casts ---------------------------------------------------
    def _check_casts(self, info, ctx: str) -> Iterator[Finding]:
        """No casts out of fp32 on the linear accumulator path."""
        for node in ast.walk(info.node):
            target = _cast_target(info.module, node)
            if target is not None and not _cast_ok(target):
                yield Finding(
                    self.id, info.module.relpath, node.lineno,
                    node.col_offset + 1,
                    f"cast to `{ast.unparse(target)}` in `{ctx}` leaves "
                    f"the fp32 accumulator space; the terminal cast "
                    f"belongs in finalize")

    # -- scan carries --------------------------------------------------------
    def _check_scan_carries(self, info, cls) -> Iterator[Finding]:
        """Zeros-seeded ``lax.scan`` carries must pin fp32."""
        for func_node in [info.node] + _nested_nodes(info):
            for node in iter_scope_nodes(func_node):
                if not (isinstance(node, ast.Call)
                        and info.module.call_canonical(node)
                        == "jax.lax.scan" and len(node.args) >= 2):
                    continue
                for zeros in _zero_inits(info.module, func_node,
                                         node.args[1]):
                    problem = _dtype_problem(zeros)
                    if problem:
                        yield Finding(
                            self.id, info.module.relpath, zeros.lineno,
                            zeros.col_offset + 1,
                            f"lax.scan carry in `{cls.name}` seeded by "
                            f"zeros that {problem}; accumulate in fp32 "
                            f"and cast once after the scan")


def _is_zero_call(module, call: ast.Call) -> bool:
    """True for tzeros_like / jnp.zeros / jnp.zeros_like calls."""
    canonical = module.call_canonical(call) or ""
    return canonical.rsplit(".", 1)[-1] in _ZERO_CALLS


def _dtype_problem(call: ast.Call) -> Optional[str]:
    """Why a zeros call violates the fp32 pin (None when compliant)."""
    dtype = None
    if len(call.args) >= 2:
        dtype = call.args[1]
    for kw in call.keywords:
        if kw.arg == "dtype":
            dtype = kw.value
    if dtype is None:
        return "inherit the input dtype (no dtype argument)"
    if not _is_fp32(dtype):
        return f"pin `{ast.unparse(dtype)}` instead of jnp.float32"
    return None


def _is_fp32(node) -> bool:
    """True when a dtype expression is statically float32."""
    if isinstance(node, ast.Constant):
        return node.value in ("float32", "f32")
    name = dotted_name(node) or ""
    return name.rsplit(".", 1)[-1] == "float32"


def _cast_target(module, node) -> Optional[ast.AST]:
    """The dtype expression of an ``.astype``/``tcast`` call, if any."""
    if not isinstance(node, ast.Call):
        return None
    if (isinstance(node.func, ast.Attribute) and node.func.attr == "astype"
            and node.args):
        return node.args[0]
    canonical = module.call_canonical(node) or ""
    if canonical.rsplit(".", 1)[-1] == "tcast" and len(node.args) >= 2:
        return node.args[1]
    return None


def _cast_ok(target) -> bool:
    """Casts to fp32 or to the accumulator's own dtype are allowed."""
    if _is_fp32(target):
        return True
    name = dotted_name(target) or ""
    return name.endswith(".dtype")


def _nested_nodes(info) -> List[ast.AST]:
    """All function nodes nested (at any depth) under ``info``."""
    out = []
    for node in ast.walk(info.node):
        if node is not info.node and isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            out.append(node)
    return out


def _zero_inits(module, func_node, init) -> List[ast.Call]:
    """Zeros calls seeding a scan init (direct, via Name, or in a tuple)."""
    out: List[ast.Call] = []
    elements = init.elts if isinstance(init, ast.Tuple) else [init]
    for el in elements:
        if isinstance(el, ast.Call) and _is_zero_call(module, el):
            out.append(el)
        elif isinstance(el, ast.Name):
            assigned = _assignment_value(func_node, el.id)
            if (isinstance(assigned, ast.Call)
                    and _is_zero_call(module, assigned)):
                out.append(assigned)
    return out


def _assignment_value(func_node, name: str) -> Optional[ast.AST]:
    """The value last assigned to ``name`` in ``func_node``'s own scope."""
    value = None
    for node in iter_scope_nodes(func_node):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name):
            value = node.value
    return value
