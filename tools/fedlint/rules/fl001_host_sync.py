"""FL001: host synchronization inside jit-traced code.

The round engine's performance contract (PR 1's single-jit round, PR 5's
device store) is that nothing inside the traced round forces a device
sync or falls back to host numpy: ``np.*`` calls, ``.item()``,
``float()``/``int()`` on traced values, ``jax.device_get``, and ``print``
all either fail at trace time or silently graduate to per-round blocking
transfers. This rule walks the call graph from every jit/transform entry
point (see ``fedlint.callgraph``) and flags host operations in traced
bodies.

Exemptions: shape/static derivations (``int(x.shape[0])``, ``.ndim``,
``.size``, ``len``), constants, and code lexically guarded by an
``isinstance(..., Tracer)`` check (the ``core.server.normalized_weights``
idiom, which runs host-side only when the value is concrete).
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from fedlint.callgraph import traced_functions
from fedlint.core import Finding, Rule, register_rule
from fedlint.project import dotted_name, iter_scope_nodes

#: Builtin conversions that force a concrete (host) value.
_HOST_CASTS = frozenset({"float", "int", "bool"})
#: Attribute accesses that make an int() / float() shape-derived.
_STATIC_ATTRS = frozenset({"shape", "ndim", "size", "dtype"})


@register_rule
class HostSyncInJit(Rule):
    """Flag host-sync operations reachable from jit entry points."""

    id = "FL001"
    name = "host-sync-in-jit"
    description = ("no numpy calls, .item(), float()/int() on traced "
                   "values, jax.device_get, or print inside jitted code")

    def check(self, project) -> Iterator[Finding]:
        """Walk every traced function body for host operations."""
        for info, reason in traced_functions(project).values():
            for node in iter_scope_nodes(info.node):
                if not isinstance(node, ast.Call):
                    continue
                op = self._host_op(info.module, node)
                if op is not None and not _tracer_guarded(node):
                    yield Finding(
                        self.id, info.module.relpath, node.lineno,
                        node.col_offset + 1,
                        f"{op} inside jit-traced `{info.qualname}` "
                        f"({reason}); host sync breaks the traced round")

    def _host_op(self, module, call: ast.Call) -> Optional[str]:
        """Describe the host operation a call performs, if any."""
        canonical = module.call_canonical(call) or ""
        if canonical.startswith("numpy."):
            return f"numpy call `{dotted_name(call.func)}`"
        if canonical == "jax.device_get":
            return "`jax.device_get`"
        if isinstance(call.func, ast.Attribute):
            if call.func.attr == "item":
                return "`.item()`"
            if call.func.attr == "block_until_ready":
                return "`.block_until_ready()`"
        if isinstance(call.func, ast.Name):
            if call.func.id == "print":
                return "`print` (use jax.debug.print)"
            if call.func.id in _HOST_CASTS and not _static_arg(call):
                return f"`{call.func.id}()` on a traced value"
        return None


def _static_arg(call: ast.Call) -> bool:
    """True when a float()/int() argument is constant or shape-derived.

    Shape-derived: any ``.shape``/``.ndim``/``.size`` access or ``len()``
    call. Attribute-only expressions (``cfg.expansion * cfg.d_model``,
    where every Name is just an attribute base) are treated as static
    config reads — traced values in this codebase are locals, not object
    attributes.
    """
    if not call.args:
        return True
    arg = call.args[0]
    bare_name = False
    for node in ast.walk(arg):
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return True
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "len"):
            return True
        if isinstance(node, ast.Name) and not _is_attr_base(node):
            bare_name = True
        if isinstance(node, (ast.Call, ast.Subscript)):
            bare_name = True
    return not bare_name


def _is_attr_base(node) -> bool:
    """True when a Name only serves as the base of an attribute read."""
    parent = getattr(node, "parent", None)
    return isinstance(parent, ast.Attribute) and parent.value is node


def _tracer_guarded(node) -> bool:
    """True inside an ``if ... isinstance(..., Tracer)``-guarded block."""
    cur = getattr(node, "parent", None)
    while cur is not None and not isinstance(cur, (ast.FunctionDef,
                                                   ast.AsyncFunctionDef,
                                                   ast.Lambda)):
        if isinstance(cur, ast.If) and "Tracer" in ast.unparse(cur.test):
            return True
        cur = getattr(cur, "parent", None)
    return False
