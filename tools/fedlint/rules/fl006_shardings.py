"""FL006: donating jit calls must pin ``out_shardings`` explicitly.

PR 7's population-sharded device store relies on every jit that donates
the store buffer also pinning its output shardings: without the pin, XLA
is free to lay the donated output out differently from the population
sharding, which silently breaks buffer donation (a fresh allocation per
round) or, worse, resharded client state. Every ``jit_donating_store``
call and every ``jax.jit(..., donate_argnums=...)`` call must therefore
pass ``out_shardings`` — explicitly ``None`` where single-device
execution makes that a decision rather than an omission. Calls that
forward ``**kwargs`` are exempt (the decision is the caller's).
"""
from __future__ import annotations

import ast
from typing import Iterator

from fedlint.core import Finding, Rule, register_rule


@register_rule
class UnpinnedOutShardings(Rule):
    """Flag donating jit wrappers that omit out_shardings."""

    id = "FL006"
    name = "unpinned-out-shardings"
    description = ("jit calls that donate buffers must pass out_shardings "
                   "(None is an explicit decision; omission is not)")

    def check(self, project) -> Iterator[Finding]:
        """Scan every call site for donation without a sharding pin."""
        for mod in project.modules.values():
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call):
                    problem = _unpinned(mod, node)
                    if problem:
                        yield Finding(
                            self.id, mod.relpath, node.lineno,
                            node.col_offset + 1, problem)


def _unpinned(mod, call: ast.Call) -> str:
    """Describe the missing pin for a donating call ('' when fine)."""
    canonical = mod.call_canonical(call) or ""
    kwargs = {kw.arg for kw in call.keywords}   # None marks a ** splat
    if None in kwargs or "out_shardings" in kwargs:
        return ""
    if canonical.rsplit(".", 1)[-1] == "jit_donating_store":
        return ("jit_donating_store call without out_shardings; pin the "
                "population sharding (or pass None explicitly on "
                "single-device paths)")
    if canonical in ("jax.jit", "jax.pmap") and (
            kwargs & {"donate_argnums", "donate_argnames"}):
        return ("jax.jit with donated arguments but no out_shardings; "
                "donation without a sharding pin can silently reallocate "
                "or reshard the donated buffer")
    return ""
