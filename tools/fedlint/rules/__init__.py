"""Rule modules; importing this package registers every rule.

Each module holds one rule, named after its id. See the repo README's
"Static analysis" section for the invariant each rule guards and the
PR/bug that motivated it.
"""
from fedlint.rules import (fl001_host_sync, fl002_donation,  # noqa: F401
                           fl003_accumulator, fl004_prng, fl005_registry,
                           fl006_shardings, fl007_history)
