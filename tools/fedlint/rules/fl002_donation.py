"""FL002: a donated buffer referenced after the donating call.

``jit_donating_store`` (PR 5/PR 7) and ``jax.jit(..., donate_argnums=)``
invalidate the donated argument's buffer: any later read sees freed (or
aliased) memory and XLA only sometimes warns. The correct idiom rebinds
the name from the call's result — ``state = apply(state, ...)`` — which
this rule treats as the reassignment that un-poisons the name.

The analysis is lexical and per-scope: a name passed at a donated
position becomes poisoned after the donating statement; any later load
before a rebinding is flagged. Loop bodies are processed twice so a
donation in iteration *i* poisons a read in iteration *i+1*.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set, Tuple

from fedlint.core import Finding, Rule, register_rule
from fedlint.project import assigned_names, dotted_name

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


@register_rule
class DonationAfterUse(Rule):
    """Flag reads of a name after it was passed at a donated position."""

    id = "FL002"
    name = "donation-after-use"
    description = ("a donated argument must not be referenced after the "
                   "donating call; rebind it from the call's result")

    def check(self, project) -> Iterator[Finding]:
        """Simulate each function scope against its donating wrappers."""
        for mod in project.modules.values():
            wrappers = _donating_wrappers(mod)
            if not wrappers:
                continue
            scopes = [info.node for info in mod.func_index.values()
                      if not isinstance(info.node, ast.Lambda)]
            for scope in scopes:
                sim = _Simulator(self.id, mod, wrappers)
                sim.run(scope.body)
                yield from sim.findings


def _donating_wrappers(mod) -> Dict[str, Set[int]]:
    """Names bound to donating callables -> their donated arg positions.

    Tracks both plain assignments (``apply = jit_donating_store(f, 0)``)
    and ``self.attr = ...`` bindings (checked under the textual name
    ``self.attr``, which is how methods call them).
    """
    wrappers: Dict[str, Set[int]] = {}
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.value, ast.Call)):
            continue
        target = dotted_name(node.targets[0])
        argnums = _donated_argnums(mod, node.value)
        if target and argnums:
            wrappers.setdefault(target, set()).update(argnums)
    return wrappers


def _donated_argnums(mod, call: ast.Call) -> Set[int]:
    """Donated argument positions of a wrapper-constructing call."""
    canonical = mod.call_canonical(call) or ""
    if canonical.rsplit(".", 1)[-1] == "jit_donating_store":
        if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
            if isinstance(call.args[1].value, int):
                return {call.args[1].value}
        return set()
    if canonical in ("jax.jit", "jax.pmap"):
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                return _const_ints(kw.value)
    return set()


def _const_ints(node) -> Set[int]:
    """Constant ints from an int or tuple-of-ints expression."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, ast.Tuple):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)}
    return set()


class _Simulator:
    """Linear walk of a statement list tracking poisoned (donated) names."""

    def __init__(self, rule_id: str, mod, wrappers: Dict[str, Set[int]]):
        """Track donations against ``wrappers`` in module ``mod``."""
        self.rule_id = rule_id
        self.mod = mod
        self.wrappers = wrappers
        self.poisoned: Dict[str, int] = {}   # name -> donation line
        self.findings: List[Finding] = []
        self.flagged: Set[Tuple[int, int]] = set()

    def run(self, body: List[ast.stmt]):
        """Process statements in order; loops twice for cross-iteration."""
        for stmt in body:
            self._step(stmt)

    def _step(self, stmt: ast.stmt):
        """Process one statement: loads, donations, then rebindings."""
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            self._flag_loads(stmt, exclude_bodies=True)
            for _ in range(2):
                self.run(stmt.body)
            self.run(stmt.orelse)
            return
        if isinstance(stmt, (ast.If, ast.With, ast.AsyncWith, ast.Try)):
            self._flag_loads(stmt, exclude_bodies=True)
            for field in ("body", "orelse", "finalbody"):
                self.run(getattr(stmt, field, []) or [])
            for handler in getattr(stmt, "handlers", []):
                self.run(handler.body)
            self._unpoison(stmt)
            return
        self._flag_loads(stmt)
        for name, line in self._donations(stmt):
            self.poisoned.setdefault(name, line)
        self._unpoison(stmt)

    def _donations(self, stmt) -> List[Tuple[str, int]]:
        """(name, line) pairs donated by calls inside ``stmt``."""
        out = []
        for node in _walk_scope(stmt):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            for pos in self.wrappers.get(callee or "", ()):
                if pos < len(node.args):
                    name = dotted_name(node.args[pos])
                    if name:
                        out.append((name, node.lineno))
        return out

    def _flag_loads(self, stmt, exclude_bodies: bool = False):
        """Flag loads of currently-poisoned names inside ``stmt``."""
        if not self.poisoned:
            return
        nodes = (_header_nodes(stmt) if exclude_bodies
                 else list(_walk_scope(stmt)))
        for node in nodes:
            name = dotted_name(node) if isinstance(
                node, (ast.Name, ast.Attribute)) else None
            if name in self.poisoned and _is_load(node):
                key = (node.lineno, node.col_offset)
                if key not in self.flagged:
                    self.flagged.add(key)
                    self.findings.append(Finding(
                        self.rule_id, self.mod.relpath, node.lineno,
                        node.col_offset + 1,
                        f"`{name}` is read after being donated at line "
                        f"{self.poisoned[name]}; its buffer is invalid — "
                        f"rebind it from the donating call's result"))

    def _unpoison(self, stmt):
        """Clear poison for names (re)bound by ``stmt``."""
        for name in assigned_names(stmt):
            self.poisoned.pop(name, None)


def _header_nodes(stmt) -> List:
    """Nodes of a compound statement's header (test/iter), not its body."""
    headers = []
    for field in ("test", "iter", "items"):
        val = getattr(stmt, field, None)
        if isinstance(val, ast.AST):
            headers.extend(ast.walk(val))
        elif isinstance(val, list):
            for item in val:
                headers.extend(ast.walk(item))
    return headers


def _is_load(node) -> bool:
    """True when the outermost Name of an expression is a load."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(getattr(node, "ctx", None), ast.Load)


def _walk_scope(node):
    """Walk a subtree without descending into nested function bodies."""
    stack = [node]
    while stack:
        cur = stack.pop()
        yield cur
        if not isinstance(cur, _FUNC_NODES):
            stack.extend(ast.iter_child_nodes(cur))
