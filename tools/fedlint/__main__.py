"""``python -m fedlint`` entry point."""
from fedlint.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
