"""File collection and the analyze-everything entry point."""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Tuple

from fedlint.core import Finding, all_rules, filter_suppressed
from fedlint.project import Project

#: Directories never worth descending into.
_SKIP_DIRS = frozenset({"__pycache__", ".git", ".venv", "node_modules"})


def collect_files(paths: Iterable) -> List[Path]:
    """Expand files/directories into a sorted list of ``.py`` files."""
    out = set()
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in p.rglob("*.py"):
                if not (_SKIP_DIRS & set(f.parts)):
                    out.add(f)
        elif p.suffix == ".py":
            out.add(p)
    return sorted(out)


def run_paths(paths: Iterable, select: Optional[Iterable[str]] = None,
              root: Optional[Path] = None) -> Tuple[List[Finding], Project]:
    """Analyze ``paths`` and return (suppression-filtered findings, project).

    ``select`` restricts to a subset of rule ids; ``root`` anchors the
    relative paths findings are reported under (defaults to the CWD).
    """
    root = Path(root) if root is not None else Path.cwd()
    project = Project(collect_files(paths), root)
    wanted = set(select) if select else None
    findings: List[Finding] = []
    for rule_id, rule_cls in all_rules().items():
        if wanted is None or rule_id in wanted:
            findings.extend(rule_cls().check(project))
    findings = filter_suppressed(findings, project.lines_for_path)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, project


def run(paths: Iterable, select: Optional[Sequence[str]] = None,
        root: Optional[Path] = None) -> List[Finding]:
    """Convenience wrapper returning only the findings list."""
    return run_paths(paths, select=select, root=root)[0]
