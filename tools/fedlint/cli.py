"""The ``python -m fedlint`` command-line interface."""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from fedlint.core import (SCHEMA_VERSION, all_rules, load_baseline,
                          split_baselined, write_baseline)
from fedlint.runner import run

#: Default committed baseline location (repo-root relative).
DEFAULT_BASELINE = "tools/fedlint/baseline.json"


def build_parser() -> argparse.ArgumentParser:
    """The fedlint argument parser."""
    p = argparse.ArgumentParser(
        prog="fedlint",
        description="AST-based lint for this repo's JAX invariants")
    p.add_argument("paths", nargs="*", default=["src/repro"],
                   help="files or directories to analyze")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable output")
    p.add_argument("--baseline", default=DEFAULT_BASELINE,
                   help="baseline file of grandfathered findings")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline (report everything as new)")
    p.add_argument("--write-baseline", action="store_true",
                   help="write current findings into the baseline and exit 0")
    p.add_argument("--select", default="",
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the registered rules and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    """Run the CLI; returns the process exit code."""
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule_id, cls in all_rules().items():
            print(f"{rule_id} {cls.name}: {cls.description}")
        return 0
    select = [r.strip() for r in args.select.split(",") if r.strip()] or None
    findings = run(args.paths, select=select)
    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"fedlint: wrote {len(findings)} finding(s) to "
              f"{args.baseline}")
        return 0
    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    new, old = split_baselined(findings, baseline)
    if args.as_json:
        _print_json(new, old)
    else:
        _print_human(new, old)
    return 1 if new else 0


def _print_human(new, old) -> None:
    """One line per new finding plus a summary."""
    for f in new:
        print(f"{f.path}:{f.line}:{f.col} {f.rule} {f.message}")
    total = len(new) + len(old)
    print(f"fedlint: {total} finding(s): {len(new)} new, "
          f"{len(old)} baselined")


def _print_json(new, old) -> None:
    """Machine-readable report on stdout."""
    out = {
        "version": SCHEMA_VERSION,
        "findings": ([dict(f.to_json(), baselined=False) for f in new]
                     + [dict(f.to_json(), baselined=True) for f in old]),
        "summary": {"total": len(new) + len(old), "new": len(new),
                    "baselined": len(old)},
    }
    json.dump(out, sys.stdout, indent=2)
    sys.stdout.write("\n")
