"""Finding/Rule primitives, the rule registry, suppressions, and baselines.

Everything here is analyzer-framework plumbing with no knowledge of any
specific rule: :class:`Finding` is one violation at a source location,
:class:`Rule` is the pluggable check interface, and the helpers implement
the two escape hatches — per-line ``# fedlint: disable=RULE`` comments and
the committed JSON baseline of grandfathered findings.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Sequence, Type

#: Comment markers: ``# fedlint: disable=FL001[,FL002][ -- reason]`` on the
#: finding's line or on a standalone comment line directly above it.
_DISABLE_RE = re.compile(
    r"#\s*fedlint:\s*disable=([A-Z0-9,\s]+?)(?:\s+--.*)?$")

#: Schema version stamped into baselines and ``--json`` output.
SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location (1-indexed line/col)."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    @property
    def key(self) -> str:
        """Stable baseline key: rule + path + message digest.

        Line numbers are deliberately excluded so unrelated edits above a
        grandfathered finding do not invalidate the baseline entry.
        """
        digest = hashlib.sha1(self.message.encode("utf-8")).hexdigest()[:12]
        return f"{self.rule}:{self.path}:{digest}"

    def to_json(self) -> dict:
        """Plain-dict form for ``--json`` output."""
        return dataclasses.asdict(self)


class Rule:
    """Base class for fedlint rules.

    Subclasses set ``id``/``name``/``description``, implement
    :meth:`check`, and register themselves with :func:`register_rule`.
    """

    id: str = "FL000"
    name: str = "abstract-rule"
    description: str = ""

    def check(self, project) -> Iterator[Finding]:
        """Yield findings for ``project`` (a ``fedlint.project.Project``)."""
        raise NotImplementedError


_RULES: Dict[str, Type[Rule]] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator: add ``cls`` to the global rule registry by id."""
    if cls.id in _RULES and _RULES[cls.id] is not cls:
        raise ValueError(f"duplicate fedlint rule id {cls.id!r}")
    _RULES[cls.id] = cls
    return cls


def all_rules() -> Dict[str, Type[Rule]]:
    """The registered rules keyed by id (importing the rules package)."""
    from fedlint import rules  # noqa: F401, PLC0415  (registration side effect)
    return dict(sorted(_RULES.items()))


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

def _disabled_in(line_text: str) -> frozenset:
    """Rule ids named by a ``fedlint: disable=...`` marker in one line."""
    m = _DISABLE_RE.search(line_text)
    if not m:
        return frozenset()
    return frozenset(r.strip() for r in m.group(1).split(",") if r.strip())


def suppressed_rules(lines: Sequence[str], line: int) -> frozenset:
    """Rule ids disabled for 1-indexed ``line`` of a file.

    A marker counts if it sits on the line itself or on a standalone
    comment line directly above it.
    """
    ids = set()
    if 1 <= line <= len(lines):
        ids |= _disabled_in(lines[line - 1])
        if line >= 2 and lines[line - 2].lstrip().startswith("#"):
            ids |= _disabled_in(lines[line - 2])
    return frozenset(ids)


def filter_suppressed(findings: Iterable[Finding],
                      lines_for_path) -> List[Finding]:
    """Drop findings whose line carries a matching disable marker.

    ``lines_for_path`` maps a finding path to the file's source lines.
    """
    kept = []
    for f in findings:
        lines = lines_for_path(f.path)
        if lines is None or f.rule not in suppressed_rules(lines, f.line):
            kept.append(f)
    return kept


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

def load_baseline(path) -> Dict[str, str]:
    """Read a baseline file: finding key -> grandfathered message.

    A missing file is an empty baseline; a malformed one raises so CI
    cannot silently accept garbage.
    """
    p = Path(path)
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    if not isinstance(data, dict) or "findings" not in data:
        raise ValueError(f"malformed fedlint baseline {p}: "
                         f"expected an object with a 'findings' key")
    return dict(data["findings"])


def write_baseline(path, findings: Iterable[Finding]) -> None:
    """Write every finding into the baseline file at ``path``."""
    entries = {f.key: f.message for f in findings}
    payload = {"version": SCHEMA_VERSION,
               "findings": dict(sorted(entries.items()))}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def split_baselined(findings: Sequence[Finding],
                    baseline: Dict[str, str]):
    """Partition findings into (new, baselined) against a baseline map."""
    new, old = [], []
    for f in findings:
        (old if f.key in baseline else new).append(f)
    return new, old
