"""Batched serving example: prefill a batch of prompts on a sliding-window
architecture (gemma3-family smoke config), then decode with the ring-buffer
KV cache — the decode_32k / long_500k code path at CPU scale.

  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import subprocess
import sys


def main():
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "gemma3-27b",
        "--batch", "4",
        "--prompt-len", "96",
        "--gen", "24",
    ] + sys.argv[1:]
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    raise SystemExit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
