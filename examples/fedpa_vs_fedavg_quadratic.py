"""Reproduce the paper's Fig. 1 trajectories on the toy 2D problem and print
them as a round-by-round table (plot-free container).

  PYTHONPATH=src python examples/fedpa_vs_fedavg_quadratic.py
"""
import sys

sys.path.insert(0, ".")  # allow running from the repo root

from benchmarks.fig1_quadratic import (_setup, run_fedavg, run_fedpa,
                                       run_mb_sgd)


def main():
    rounds = 300
    clients, mu = _setup()
    curves = {
        "mb-sgd": run_mb_sgd(clients, mu, rounds),
        "fedavg-k10": run_fedavg(clients, mu, rounds, 10),
        "fedavg-k100": run_fedavg(clients, mu, rounds, 100),
        "fedpa-l10": run_fedpa(clients, mu, rounds, 10),
        "fedpa-l100": run_fedpa(clients, mu, rounds, 100),
    }
    names = list(curves)
    print("round," + ",".join(names))
    for r in range(0, rounds, 25):
        print(f"{r}," + ",".join(f"{curves[n][r]:.4f}" for n in names))
    print(f"{rounds - 1}," + ",".join(f"{curves[n][-1]:.4f}" for n in names))
    print("\ndistance to the true global optimum; note FedAvg k=100 "
          "stagnating and FedPA improving with more samples (paper Fig. 1)")


if __name__ == "__main__":
    main()
