"""Lower + compile one (arch x shape) against the production mesh and print
its memory analysis and roofline terms — the single-combo view of the
multi-pod dry-run.

  PYTHONPATH=src python examples/dryrun_one.py gemma3-27b train_4k [--multi-pod]
"""
import os
import subprocess
import sys


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-27b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    extra = sys.argv[3:]
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape] + extra, env=env))


if __name__ == "__main__":
    main()
