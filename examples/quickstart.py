"""Quickstart: federated posterior averaging in ~60 lines.

Builds a heterogeneous federated least-squares problem, runs FedAvg and
FedPA through the exact same generalized federated optimization loop
(Algorithm 1 — only the client update differs), and prints the distance to
the true global optimum, which is known in closed form (Eq. 3).

Each round is ONE compiled XLA program (core/round_program.py): FedSim
stacks the cohort's batches and the clients run vmapped inside the jit —
set ``placement="sequential"``/``"chunked"`` on FedSim (or
``round_placement`` on FedConfig) to trade memory for parallelism without
changing the math.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import FedSim, global_posterior_mode
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches

D, N_CLIENTS, N_PER_CLIENT = 8, 8, 100

clients, data = make_federated_lsq(N_CLIENTS, N_PER_CLIENT, D,
                                   heterogeneity=20.0, seed=0)
mu_star = np.asarray(global_posterior_mode(clients))   # exact global optimum


def grad_fn(params, batch):
    def loss(p):
        r = batch["x"] @ p - batch["y"]
        return 0.5 * jnp.mean(r * r) * N_PER_CLIENT    # sum-scale objective
    return jax.value_and_grad(loss)(params)


def batch_fn(cid, round_idx, steps):
    X, y = data[cid]
    return lsq_batches(X, y, batch_size=25, num_steps=steps,
                       seed=round_idx * 977 + cid)


common = dict(clients_per_round=4, local_steps=300, client_opt="sgd",
              client_lr=0.002)
configs = {
    "fedavg": FedConfig(algorithm="fedavg", server_opt="sgdm",
                        server_lr=1.0, **common),
    # chunked placement: 2 clients vmapped per chunk, chunks scanned —
    # same round math as parallel, bounded peak memory
    "fedpa": FedConfig(algorithm="fedpa", burn_in_steps=100,
                       steps_per_sample=20, shrinkage_rho=1.0,
                       server_opt="sgd", server_lr=0.03,
                       round_placement="chunked", round_chunk_size=2,
                       **common),
}

for name, fed in configs.items():
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=N_CLIENTS)
    state, hist = sim.run(jnp.zeros(D), num_rounds=60)
    dist = np.linalg.norm(np.asarray(state.params) - mu_star)
    # loss_first vs loss_last: how much the final round's local runs still
    # move — the within-round progress signal that distinguishes burn-in
    # rounds from sampling rounds
    print(f"{name:7s}: final round loss {hist[-1]['loss_first']:.3f} -> "
          f"{hist[-1]['loss_last']:.3f} (first -> last local step), "
          f"distance to global optimum {dist:.4f}")

print("\nFedPA reaches a better optimum with the same local computation —")
print("the posterior-correction of client deltas in action (paper Fig. 1).")
