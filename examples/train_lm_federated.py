"""End-to-end driver: federated training of the ~100M-parameter fedlm-100m
decoder on the synthetic-token federated corpus — the production train path
(same code the 512-chip dry-run lowers) at whatever scale this host allows.

Default runs the reduced config for a CPU-friendly demonstration; pass
``--full`` on real hardware to train the honest 100M model for a few hundred
rounds.

  PYTHONPATH=src python examples/train_lm_federated.py            # smoke
  PYTHONPATH=src python examples/train_lm_federated.py --full \
      --rounds 300 --clients 8 --batch 8 --seq-len 512            # real
"""
import os
import subprocess
import sys


def main():
    args = sys.argv[1:]
    full = "--full" in args
    args = [a for a in args if a != "--full"]
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "fedlm-100m",
        "--algorithm", "fedpa",
        "--rounds", "20",
        "--clients", "4",
        "--local-steps", "8",
        "--burn-in-rounds", "5",
        "--server-lr", "0.3",
    ]
    if not full:
        cmd.append("--smoke")
    cmd += args
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    raise SystemExit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
