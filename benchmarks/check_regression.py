"""Bench regression gate: compare fresh ``BENCH_*.json`` reports against
the committed baselines in ``benchmarks/baselines/``.

Only dimensionless ratio metrics — keys containing ``speedup``,
``overhead``, ``mem_ratio``, or ``compression_ratio`` — are gated;
absolute ``*_ms``/``*_us`` timings vary too much across runners to fail
CI on. For ``speedup`` and ``compression_ratio`` keys higher is better,
for ``overhead`` and ``mem_ratio`` keys lower is better; either
direction fails when it regresses by more than ``--tolerance``
(default 20%).

Typical CI usage, after the bench lane has produced the reports::

  PYTHONPATH=src python -m benchmarks.run --only round_engine,async_engine,cohort_source,client_store,compression
  python -m benchmarks.check_regression

To refresh the baselines after an intentional perf change, rerun the
benches on a quiet machine and copy the reports over (the failure
message prints this too)::

  cp BENCH_round_engine.json BENCH_async_engine.json \
     BENCH_cohort_source.json BENCH_compression.json benchmarks/baselines/
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

#: fraction of the baseline value a gated metric may regress by
DEFAULT_TOLERANCE = 0.20

REFRESH_HINT = (
    "To refresh after an intentional perf change:\n"
    "  PYTHONPATH=src python -m benchmarks.run "
    "--only round_engine,async_engine,cohort_source,client_store,"
    "compression\n"
    "  cp BENCH_round_engine.json BENCH_async_engine.json "
    "BENCH_cohort_source.json BENCH_client_store.json "
    "BENCH_compression.json benchmarks/baselines/"
)


def flatten(report: dict, prefix: str = "") -> dict:
    """Flatten nested report sections into dotted keys
    (``fedavg.parallel_speedup``)."""
    out = {}
    for k, v in report.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten(v, prefix=key + "."))
        else:
            out[key] = v
    return out


def gated_keys(report: dict) -> list[str]:
    """Ratio-type metric names: dimensionless, stable across runners."""
    return sorted(
        k for k, v in flatten(report).items()
        if isinstance(v, (int, float))
        and ("speedup" in k or "overhead" in k or "mem_ratio" in k
             or "compression_ratio" in k)
    )


def check_report(name: str, current: dict, baseline: dict,
                 tolerance: float) -> list[str]:
    """Return failure messages for one BENCH report pair (empty = pass)."""
    failures = []
    flat_base, flat_cur = flatten(baseline), flatten(current)
    for key in gated_keys(baseline):
        base = float(flat_base[key])
        if key not in flat_cur:
            failures.append(f"{name}: metric '{key}' missing from current "
                            f"report (baseline has {base:.3f})")
            continue
        cur = float(flat_cur[key])
        if base <= 0:
            continue  # degenerate baseline: nothing meaningful to gate
        if "overhead" in key or "mem_ratio" in key:
            worse = (cur - base) / base       # overhead/mem: higher is worse
        else:
            worse = (base - cur) / base       # speedup: lower is worse
        if worse > tolerance:
            failures.append(
                f"{name}: {key} regressed {worse * 100:.1f}% "
                f"(baseline {base:.3f} -> current {cur:.3f}, "
                f"tolerance {tolerance * 100:.0f}%)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--current", default=".",
                    help="directory holding the freshly produced reports")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional regression (default 0.20)")
    args = ap.parse_args(argv)

    baseline_dir = Path(args.baselines)
    current_dir = Path(args.current)
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"check_regression: no BENCH_*.json under {baseline_dir}/ — "
              "nothing to gate", file=sys.stderr)
        return 1

    failures: list[str] = []
    checked = 0
    for base_path in baselines:
        cur_path = current_dir / base_path.name
        if not cur_path.exists():
            failures.append(f"{base_path.name}: current report not found at "
                            f"{cur_path} (bench lane did not run it?)")
            continue
        with open(base_path) as f:
            baseline = json.load(f)
        with open(cur_path) as f:
            current = json.load(f)
        keys = gated_keys(baseline)
        checked += len(keys)
        fails = check_report(base_path.name, current, baseline,
                             args.tolerance)
        status = "FAIL" if fails else "ok"
        print(f"{base_path.name}: {len(keys)} gated metric(s) ... {status}")
        failures.extend(fails)

    if failures:
        print()
        for msg in failures:
            print(f"REGRESSION: {msg}")
        print()
        print(REFRESH_HINT)
        return 1
    print(f"check_regression: {checked} metric(s) within "
          f"{args.tolerance * 100:.0f}% of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
