"""Sharded vs replicated DeviceClientStateStore at population scale.

Measures, on 8 fake host devices, what population sharding buys the
client-state store at N in {10k, 1M} clients (scaffold-sized per-client
state, ~16 floats):

* ``sharded_mem_ratio`` — the headline, gated by ``check_regression``:
  max per-device bytes of the sharded store over the total (replicated)
  footprint. With 8 devices and a divisible population this is exactly
  1/8; padding a non-divisible N can only nudge it by ``padded/N``. A
  regression here means the population axis silently stopped sharding —
  the exact failure mode the padded layout fix closed.
* cohort gather + CAS-scatter wall time, sharded vs replicated — the
  data-movement cost of keeping the population distributed
  (informational; timings are not gated).

The workload runs in a subprocess: device count locks at the first jax
import, and the other benches in ``benchmarks.run`` must keep seeing the
real (single) device. Writes ``BENCH_client_store.json`` for the CI
artifact lane.

  PYTHONPATH=src python -m benchmarks.bench_client_store [--full]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

#: population sizes per the issue contract: a 10k and a 1M-client store
#: (quick only trims the timing repeats — the gated mem ratio must come
#: from the same populations as the committed baseline)
POPULATIONS = (10_000, 1_000_000)
COHORT = 64
STATE_DIM = 16


def _worker() -> None:
    """Subprocess body: build both stores, measure, print one JSON line."""
    import time

    import jax
    import numpy as np

    from repro.core.client_state import make_client_store
    from repro.launch.mesh import make_host_mesh

    assert jax.device_count() == 8, jax.device_count()
    mesh = make_host_mesh()
    quick = os.environ.get("BENCH_QUICK", "1") == "1"
    repeats = 5 if quick else 20
    template = {"c": np.zeros((STATE_DIM,), np.float32)}
    rng = np.random.default_rng(0)
    report = {}

    def bench_ops(store, n):
        ids = np.sort(rng.choice(n, COHORT, replace=False))
        new_states = {"c": np.ones((COHORT, STATE_DIM), np.float32)}
        gather_s = scatter_s = 0.0
        for i in range(repeats + 3):
            t0 = time.perf_counter()
            states, stamps = store.gather(ids)
            jax.block_until_ready(states)
            t1 = time.perf_counter()
            store.scatter(ids, new_states, stamps)
            jax.block_until_ready(store.device_state())
            t2 = time.perf_counter()
            if i >= 3:                      # skip compile/warmup
                gather_s += t1 - t0
                scatter_s += t2 - t1
        return gather_s / repeats * 1e3, scatter_s / repeats * 1e3

    def mem_ratio(store):
        per_dev, total = {}, 0
        for leaf in jax.tree_util.tree_leaves(store.device_state()):
            total += leaf.nbytes
            for s in leaf.addressable_shards:
                per_dev[s.device] = per_dev.get(s.device, 0) + s.data.nbytes
        return max(per_dev.values()) / total

    for n in POPULATIONS:
        sharded = make_client_store("device", n, mesh=mesh).ensure(template)
        replicated = make_client_store("device", n).ensure(template)
        g_sh, s_sh = bench_ops(sharded, n)
        g_re, s_re = bench_ops(replicated, n)
        report[f"n{n}"] = {
            "sharded_mem_ratio": mem_ratio(sharded),
            "rows_per_device": sharded.padded_num_clients // 8,
            "gather_sharded_ms": g_sh, "gather_replicated_ms": g_re,
            "scatter_sharded_ms": s_sh, "scatter_replicated_ms": s_re,
        }
    print("BENCHJSON " + json.dumps(report), flush=True)


def run(quick: bool = True):
    """Spawn the 8-device worker, collect the report, emit CSV rows."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               BENCH_QUICK="1" if quick else "0")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_client_store", "--worker"],
        capture_output=True, text=True, env=env, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"client-store worker failed:\n{out.stderr[-4000:]}")
    line = next(ln for ln in out.stdout.splitlines()
                if ln.startswith("BENCHJSON "))
    report = json.loads(line[len("BENCHJSON "):])
    report["worst_mem_ratio"] = max(v["sharded_mem_ratio"]
                                    for v in report.values())
    rows = []
    for key, res in report.items():
        if not isinstance(res, dict):
            continue
        rows.append({
            "name": f"client_store/{key}",
            "us_per_call": res["gather_sharded_ms"] * 1e3,
            "derived": (f"mem_ratio={res['sharded_mem_ratio']:.4f},"
                        f"gather={res['gather_sharded_ms']:.2f}ms"
                        f"(repl {res['gather_replicated_ms']:.2f}ms),"
                        f"scatter={res['scatter_sharded_ms']:.2f}ms"
                        f"(repl {res['scatter_replicated_ms']:.2f}ms)"),
        })
    with open("BENCH_client_store.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker()
    else:
        for row in run(quick="--full" not in sys.argv):
            print(row)
