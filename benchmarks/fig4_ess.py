"""Fig. 4: effective sample size of IASG posterior samples.

Reproduces the Appendix A.2 takeaways on synthetic least squares:
more burn-in helps, more steps-per-sample helps, quality degrades with
dimensionality, and the learning rate is the sensitive knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.diagnostics import ess_from_losses
from repro.core.iasg import iasg_sample
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import sgd


def _ess(d, lr, burn_in, sps, ell=20, seed=0):
    clients, data = make_federated_lsq(1, 500, d, heterogeneity=0.0,
                                       seed=seed)
    X, y = data[0]

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r)
        return jax.value_and_grad(loss)(params)

    opt = sgd(lr)
    theta0 = jnp.zeros(d)
    batches = lsq_batches(X, y, 10, burn_in + sps * ell, seed=seed + 1)
    res = iasg_sample(theta0, opt, opt.init(theta0), grad_fn, batches,
                      burn_in, sps, ell)
    # weight samples by their (sum) loss on the full data
    losses = jnp.stack([
        0.5 * jnp.sum((X @ s - y) ** 2) for s in res.samples
    ])
    return float(ess_from_losses(losses - losses.min()))


def run(quick: bool = True):
    rows = []
    dims = (10, 100) if quick else (10, 100, 1000)
    for d in dims:
        lr = 0.1 if d <= 100 else 0.01
        for burn in (10, 200):
            e = _ess(d, lr, burn, sps=10)
            rows.append({"name": f"fig4/d={d}/burnin={burn}",
                         "us_per_call": "", "derived": f"ess={e:.2f}/20"})
        for sps in (1, 20):
            e = _ess(d, lr, 100, sps=sps)
            rows.append({"name": f"fig4/d={d}/steps_per_sample={sps}",
                         "us_per_call": "", "derived": f"ess={e:.2f}/20"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
