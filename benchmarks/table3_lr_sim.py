"""Table 3c (simulated): the StackOverflow tag-prediction (LR) task —
multi-label logistic regression over bag-of-words features, with the
paper's metrics: precision, recall@5, macro-F1, micro-F1.

Synthetic stand-in (real StackOverflow is network-gated): 50 "tags" with
Dirichlet-skewed per-client tag usage; features are noisy sums of per-tag
prototype vectors — so clients disagree about rare tags exactly like
StackOverflow users do. The paper's phenomenon of interest: FedPA trades a
little precision for better macro-F1 (rare-tag recall).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.round import FedSim

D, TAGS = 128, 50


def _make_data(num_clients=32, n_per_client=64, alpha=0.15, seed=0,
               n_test=512):
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((TAGS, D)) * 2.0
    tag_pop = rng.dirichlet(0.5 * np.ones(TAGS))  # global tag frequencies

    def sample(n, tag_p):
        ys = np.zeros((n, TAGS), np.float32)
        xs = np.zeros((n, D), np.float32)
        for i in range(n):
            k = rng.integers(1, 4)
            tags = rng.choice(TAGS, size=k, replace=False, p=tag_p)
            ys[i, tags] = 1.0
            xs[i] = protos[tags].sum(0) + rng.standard_normal(D)
        return xs, ys

    client_x, client_y = [], []
    for _ in range(num_clients):
        p = rng.dirichlet(alpha * TAGS * tag_pop)
        xs, ys = sample(n_per_client, p)
        client_x.append(xs)
        client_y.append(ys)
    tx, ty = sample(n_test, tag_pop)
    return client_x, client_y, jnp.asarray(tx), jnp.asarray(ty)


def _init(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((D, TAGS)) * 0.01,
                             jnp.float32),
            "b": jnp.zeros((TAGS,), jnp.float32)}


def _logits(params, x):
    return x @ params["w"] + params["b"]


def _grad_fn(params, batch):
    def loss(p):
        z = _logits(p, batch["x"])
        y = batch["y"]
        # sigmoid BCE
        return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
    return jax.value_and_grad(loss)(params)


def _metrics(params, tx, ty):
    z = np.asarray(_logits(params, tx))
    y = np.asarray(ty)
    pred = (z > 0).astype(np.float32)
    tp = (pred * y).sum(0)
    fp = (pred * (1 - y)).sum(0)
    fn = ((1 - pred) * y).sum(0)
    precision = tp.sum() / max(tp.sum() + fp.sum(), 1.0)
    # recall@5: fraction of true tags within the top-5 scored
    top5 = np.argsort(-z, axis=1)[:, :5]
    hits = sum(y[i, top5[i]].sum() for i in range(len(y)))
    recall5 = hits / max(y.sum(), 1.0)
    f1 = 2 * tp / np.maximum(2 * tp + fp + fn, 1.0)
    macro_f1 = f1.mean()
    micro_f1 = 2 * tp.sum() / max(2 * tp.sum() + fp.sum() + fn.sum(), 1.0)
    return dict(precision=float(precision), recall5=float(recall5),
                macro_f1=float(macro_f1), micro_f1=float(micro_f1))


def _run(algorithm, epochs, rounds, seed=0):
    client_x, client_y, tx, ty = _make_data(seed=seed)
    batch = 16
    spe = 64 // batch
    steps = epochs * spe
    kw = {}
    if algorithm == "fedpa":
        kw = dict(burn_in_steps=steps // 2, steps_per_sample=max(spe // 2, 1),
                  shrinkage_rho=0.01, burn_in_rounds=rounds // 4)
    # Adagrad server for LR, as the paper's Table 4 prescribes
    fed = FedConfig(algorithm=algorithm, clients_per_round=8,
                    local_steps=steps, server_opt="adagrad", server_lr=0.3,
                    client_opt="sgdm", client_lr=0.3, client_momentum=0.9,
                    **kw)

    def batch_fn(cid, r, n):
        rng = np.random.default_rng(r * 977 + cid)
        idx = rng.integers(0, 64, size=(n, batch))
        return {"x": jnp.asarray(client_x[cid][idx]),
                "y": jnp.asarray(client_y[cid][idx])}

    sim = FedSim(fed=fed, grad_fn=_grad_fn, batch_fn=batch_fn,
                 num_clients=len(client_x), seed=seed)
    state, _ = sim.run(_init(seed), rounds)
    return _metrics(state.params, tx, ty)


def run(quick: bool = True):
    rounds = 25 if quick else 80
    rows = []
    results = {}
    for name, alg, ep in [("fedavg_1e", "fedavg", 1),
                          ("fedavg_me", "fedavg", 5),
                          ("fedpa_me", "fedpa", 5)]:
        m = _run(alg, ep, rounds)
        results[name] = m
        rows.append({"name": f"table3lr/{name}", "us_per_call": "",
                     "derived": (f"prec={m['precision']:.3f},"
                                 f"rec@5={m['recall5']:.3f},"
                                 f"maF1={m['macro_f1']:.3f},"
                                 f"miF1={m['micro_f1']:.3f}")})
    # all methods must actually learn the task
    assert all(m["micro_f1"] > 0.3 for m in results.values()), results
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
