"""Compression benchmark: dense fedpa_precision vs fedlora payloads.

Two parts. (1) **Exact wire bytes, analytically**: per-client uplink for
the fedlm-100m decoder under the dense precision payload vs the
``lowrank`` and ``lowrank+int8`` codecs, via ``jax.eval_shape`` — no
allocation, so the ratios are exact and runner-independent. The
``*_compression_ratio`` headline metrics are gated by
``check_regression`` (higher is better). (2) **Simulated cost**: round
wall time and final loss for dense vs compressed on a heterogeneous
matrix-LSQ problem — the compression math (QR sketch + quantize) rides
inside the jitted round, so ``*_ms`` shows its overhead and ``loss_gap``
what the payload diet costs in quality. Timings are informational only.

Writes ``BENCH_compression.json`` next to the CWD for the CI artifact
lane.

  PYTHONPATH=src python -m benchmarks.bench_compression [--full]
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import round_bytes
from repro.configs import fedlm_100m
from repro.configs.base import FedConfig
from repro.core import FedSim
from repro.models.model import abstract_params

CLIENTS = 8


def _wire_bytes() -> dict:
    """Exact per-client uplink bytes for fedlm-100m, per codec."""
    params = abstract_params(fedlm_100m.config())
    kw = dict(clients_per_round=CLIENTS, local_steps=12, burn_in_steps=4,
              steps_per_sample=2, shrinkage_rho=0.3)
    dense = round_bytes(FedConfig(algorithm="fedpa_precision", **kw),
                        params)
    out = {"dense_up_mb": dense["bytes_up_per_client"] / 2**20}
    for label, codec in (("lowrank", "lowrank"),
                         ("lowrank_int8", "lowrank+int8")):
        fed = FedConfig(algorithm="fedlora", payload_codec=codec,
                        lora_rank=4, **kw)
        rb = round_bytes(fed, params)
        out[f"{label}_up_mb"] = rb["bytes_up_per_client"] / 2**20
        out[f"{label}_compression_ratio"] = (
            dense["bytes_up_per_client"] / rb["bytes_up_per_client"])
    return out


def _sim(rounds: int, din: int, dout: int) -> dict:
    """Round time + final loss, dense vs lowrank+int8, same LSQ problem."""
    n = 64
    rng = np.random.RandomState(0)
    w_true = rng.randn(din, dout).astype(np.float32)
    data = {}
    for cid in range(CLIENTS):
        shift = rng.randn(din, dout).astype(np.float32) * 0.5
        x = rng.randn(n, din).astype(np.float32)
        y = x @ (w_true + shift) + 0.1 * rng.randn(n, dout).astype(
            np.float32)
        data[cid] = (jnp.asarray(x), jnp.asarray(y))

    def grad_fn(params, batch):
        def loss(p):
            r = batch["x"] @ p["w"] - batch["y"]
            return 0.5 * jnp.mean(r * r)
        return jax.value_and_grad(loss)(params)

    def batch_fn(cid, r, steps):
        x, y = data[cid]
        rs = np.random.RandomState(r * 131 + cid)
        idx = rs.randint(0, n, size=(steps, 16))
        return {"x": x[idx], "y": y[idx]}

    def final_loss(state):
        tot = 0.0
        for cid in data:
            x, y = data[cid]
            r = x @ state.params["w"] - y
            tot += float(0.5 * jnp.mean(r * r))
        return tot / len(data)

    kw = dict(clients_per_round=CLIENTS, local_steps=12, burn_in_steps=4,
              steps_per_sample=2, shrinkage_rho=0.3, burn_in_rounds=2,
              server_opt="sgd", server_lr=0.5, client_opt="sgd",
              client_lr=0.05)
    feds = {
        "dense": FedConfig(algorithm="fedpa_precision", **kw),
        "lowrank_int8": FedConfig(algorithm="fedlora",
                                  payload_codec="lowrank+int8",
                                  lora_rank=4, **kw),
    }
    out = {}
    for label, fed in feds.items():
        sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                     num_clients=CLIENTS)
        state = sim.init({"w": jnp.zeros((din, dout))})
        state, _ = sim.round(state, 0)            # warm-up / compile
        jax.block_until_ready(state.params["w"])
        t0 = time.perf_counter()
        for r in range(1, rounds):
            state, _ = sim.round(state, r)
        jax.block_until_ready(state.params["w"])
        out[f"{label}_ms"] = (time.perf_counter() - t0) / (rounds - 1) * 1e3
        out[f"{label}_final_loss"] = final_loss(state)
    out["loss_gap"] = (out["lowrank_int8_final_loss"]
                       / out["dense_final_loss"] - 1.0)
    return out


def run(quick: bool = True):
    """quick: 20-round LSQ sim; full: 50 rounds on a bigger matrix."""
    rounds, din, dout = (20, 32, 16) if quick else (50, 128, 64)
    report = {"model": "fedlm-100m", "clients_per_round": CLIENTS,
              "wire": _wire_bytes(), "sim": _sim(rounds, din, dout)}
    wire, sim = report["wire"], report["sim"]
    rows = [
        {"name": "compression/fedlm_100m_wire",
         "us_per_call": "",
         "derived": (f"dense={wire['dense_up_mb']:.1f}MB/client,"
                     f"lowrank={wire['lowrank_up_mb']:.1f}MB"
                     f"({wire['lowrank_compression_ratio']:.1f}x),"
                     f"+int8={wire['lowrank_int8_up_mb']:.1f}MB"
                     f"({wire['lowrank_int8_compression_ratio']:.1f}x)")},
        {"name": "compression/lsq_round",
         "us_per_call": sim["dense_ms"] * 1e3,
         "derived": (f"dense={sim['dense_ms']:.1f}ms,"
                     f"lowrank+int8={sim['lowrank_int8_ms']:.1f}ms,"
                     f"loss_gap={sim['loss_gap'] * 100:+.1f}%")},
    ]
    with open("BENCH_compression.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    import sys
    for row in run(quick="--full" not in sys.argv):
        print(row)
