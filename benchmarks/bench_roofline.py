"""Roofline bench: renders the §Roofline table from the dry-run artifacts
(dryrun_single.jsonl / dryrun_multi.jsonl at the repo root). The dry-run
itself is launched separately (launch/dryrun.py) because it needs 512
placeholder devices; this bench only aggregates."""
from __future__ import annotations

import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_records():
    recs = []
    for fn in ("dryrun_single.jsonl", "dryrun_multi.jsonl"):
        path = os.path.join(ROOT, fn)
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    recs.append(json.loads(line))
    return recs


def run(quick: bool = True):
    recs = load_records()
    rows = []
    if not recs:
        return [{"name": "roofline/no-dryrun-artifacts", "us_per_call": "",
                 "derived": "run launch/dryrun.py first"}]
    for r in recs:
        name = f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
        if r.get("roofline"):
            rf = r["roofline"]
            dom = rf["dominant"]
            rows.append({
                "name": name, "us_per_call": "",
                "derived": (f"compute={rf['compute_s']:.2e}s,"
                            f"memory={rf['memory_s']:.2e}s,"
                            f"collective={rf['collective_s']:.2e}s,"
                            f"dominant={dom},"
                            f"useful={rf['useful_ratio']:.2f}"),
            })
        else:
            rows.append({"name": name, "us_per_call": "",
                         "derived": str(r.get("status", ""))[:80]})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
