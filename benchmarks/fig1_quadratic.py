"""Fig. 1: the toy 2D two-client federated quadratic.

MB-SGD converges (slowly) to the global optimum; FedAvg with 10/100 local
steps stagnates at biased fixed points (more steps = worse); FedPA with
10/100 posterior samples per round converges closer with MORE local
computation (rho = 1, exact local posterior sampling as in the paper's toy).
Outputs distance-to-optimum at the final round per method.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (aggregate_deltas_list, dp_delta,
                        global_posterior_mode)
from repro.core.server import init_server_state, server_update
from repro.data import make_federated_lsq
from repro.optim import sgd, sgdm


def _setup(seed=3):
    clients, data = make_federated_lsq(2, 50, 2, heterogeneity=40.0,
                                       seed=seed)
    mu = np.asarray(global_posterior_mode(clients))
    return clients, mu


def _dist(theta, mu):
    return float(np.linalg.norm(np.asarray(theta) - mu))


def run_mb_sgd(clients, mu, rounds, lr=5e-4):
    theta = jnp.zeros(2)
    traj = []
    for _ in range(rounds):
        g = sum(c.weight * c.grad(theta) for c in clients)
        theta = theta - lr * g
        traj.append(_dist(theta, mu))
    return traj


def run_fedavg(clients, mu, rounds, local_steps, client_lr=5e-4,
               server_lr=1.0):
    opt = sgdm(server_lr, 0.9)
    st = init_server_state(jnp.zeros(2), opt)
    traj = []
    eye = jnp.eye(2)
    for _ in range(rounds):
        deltas = []
        for c in clients:
            m = eye - jnp.linalg.matrix_power(eye - client_lr * c.sigma_inv,
                                              local_steps)
            deltas.append(m @ (st.params - c.mu))   # exact K-step GD delta
        st = server_update(st, aggregate_deltas_list(deltas), opt)
        traj.append(_dist(st.params, mu))
    return traj


def run_fedpa(clients, mu, rounds, ell, rho=1.0, server_lr=0.02, seed=0):
    rng = np.random.default_rng(seed)
    opt = sgd(server_lr)
    st = init_server_state(jnp.zeros(2), opt)
    dp = jax.jit(lambda x0, xs: dp_delta(x0, xs, rho))
    covs = [np.linalg.cholesky(np.linalg.inv(np.asarray(c.sigma_inv,
                                                        np.float64)))
            for c in clients]
    traj = []
    for _ in range(rounds):
        deltas = []
        for c, L in zip(clients, covs):
            z = rng.standard_normal((ell, 2))
            xs = jnp.asarray(np.asarray(c.mu)[None] + z @ L.T, jnp.float32)
            deltas.append(dp(st.params, xs))
        st = server_update(st, aggregate_deltas_list(deltas), opt)
        traj.append(_dist(st.params, mu))
    return traj


def run(quick: bool = True):
    rounds = 300 if quick else 800
    clients, mu = _setup()
    rows = []
    for name, traj in [
        ("mb_sgd", run_mb_sgd(clients, mu, rounds)),
        ("fedavg_k10", run_fedavg(clients, mu, rounds, 10)),
        ("fedavg_k100", run_fedavg(clients, mu, rounds, 100)),
        ("fedpa_l10", run_fedpa(clients, mu, rounds, 10)),
        ("fedpa_l100", run_fedpa(clients, mu, rounds, 100)),
    ]:
        rows.append({"name": f"fig1/{name}", "us_per_call": "",
                     "derived": f"final_dist={traj[-1]:.4f}"})
    # the paper's orderings, asserted
    d = {r["name"].split("/")[1]: float(r["derived"].split("=")[1])
         for r in rows}
    assert d["fedavg_k100"] > d["fedavg_k10"] * 0.9, d   # more K hurts FedAvg
    assert d["fedpa_l100"] < d["fedpa_l10"], d           # more l helps FedPA
    assert d["fedpa_l100"] < d["fedavg_k100"], d
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
