"""Fig. 3: empirical bias/variance of client deltas vs local computation.

On synthetic least-squares problems (exact Delta_i = Sigma_i^{-1}(theta-mu_i)
analytic), the paper's three panels:

  (a) FedAvg: variance shrinks with more local steps but the bias never
      vanishes — more local computation cannot fix FedAvg.
  (b) FedPA: bias shrinks as the number of posterior samples grows. The
      estimator-side claim is isolated with exact Gaussian posterior samples
      (the paper's toy regime); the IASG-sampled variant is reported too,
      with its documented sensitivity to the client lr (Appendix A.2: "the
      learning rate is the most sensitive and important hyperparameter" —
      untuned lr inflates the sample covariance mismatch).
  (c) FedPA: the shrinkage rho trades bias against variance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.diagnostics import bias_variance
from repro.core.dp_delta import dp_delta
from repro.core.iasg import iasg_sample, sgd_steps
from repro.core.shrinkage import dense_delta
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import sgd

D = 10


def _problem(seed=0):
    clients, data = make_federated_lsq(1, 500, D, heterogeneity=5.0,
                                       seed=seed)
    c = clients[0]
    X, y = data[0]
    theta0 = jnp.asarray(np.random.default_rng(seed + 1).normal(size=D),
                         jnp.float32)
    exact = np.asarray(c.exact_delta(theta0))      # sum-scale Sigma^{-1}(th-mu)
    return c, X, y, theta0, exact


def _grad_fn():
    def fn(params, batch):
        def loss(p):
            r = batch["x"] @ p - batch["y"]
            return 0.5 * jnp.mean(r * r) * 500     # sum-scale objective
        return jax.value_and_grad(loss)(params)
    return fn


def fedavg_bias_var(local_steps, n_trials=8, seed=0, lr=1e-3):
    c, X, y, theta0, exact = _problem(seed)
    grad_fn = _grad_fn()
    opt = sgd(lr / 500)
    ests = []
    for t in range(n_trials):
        batches = lsq_batches(X, y, 10, local_steps, seed=seed * 100 + t)
        final, _, _ = sgd_steps(theta0, opt, opt.init(theta0), grad_fn,
                                batches)
        ests.append(np.asarray(theta0 - final))
    b, v = bias_variance(jnp.asarray(np.stack(ests)), jnp.asarray(exact))
    s = np.linalg.norm(exact)
    return float(b) / s, float(v) / s**2


def fedpa_exact_bias(ell, n_trials=8, seed=0, rho=1.0):
    """Estimator-side Fig. 3b: exact N(mu, Sigma) posterior samples."""
    c, X, y, theta0, exact = _problem(seed)
    rng = np.random.default_rng(seed + 7)
    cov = np.linalg.inv(np.asarray(c.sigma_inv, np.float64))
    L = np.linalg.cholesky(cov)
    # dense oracle == the DP (tests/test_dp_delta.py); O(d^3) with d=10 is
    # instant, while the l=1000 DP would trace ~500k ops
    dense = jax.jit(lambda xs: dense_delta(theta0, xs, rho))
    ests = []
    for _ in range(n_trials):
        z = rng.standard_normal((ell, D))
        xs = jnp.asarray(np.asarray(c.mu)[None] + z @ L.T, jnp.float32)
        ests.append(np.asarray(dense(xs)))
    b, v = bias_variance(jnp.asarray(np.stack(ests)), jnp.asarray(exact))
    s = np.linalg.norm(exact)
    return float(b) / s, float(v) / s**2


def fedpa_iasg_bias_var(local_steps, rho, n_trials=8, seed=0, lr=1e-3):
    c, X, y, theta0, exact = _problem(seed)
    grad_fn = _grad_fn()
    opt = sgd(lr / 500)
    burn = local_steps // 2
    sps = 10
    ell = max((local_steps - burn) // sps, 1)
    ests = []
    for t in range(n_trials):
        batches = lsq_batches(X, y, 10, local_steps, seed=seed * 100 + t)
        res = iasg_sample(theta0, opt, opt.init(theta0), grad_fn, batches,
                          burn, sps, ell)
        ests.append(np.asarray(dp_delta(theta0, res.samples, rho)))
    b, v = bias_variance(jnp.asarray(np.stack(ests)), jnp.asarray(exact))
    s = np.linalg.norm(exact)
    return float(b) / s, float(v) / s**2


def run(quick: bool = True):
    rows = []
    # (a) FedAvg: variance decreases, bias persists
    fa = {k: fedavg_bias_var(k) for k in (100, 1000)}
    for k, (b, v) in fa.items():
        rows.append({"name": f"fig3/fedavg/steps={k}", "us_per_call": "",
                     "derived": f"bias={b:.4f},var={v:.2e}"})
    assert fa[1000][1] <= fa[100][1] * 1.5, fa            # variance down-ish
    assert fa[1000][0] > 0.5 * fa[100][0], fa             # bias persists

    # (b) FedPA: bias vanishes with more posterior samples (exact sampling)
    fp = {l: fedpa_exact_bias(l) for l in (10, 100, 1000)}
    for l, (b, v) in fp.items():
        rows.append({"name": f"fig3/fedpa_exact/l={l}", "us_per_call": "",
                     "derived": f"bias={b:.4f},var={v:.2e}"})
    assert fp[1000][0] < fp[100][0] < fp[10][0], fp

    # (b') IASG-sampled FedPA at a fixed modest l (reported; lr-sensitive)
    bi, vi = fedpa_iasg_bias_var(100, rho=0.01)
    rows.append({"name": "fig3/fedpa_iasg/steps=100", "us_per_call": "",
                 "derived": f"bias={bi:.4f},var={vi:.2e}"})

    # (c) shrinkage rho trades bias for variance
    sweep = {r: fedpa_iasg_bias_var(100, rho=r) for r in (0.001, 0.01, 0.1)}
    for r, (b, v) in sweep.items():
        rows.append({"name": f"fig3/fedpa_iasg/rho={r}", "us_per_call": "",
                     "derived": f"bias={b:.4f},var={v:.2e}"})
    assert sweep[0.1][1] >= sweep[0.001][1], sweep        # variance up with rho
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
