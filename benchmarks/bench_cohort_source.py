"""Thread vs process cohort prefetcher on a decode-bound input pipeline
(data/prefetch.py).

The workload models the cross-device input path where host-side decode —
not device compute — is the largest pipeline stage: the builder runs a
chain of elementwise numpy passes over a scratch buffer (elementwise
ufuncs never release the GIL, unlike BLAS calls) before emitting the
cohort's stacked batches, and the consumer replays a round loop's
dispatch/device-wait interleave (short GIL-holding dispatch slices
separated by GIL-released device waits, the shape of jit dispatch plus
blocking metric syncs). Three lanes over the same rounds, best-of-
``TRIALS`` per lane to shed scheduler noise:

* ``inline``  — no prefetcher: decode serialized into the round loop;
* ``thread``  — ``CohortPrefetcher``: decode overlaps device waits but
  shares the GIL with the loop's dispatch work;
* ``process`` — ``ProcessCohortPrefetcher``: decode runs behind a fork
  and cohorts arrive through the shared-memory arena (one memcpy per
  round at ``get()``).

Both prefetchers must beat ``inline`` (the decode leaves the critical
path), and ``process_speedup_vs_thread`` is the gated headline: the arena
reader must be at least as fast as the GIL-sharing thread backend on this
decode-bound config. On multi-core hosts the arena genuinely overlaps
GIL-bound decode with the loop's own Python and the margin grows; on a
single-core host every backend time-shares one CPU, so the expected
margin is parity — the headline then checks that the arena's copy + IPC
overhead stays amortized below the thread backend's GIL handoff cost.
Writes ``BENCH_cohort_source.json`` for the CI artifact + regression
lane.

  PYTHONPATH=src python -m benchmarks.bench_cohort_source [--full]
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.data.prefetch import Cohort, make_prefetcher

CLIENTS = 16
TRIALS = 3
#: Dispatch/device-wait interleaves per round (jit dispatch + metric sync).
DISPATCHES = 6
DEVICE_WAIT_S = 0.003


def _make_build_fn(n_local, dim, steps, batch, scratch_elems, passes):
    """Decode-bound cohort builder: GIL-holding numpy passes + gather."""
    rng = np.random.default_rng(0)
    scratch = rng.random(scratch_elems).astype(np.float32)
    client_u8 = [rng.integers(0, 256, size=(n_local, dim), dtype=np.uint8)
                 for _ in range(CLIENTS)]

    def build(r):
        step_rng = np.random.default_rng(r)
        s = scratch.copy()
        for _ in range(passes):
            # elementwise ufuncs on a multi-MB buffer: atomic, GIL-held
            s = s * 1.0001 + 0.0001
        xs = []
        for cid in range(CLIENTS):
            idx = step_rng.integers(0, n_local, size=(steps, batch))
            xs.append(client_u8[cid][idx].astype(np.float32) / 255.0)
        # checksum leaf ties the scratch passes into the shipped cohort so
        # the decode work cannot be dead-code-skipped by a future refactor
        return Cohort(r, np.arange(CLIENTS),
                      {"x": np.stack(xs), "chk": s[:4].copy()}, None)

    return build


def _dispatch_slice(n):
    """~0.5ms of small-op Python: the GIL-holding side of a jit dispatch."""
    acc = np.zeros(4)
    for i in range(n):
        acc = acc + i
    return float(acc[0])


def _consume(cohort, dispatch_n):
    """One round's consumer side: touch the batches, then interleave
    dispatch slices with GIL-released device waits."""
    total = float(cohort.batches["x"][0, 0, 0].sum())
    for _ in range(DISPATCHES):
        _dispatch_slice(dispatch_n)
        time.sleep(DEVICE_WAIT_S)
    return total


def _lane(backend, build, rounds, dispatch_n):
    """Best-of-``TRIALS`` mean per-round wall-clock (ms) for one lane."""
    best = float("inf")
    for _ in range(TRIALS):
        if backend == "inline":
            t0 = time.perf_counter()
            for r in range(rounds):
                _consume(build(r), dispatch_n)
            best = min(best, (time.perf_counter() - t0) / rounds * 1e3)
            continue
        with make_prefetcher(backend, build, 0, rounds, depth=2) as p:
            _consume(p.get(0), dispatch_n)   # spin-up: fork/thread + fill
            t0 = time.perf_counter()
            for r in range(1, rounds):
                _consume(p.get(r), dispatch_n)
            best = min(best,
                       (time.perf_counter() - t0) / (rounds - 1) * 1e3)
    return best


def run(quick: bool = True):
    """quick: the CI operating point; full: heavier decode + more rounds."""
    if quick:
        rounds, n_local, dim, steps, batch = 50, 2048, 64, 8, 16
        scratch_elems, passes, dispatch_n = 2_000_000, 10, 300
    else:
        rounds, n_local, dim, steps, batch = 100, 4096, 128, 8, 32
        scratch_elems, passes, dispatch_n = 4_000_000, 20, 600

    build = _make_build_fn(n_local, dim, steps, batch, scratch_elems, passes)
    t0 = time.perf_counter()
    build(0)
    decode_ms = (time.perf_counter() - t0) * 1e3

    report = {"clients_per_round": CLIENTS, "rounds": rounds,
              "decode_passes": passes, "dispatches": DISPATCHES,
              "decode_ms": decode_ms}
    for lane in ("inline", "thread", "process"):
        report[f"{lane}_ms"] = _lane(lane, build, rounds, dispatch_n)
    report["thread_speedup_vs_inline"] = (report["inline_ms"]
                                          / report["thread_ms"])
    report["process_speedup_vs_inline"] = (report["inline_ms"]
                                           / report["process_ms"])
    # the headline: the arena reader must not trail the thread backend on
    # a decode-bound pipeline
    report["process_speedup_vs_thread"] = (report["thread_ms"]
                                           / report["process_ms"])
    with open("BENCH_cohort_source.json", "w") as f:
        json.dump(report, f, indent=2)
    return [{
        "name": "cohort_source/decode_bound",
        "us_per_call": report["inline_ms"] * 1e3,
        "derived": (f"inline={report['inline_ms']:.1f}ms,"
                    f"thread={report['thread_ms']:.1f}ms,"
                    f"process={report['process_ms']:.1f}ms"
                    f"({report['process_speedup_vs_thread']:.2f}x vs thread)"),
    }]


if __name__ == "__main__":
    import sys
    for row in run(quick="--full" not in sys.argv):
        print(row)
