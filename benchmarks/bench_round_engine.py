"""Round-engine microbenchmark: old per-client Python loop vs the unified
compiled round (core/round_program.py), on the EMNIST CNN config at 16
clients/round.

The legacy baseline reproduces the pre-engine ``FedSim.round`` exactly: one
jitted client-update dispatch per client with a blocking per-client loss
sync, then eager (un-jitted) list aggregation and an eager server update.
The engine lane drives the unified ``core.engine.RoundEngine`` round loop
(window=1, fused backend) over the identical round math compiled as ONE
jitted program per round (placements: vmap over clients / scan-of-vmap
chunks) — the loop that ``FedSim``/``launch.train`` run in production,
history recording included. Cohort batches for all timed rounds are
pre-generated so both paths time the round itself, not the (identical)
data pipeline.

Quick mode uses the smoke-scale EMNIST CNN in the paper's cross-device
regime (small per-client datasets => a handful of local steps per round),
which is where per-client dispatch overhead dominates and the engine's win
is largest; ``--full``/(quick=False) scales up to the 28x28 model with more
local compute, where the two paths converge toward pure compute time on
CPU hosts. Writes ``BENCH_round_engine.json`` next to the CWD for the CI
artifact lane.

  PYTHONPATH=src python -m benchmarks.bench_round_engine [--full]
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.emnist_cnn import config as cnn_full, smoke as cnn_smoke
from repro.core.client import make_client_update
from repro.core.engine import RoundEngine
from repro.core.round_program import make_round_program
from repro.core.server import (aggregate_deltas_list, init_server_state,
                               server_update)
from repro.data.dirichlet import make_dirichlet_classification
from repro.data.prefetch import Cohort
from repro.models.cnn import cnn_loss, init_cnn_params
from repro.optim import get_optimizer

CLIENTS = 16
PLACEMENTS = ("parallel", "chunked")


def _cohort_batches(fc, rounds, batch_size, steps, seed=0):
    """(rounds, C, K, B, d) feature / (rounds, C, K, B) label arrays."""
    rng = np.random.default_rng(seed)
    d = fc.client_x[0].shape[1]
    xs = np.empty((rounds, CLIENTS, steps, batch_size, d), np.float32)
    ys = np.empty((rounds, CLIENTS, steps, batch_size), np.int32)
    for r in range(rounds):
        for c in range(CLIENTS):
            n = fc.client_x[c].shape[0]
            idx = rng.integers(0, n, size=(steps, batch_size))
            xs[r, c] = fc.client_x[c][idx]
            ys[r, c] = fc.client_y[c][idx]
    return xs, ys


def _bench_one(cfg, fed, rounds, batch_size, seed=0):
    side = cfg.image_size
    fc = make_dirichlet_classification(
        CLIENTS, cfg.num_classes, side * side, n_per_client=64, alpha=0.1,
        proto_scale=1.5, noise=1.5, seed=seed)
    reshape = lambda x: x.reshape(-1, side, side, 1)

    def grad_fn(params, batch):
        b = {"x": reshape(batch["x"]), "y": batch["y"]}
        return jax.value_and_grad(lambda p: cnn_loss(p, b, cfg))(params)

    xs, ys = _cohort_batches(fc, rounds + 1, batch_size, fed.local_steps,
                             seed)
    client_opt = get_optimizer(fed.client_opt, fed.client_lr,
                               fed.client_momentum)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    params = init_cnn_params(jax.random.PRNGKey(seed), cfg)
    state0 = init_server_state(params, server_opt)

    # --- legacy: the pre-engine FedSim.round, verbatim ---------------------
    update = jax.jit(make_client_update(grad_fn, fed, client_opt))

    def legacy_round(state, r):
        deltas, losses = [], []
        for c in range(CLIENTS):
            res = update(state.params,
                         {"x": xs[r, c], "y": ys[r, c]})
            deltas.append(res.payload)
            # blocking per-client sync
            losses.append(float(res.metrics["loss_last"]))
        mean_delta = aggregate_deltas_list(deltas)
        return server_update(state, mean_delta, server_opt)

    def timed(round_fn):
        state = round_fn(state0, 0)                # warm-up / compile
        jax.block_until_ready(state.params)
        t0 = time.perf_counter()
        for r in range(1, rounds + 1):
            state = round_fn(state, r)
        jax.block_until_ready(state.params)
        return (time.perf_counter() - t0) / rounds * 1e3

    out = {"legacy_ms": timed(legacy_round)}

    # --- engine: the unified round loop, one jitted dispatch per round -----
    for place in PLACEMENTS:
        engine = RoundEngine(round_fn=make_round_program(
            grad_fn, fed, placement=place, server_opt=server_opt))

        def run_engine(n, lo, engine=engine):
            state, _ = engine.run(
                state0,
                lambda i: Cohort(i, None, {"x": xs[lo + i],
                                           "y": ys[lo + i]}), n)
            return state

        state = run_engine(1, 0)                  # warm-up / compile
        jax.block_until_ready(state.params)
        t0 = time.perf_counter()
        state = run_engine(rounds, 1)
        jax.block_until_ready(state.params)
        out[f"{place}_ms"] = (time.perf_counter() - t0) / rounds * 1e3
        out[f"{place}_speedup"] = out["legacy_ms"] / out[f"{place}_ms"]
    out["best_speedup"] = max(out[f"{p}_speedup"] for p in PLACEMENTS)
    return out


def run(quick: bool = True):
    """quick: smoke EMNIST CNN in the dispatch-bound cross-device regime;
    full: the 28x28 model with a compute-heavier local run."""
    if quick:
        cfg, rounds = cnn_smoke(), 10
        grid = [("fedavg", 2, 2, {}),
                ("fedpa", 4, 2,
                 dict(burn_in_steps=2, steps_per_sample=1,
                      shrinkage_rho=0.01))]
    else:
        cfg, rounds = cnn_full(), 5
        grid = [("fedavg", 8, 16, {}),
                ("fedpa", 8, 16,
                 dict(burn_in_steps=4, steps_per_sample=2,
                      shrinkage_rho=0.01))]

    rows, report = [], {"config": cfg.name, "clients_per_round": CLIENTS}
    for alg, steps, batch, kw in grid:
        fed = FedConfig(algorithm=alg, clients_per_round=CLIENTS,
                        local_steps=steps, server_opt="sgdm", server_lr=0.3,
                        client_opt="sgdm", client_lr=0.01, **kw)
        res = _bench_one(cfg, fed, rounds, batch)
        report[alg] = res
        derived = (f"legacy={res['legacy_ms']:.1f}ms," +
                   ",".join(f"{p}={res[f'{p}_ms']:.1f}ms"
                            f"({res[f'{p}_speedup']:.2f}x)"
                            for p in PLACEMENTS))
        rows.append({"name": f"round_engine/{alg}_{cfg.name}",
                     "us_per_call": res["legacy_ms"] * 1e3,
                     "derived": derived})
    with open("BENCH_round_engine.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    import sys
    for row in run(quick="--full" not in sys.argv):
        print(row)
