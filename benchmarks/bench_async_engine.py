"""Sync vs async round throughput on the EMNIST CNN config at 16
clients/round — both lanes drive the unified ``core.engine.RoundEngine``
through ``FedSim``.

The sync baseline is the engine's window=1 fused path: per-round host-side
cohort fetch + decode + batch stacking, then one fused jitted round
dispatch. The async path is the same ``FedSim`` with
``fed.async_rounds=True``: cohort t+1's client compute is dispatched
before round t's server update lands (``max_staleness=1``, deltas
discounted by ``staleness_discount**s``) and the input pipeline runs on a
prefetch thread. Both lanes keep metrics on device until the loop's single
end-of-history sync — the old sync loop's blocking per-round metrics sync
is gone, so the async speedup here is the input-pipeline overlap alone
(expect ratios near 1 on a lone CPU device, where the split backend's two
dispatches offset the overlap; the gate pins that the overhead does not
grow).

The host-bound part of the pipeline is modeled explicitly: clients hold
raw uint8 images behind a store with ``FETCH_MS`` of per-client read
latency (federated datasets live in LevelDB / HDF5 / remote stores — the
fetch is an I/O wait, which is exactly what the prefetch thread hides
behind device compute), and the round's batches are decoded to normalized
float on the host each round. In this dispatch/host-bound cross-device
regime (smoke-scale CNN, a handful of local steps per round — the paper's
own operating point) the async pipeline removes the serialized
fetch/decode from the critical path; in the compute-bound ``--full``
regime both paths converge toward pure device time. Writes
``BENCH_async_engine.json`` for the CI artifact lane.

A third, *stateful* lane runs SCAFFOLD (per-client control variates)
through the same async pipeline with both client-state placements: the
host ``ClientStateStore`` pays one blocking device sync per round at
scatter time (the write-back pulls the stacked state updates to numpy),
while the ``DeviceClientStateStore`` keeps the gather/CAS-scatter inside
the jitted programs — its per-round time should sit within noise of the
*stateless* async path, demonstrating the sync is gone.

  PYTHONPATH=src python -m benchmarks.bench_async_engine [--full]
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.emnist_cnn import config as cnn_full
from repro.configs.emnist_cnn import smoke as cnn_smoke
from repro.core import FedSim
from repro.data.dirichlet import make_dirichlet_classification
from repro.models.cnn import cnn_loss, init_cnn_params

CLIENTS = 16
#: Per-client read latency of the simulated federated dataset store (ms).
#: An I/O wait, not compute — it releases the GIL, so the prefetch thread
#: genuinely overlaps it with device rounds.
FETCH_MS = 1.0


def _make_problem(cfg, n_local, batch_size, seed=0):
    """(grad_fn, batch_fn, params) for the simulated-store CNN workload."""
    side = cfg.image_size
    fc = make_dirichlet_classification(
        CLIENTS, cfg.num_classes, side * side, n_per_client=n_local,
        alpha=0.1, proto_scale=1.5, noise=1.5, seed=seed)
    # clients hold raw uint8 images (the on-disk / on-device format); the
    # float pixels exist only round-to-round, as in a real input pipeline
    client_u8 = [np.clip((x - x.min()) / (np.ptp(x) + 1e-6) * 255,
                         0, 255).astype(np.uint8) for x in fc.client_x]
    reshape = lambda x: x.reshape(-1, side, side, 1)

    def grad_fn(params, batch):
        b = {"x": reshape(batch["x"]), "y": batch["y"]}
        return jax.value_and_grad(lambda p: cnn_loss(p, b, cfg))(params)

    def batch_fn(cid, r, steps):
        # the per-round host-side input pipeline the prefetcher overlaps:
        # fetch the client's examples from the store (I/O latency), decode
        # uint8 -> normalized float, reshuffle, and materialize the round's
        # (K, B, d) arrays
        time.sleep(FETCH_MS * 1e-3)
        rng = np.random.default_rng(r * 977 + cid)
        x = client_u8[cid].astype(np.float32)
        x = (x / 255.0 - 0.1307) / 0.3081
        idx = rng.permutation(x.shape[0])[: steps * batch_size]
        idx = idx.reshape(steps, batch_size)
        return {"x": x[idx], "y": fc.client_y[cid][idx]}

    return grad_fn, batch_fn, init_cnn_params(jax.random.PRNGKey(seed), cfg)


def _timed(sim, params, rounds):
    """Mean per-round wall-clock (ms) after a compile/spin-up warm-up."""
    state, _ = sim.run(params, 3)      # warm-up: compile + thread spin-up
    jax.block_until_ready(state.params)
    t0 = time.perf_counter()
    state, _ = sim.run(params, rounds)
    jax.block_until_ready(state.params)
    return (time.perf_counter() - t0) / rounds * 1e3


def _bench_one(cfg, fed, rounds, batch_size, n_local, seed=0):
    grad_fn, batch_fn, params = _make_problem(cfg, n_local, batch_size, seed)
    sync_sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                      num_clients=CLIENTS, seed=seed)
    afed = dataclasses.replace(fed, async_rounds=True, max_staleness=1,
                               staleness_discount=0.9, prefetch_rounds=2)
    async_sim = FedSim(fed=afed, grad_fn=grad_fn, batch_fn=batch_fn,
                       num_clients=CLIENTS, seed=seed)
    out = {"sync_ms": _timed(sync_sim, params, rounds),
           "async_ms": _timed(async_sim, params, rounds)}
    out["speedup"] = out["sync_ms"] / out["async_ms"]
    return out


def _bench_stateful(cfg, rounds, batch_size, n_local, local_steps, seed=0):
    """The stateful async lane: SCAFFOLD, host store vs device store.

    Same async pipeline (max_staleness=1, prefetch thread) three ways —
    host-store scatter (one blocking device->host sync per round at
    write-back time), device-store (gather/CAS-scatter traced inside the
    jitted programs, drops synced once at end of loop), and the sync
    host-store loop as the baseline — plus a *stateless* control: fedavg
    with the identical client optimizer / step count, i.e. the same async
    round minus the per-client state. Device-store time within noise of
    that control is the "per-round sync removed" claim, measured."""
    grad_fn, batch_fn, params = _make_problem(cfg, n_local, batch_size, seed)
    fed = FedConfig(algorithm="scaffold", clients_per_round=CLIENTS,
                    local_steps=local_steps, server_opt="sgdm",
                    server_lr=0.3, client_opt="sgd", client_lr=0.01)
    afed = dataclasses.replace(fed, async_rounds=True, max_staleness=1,
                               staleness_discount=0.9, prefetch_rounds=2)

    def sim(f):
        return FedSim(fed=f, grad_fn=grad_fn, batch_fn=batch_fn,
                      num_clients=CLIENTS, seed=seed)

    out = {
        "sync_ms": _timed(sim(fed), params, rounds),
        "async_host_ms": _timed(sim(afed), params, rounds),
        "async_device_ms": _timed(
            sim(dataclasses.replace(afed, client_state_placement="device")),
            params, rounds),
        # the matched stateless control (NOT the grid's fedavg, whose
        # client optimizer differs): same opt, same steps, no state
        "stateless_async_ms": _timed(
            sim(dataclasses.replace(afed, algorithm="fedavg")),
            params, rounds),
    }
    out["device_speedup_vs_host"] = (out["async_host_ms"]
                                     / out["async_device_ms"])
    out["device_overhead_vs_stateless"] = (out["async_device_ms"]
                                           / out["stateless_async_ms"])
    return out


def run(quick: bool = True):
    """quick: smoke EMNIST CNN in the dispatch/host-bound cross-device
    regime (where the async overlap pays); full: the 28x28 model with a
    compute-heavier local run."""
    if quick:
        cfg, rounds, n_local = cnn_smoke(), 30, 256
        grid = [("fedavg", 2, 2, {}),
                ("fedpa", 2, 2,
                 dict(burn_in_steps=1, steps_per_sample=1,
                      shrinkage_rho=0.01))]
    else:
        cfg, rounds, n_local = cnn_full(), 10, 256
        grid = [("fedavg", 8, 16, {}),
                ("fedpa", 8, 16,
                 dict(burn_in_steps=4, steps_per_sample=2,
                      shrinkage_rho=0.01))]

    rows, report = [], {"config": cfg.name, "clients_per_round": CLIENTS,
                        "n_local": n_local, "fetch_ms": FETCH_MS,
                        "max_staleness": 1, "prefetch_rounds": 2}
    for alg, steps, batch, kw in grid:
        fed = FedConfig(algorithm=alg, clients_per_round=CLIENTS,
                        local_steps=steps, server_opt="sgdm", server_lr=0.3,
                        client_opt="sgdm", client_lr=0.01, **kw)
        res = _bench_one(cfg, fed, rounds, batch, n_local)
        report[alg] = res
        rows.append({"name": f"async_engine/{alg}_{cfg.name}",
                     "us_per_call": res["sync_ms"] * 1e3,
                     "derived": (f"sync={res['sync_ms']:.1f}ms,"
                                 f"async={res['async_ms']:.1f}ms"
                                 f"({res['speedup']:.2f}x)")})
    report["best_speedup"] = max(report[a]["speedup"] for a, *_ in grid)

    # stateful lane: same async pipeline with per-client state; the device
    # store should land within noise of its matched stateless control
    # where the host store pays its per-round write-back sync
    steps, batch = grid[0][1], grid[0][2]
    st = _bench_stateful(cfg, rounds, batch, n_local, steps)
    report["stateful_scaffold"] = st
    rows.append({"name": f"async_engine/scaffold_state_{cfg.name}",
                 "us_per_call": st["async_host_ms"] * 1e3,
                 "derived": (f"host={st['async_host_ms']:.1f}ms,"
                             f"device={st['async_device_ms']:.1f}ms,"
                             f"stateless={st['stateless_async_ms']:.1f}ms")})
    with open("BENCH_async_engine.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    import sys
    for row in run(quick="--full" not in sys.argv):
        print(row)
