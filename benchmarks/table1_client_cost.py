"""Table 1: computational cost of client updates.

Wall-time of one client update (K local SGD steps + delta computation) for
FedAvg, FedPA with the O(l^2 d) DP, and FedPA with exact O(d^3) matrix
inversion, across model dimensionalities. Reproduces the paper's claim that
the DP overhead over plain SGD vanishes as d grows while exact inversion
blows up (paper: +896% at d=10K).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.client import make_client_update
from repro.core.iasg import iasg_sample
from repro.core.shrinkage import dense_delta
from repro.data import make_federated_lsq
from repro.data.synthetic_lsq import lsq_batches
from repro.optim import sgd


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(quick: bool = True):
    dims = (100, 1_000, 10_000) if quick else (100, 1_000, 10_000, 100_000)
    steps = 50 if quick else 500
    rows = []
    for d in dims:
        _, data = make_federated_lsq(1, 256, d, heterogeneity=5.0, seed=d)
        X, y = data[0]

        def grad_fn(params, batch):
            def loss(p):
                r = batch["x"] @ p - batch["y"]
                return 0.5 * jnp.mean(r * r)
            return jax.value_and_grad(loss)(params)

        opt = sgd(1e-4)
        params = jnp.zeros(d)
        batches = lsq_batches(X, y, 32, steps, seed=1)

        fed_avg = FedConfig(algorithm="fedavg", local_steps=steps,
                            client_opt="sgd", client_lr=1e-4)
        fed_pa = FedConfig(algorithm="fedpa", local_steps=steps,
                           burn_in_steps=steps // 2,
                           steps_per_sample=max(steps // 10, 1),
                           shrinkage_rho=0.1, client_opt="sgd",
                           client_lr=1e-4)
        up_avg = jax.jit(make_client_update(grad_fn, fed_avg, opt))
        up_pa = jax.jit(make_client_update(grad_fn, fed_pa, opt))

        t_avg = _time(lambda p, b: up_avg(p, b)[0], params, batches)
        t_pa = _time(lambda p, b: up_pa(p, b)[0], params, batches)

        # exact: same sampling, dense O(d^3) solve (cap at 10K like Table 1)
        if d <= 10_000:
            ell = fed_pa.num_samples

            def exact(p, b):
                res = iasg_sample(p, opt, opt.init(p), grad_fn, b,
                                  fed_pa.burn_in_steps,
                                  fed_pa.steps_per_sample, ell)
                return dense_delta(p, res.samples, 0.1)

            t_exact = _time(jax.jit(exact), params, batches)
        else:
            t_exact = float("nan")

        rows.append({"name": f"table1/d={d}/fedavg", "us_per_call": t_avg,
                     "derived": ""})
        rows.append({"name": f"table1/d={d}/fedpa_dp", "us_per_call": t_pa,
                     "derived": f"+{(t_pa / t_avg - 1) * 100:.0f}%"})
        rows.append({"name": f"table1/d={d}/fedpa_exact",
                     "us_per_call": t_exact,
                     "derived": f"+{(t_exact / t_avg - 1) * 100:.0f}%"
                     if np.isfinite(t_exact) else "n/a"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
