"""Table 3 (simulated): FedAvg-1E vs FedAvg-ME vs FedPA-ME on a Dirichlet
non-IID federated classification task with the paper's own CNN architecture
(EMNIST-62's TFF reference model at smoke scale — the real benchmark data is
network-gated in this container; see DESIGN.md §9).

Metrics mirror the paper: best eval accuracy within the round budget and
rounds-to-threshold. Rounds run on the compiled round engine
(core/round_program.py) via FedSim — one XLA dispatch per round; the
FedPA leg uses the chunked placement to bound peak memory at larger
cohort sizes without leaving the single-program regime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.emnist_cnn import smoke as cnn_smoke
from repro.core.round import FedSim
from repro.data.dirichlet import (classification_batches,
                                  make_dirichlet_classification)
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn_params


def _image_data(num_clients, cfg, alpha, seed=0):
    side = cfg.image_size
    fc = make_dirichlet_classification(
        num_clients, cfg.num_classes, side * side, n_per_client=64,
        alpha=alpha, proto_scale=1.5, noise=1.5, seed=seed)
    reshape = lambda x: x.reshape(-1, side, side, 1)
    return fc, reshape


def _run(algorithm, epochs, rounds, seed=0, alpha=0.1, num_clients=32):
    cfg = cnn_smoke()
    fc, reshape = _image_data(num_clients, cfg, alpha, seed)
    batch_size = 16
    steps_per_epoch = 64 // batch_size
    local_steps = epochs * steps_per_epoch

    def grad_fn(params, batch):
        b = {"x": reshape(batch["x"]), "y": batch["y"]}
        return jax.value_and_grad(lambda p: cnn_loss(p, b, cfg))(params)

    def batch_fn(cid, r, steps):
        return classification_batches(fc.client_x[cid], fc.client_y[cid],
                                      batch_size, steps,
                                      seed=r * 977 + cid)

    kw = {}
    if algorithm == "fedpa":
        kw = dict(burn_in_steps=local_steps // 2,
                  steps_per_sample=max(steps_per_epoch // 2, 1),
                  shrinkage_rho=0.01, burn_in_rounds=rounds // 4,
                  round_placement="chunked", round_chunk_size=4)
    fed = FedConfig(algorithm=algorithm, clients_per_round=8,
                    local_steps=local_steps, server_opt="sgdm",
                    server_lr=0.3, client_opt="sgdm", client_lr=0.01,
                    client_momentum=0.9, **kw)
    sim = FedSim(fed=fed, grad_fn=grad_fn, batch_fn=batch_fn,
                 num_clients=num_clients, seed=seed)
    params = init_cnn_params(jax.random.PRNGKey(seed), cfg)
    tx = reshape(np.asarray(fc.test_x))
    ty = jnp.asarray(fc.test_y)
    acc_fn = jax.jit(lambda p: cnn_accuracy(p, tx, ty, cfg))
    state, hist = sim.run(params, rounds,
                          eval_fn=lambda p: {"acc": float(acc_fn(p))})
    accs = [h["acc"] for h in hist]
    return accs


def _rounds_to(accs, thr):
    for i, a in enumerate(accs):
        if a >= thr:
            return i + 1
    return None


def run(quick: bool = True):
    rounds = 30 if quick else 100
    rows = []
    results = {}
    for name, alg, epochs in [("fedavg_1e", "fedavg", 1),
                              ("fedavg_me", "fedavg", 5),
                              ("fedpa_me", "fedpa", 5)]:
        accs = _run(alg, epochs, rounds)
        results[name] = accs
        best = max(accs)
        r70 = _rounds_to(accs, 0.7)
        rows.append({"name": f"table3/{name}", "us_per_call": "",
                     "derived": f"best_acc={best:.3f},rounds_to_70%={r70}"})
    # the paper's claims: multi-epoch learns in fewer rounds (Table 3's
    # rounds-to-accuracy), and FedPA attains at least FedAvg-ME's best
    big = rounds + 1
    r_pa = _rounds_to(results["fedpa_me"], 0.7) or big
    r_1e = _rounds_to(results["fedavg_1e"], 0.7) or big
    assert r_pa <= r_1e, (r_pa, r_1e)
    assert max(results["fedpa_me"]) >= max(results["fedavg_me"]) - 0.02
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
