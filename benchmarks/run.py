"""Benchmark harness: one benchmark per paper table/figure + the roofline
aggregate. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,fig1,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_async_engine, bench_client_store,
                        bench_cohort_source, bench_compression,
                        bench_roofline, bench_round_engine, fig1_quadratic,
                        fig3_bias_variance, fig4_ess, table1_client_cost,
                        table3_benchmark_sim, table3_lr_sim)

BENCHES = {
    "table1": table1_client_cost,
    "fig1": fig1_quadratic,
    "fig3": fig3_bias_variance,
    "fig4": fig4_ess,
    "table3": table3_benchmark_sim,
    "table3lr": table3_lr_sim,
    "roofline": bench_roofline,
    "round_engine": bench_round_engine,
    "async_engine": bench_async_engine,
    "cohort_source": bench_cohort_source,
    "client_store": bench_client_store,
    "compression": bench_compression,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args(argv)

    names = list(BENCHES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = BENCHES[name]
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,,{e!r}")
            traceback.print_exc(file=sys.stderr)
            failures += 1
            continue
        for r in rows:
            us = r.get("us_per_call", "")
            us = f"{us:.1f}" if isinstance(us, float) else us
            print(f"{r['name']},{us},\"{r['derived']}\"")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
