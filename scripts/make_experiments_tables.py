"""Render EXPERIMENTS.md's §Dry-run and §Roofline tables from the sweep
artifacts (dryrun_{single,multi}.jsonl + rooflines.jsonl)."""
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(fn):
    path = os.path.join(ROOT, fn)
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path)]


def fmt(x, nd=3):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) < 1e-3 or abs(x) >= 1e5:
            return f"{x:.2e}"
        return f"{x:.{nd}f}"
    return str(x)


def dryrun_table():
    rows = []
    for rec in load("dryrun_single.jsonl") + load("dryrun_multi.jsonl"):
        mem = rec.get("memory", {})
        coll = rec.get("collectives", {})
        status = rec.get("status", "?")
        if status.startswith("skip"):
            status = "skip (full attn)"
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "status": "ok" if status == "ok" else status,
            "placement": rec.get("placement", "-"),
            "lower_s": rec.get("lower_s"), "compile_s": rec.get("compile_s"),
            "temp_GiB": (mem.get("temp_size_in_bytes", 0) / 2**30) or None,
            "args_GiB": (mem.get("argument_size_in_bytes", 0) / 2**30) or None,
            "coll_GiB": (coll.get("total_bytes", 0) / 2**30) or None,
        })
    return rows


def roofline_table():
    rows = []
    for rec in load("rooflines.jsonl"):
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": rec["compute_s"], "memory_s": rec["memory_s"],
            "collective_s": rec["collective_s"], "dominant": rec["dominant"],
            "model_TF": rec["model_flops"] / 1e12,
            "useful": rec["useful_ratio"],
        })
    return rows


def md_table(rows, keys):
    if not rows:
        return "(no data)"
    out = ["| " + " | ".join(keys) + " |",
           "|" + "|".join("---" for _ in keys) + "|"]
    for r in rows:
        out.append("| " + " | ".join(fmt(r.get(k)) for k in keys) + " |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("dryrun", "both"):
        print("### Dry-run table\n")
        print(md_table(dryrun_table(),
                       ["arch", "shape", "mesh", "status", "placement",
                        "compile_s", "temp_GiB", "args_GiB", "coll_GiB"]))
        print()
    if which in ("roofline", "both"):
        print("### Roofline table\n")
        print(md_table(roofline_table(),
                       ["arch", "shape", "mesh", "compute_s", "memory_s",
                        "collective_s", "dominant", "useful"]))
