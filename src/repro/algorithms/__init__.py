"""Pluggable federated-algorithm strategy API (see ``base.py``).

Importing this package registers the built-in algorithms — fedavg, fedpa
(incl. the streaming DP), mime, fedprox, fedpa_precision, fedlora
(compressed low-rank payloads, ``repro.compression``), and the two
stateful ones, scaffold and fedep (per-client persistent state via the
engine's ``ClientStateStore``). Downstream code adds algorithms by
subclassing :class:`FedAlgorithm` and decorating with
:func:`register_algorithm`; no repro-internal edits required.
"""
from repro.algorithms.base import (  # noqa: F401  (import order matters:
    ClientResult,                    # base must bind the registry before the
    FedAlgorithm,                    # implementation modules populate it)
    algorithm_names,
    get_algorithm,
    get_algorithm_class,
    phase_name,
    register_algorithm,
    resolve_algorithm,
)
from repro.algorithms.fedavg import FedAvg  # noqa: F401
from repro.algorithms.fedep import FedEP  # noqa: F401
from repro.algorithms.fedlora import FedLoRA  # noqa: F401
from repro.algorithms.fedpa import FedPA  # noqa: F401
from repro.algorithms.fedpa_precision import FedPAPrecision  # noqa: F401
from repro.algorithms.fedprox import FedProx  # noqa: F401
from repro.algorithms.mime import Mime  # noqa: F401
from repro.algorithms.scaffold import Scaffold  # noqa: F401
