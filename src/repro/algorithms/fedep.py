"""FedEP: stateful expectation propagation with damped site updates.

Federated learning as variational inference (Guo et al. 2023): every
client maintains a *site* — a diagonal-Gaussian approximation of its own
likelihood factor in natural-parameter form — and the global posterior is
the product of sites. ``fedpa_precision`` already ships the one-shot
version of the statistic (shrinkage delta + diagonal precision, discarded
after aggregation); FedEP makes the site *persistent per client* and
updates it with damping:

    site_new = (1 - alpha) * site_old + alpha * (P * delta, P)

where ``P`` is the diagonal shrinkage precision of this round's IASG
samples and ``delta`` the shrinkage-DP mean shift. The cohort payload IS
the damped site (already natural parameters, so ``payload_accum`` is the
identity), aggregated by the same precision-weighted mean ``num / den``
as ``fedpa_precision`` — with ``alpha = 1`` and no participation history
the two algorithms coincide, which is the parity anchor the tests pin.

Damping is what the persistent state buys: a client whose one-round
posterior estimate is noisy (few samples, bad minibatches) only moves its
site part-way, so the aggregate forgets bad rounds geometrically instead
of instantly trusting them — the standard stabilizer for EP in the
low-participation federated regime.

The site lives in the engine's ``ClientStateStore``; burn-in rounds run
the FedAvg regime (inherited from FedPA) and leave sites untouched.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.algorithms.base import ClientResult, register_algorithm
from repro.algorithms.fedpa_precision import FedPAPrecision
from repro.core import tree_math as tm
from repro.optim import Optimizer


@register_algorithm("fedep")
class FedEP(FedPAPrecision):
    """Damped per-client natural-parameter sites (stateful fedpa_precision)."""

    stateful = True

    def validate(self) -> None:
        """Damping must be a usable convex-combination weight."""
        super().validate()
        if not 0.0 < self.fed.fedep_damping <= 1.0:
            raise ValueError(
                f"fedep_damping must be in (0, 1], got "
                f"{self.fed.fedep_damping}")

    # -- persistent state ----------------------------------------------------
    def init_client_state(self, params):
        """Site natural parameters ``{num: P*delta, den: P}`` (zeros).

        Kept in fp32 REGARDLESS of ``delta_dtype`` — like scaffold's
        control variates: the damped EMA re-rounded to bf16 every
        participation would lose corrections smaller than one ulp of the
        site. Only the shipped payload is cast down to the wire dtype.
        """
        return {"num": tm.tzeros_like(params, jnp.float32),
                "den": tm.tzeros_like(params, jnp.float32)}

    # -- client --------------------------------------------------------------
    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """``update(params, batches, site) -> ClientResult``.

        One IASG pass -> this round's natural parameters; the shipped
        payload and the state update are BOTH the damped site (the payload
        is already in accumulator form, see ``payload_accum``).
        """
        alpha = self.fed.fedep_damping
        delta_dtype = self.delta_dtype
        run = self._iasg_delta(grad_fn, client_opt)   # shared FedPA core
        diag_precision = self._diag_precision()

        def update(params, batches, site):
            delta, res, metrics = run(params, batches)
            prec = diag_precision(res.samples)
            new = {"num": tm.tmap(jnp.multiply, prec, delta), "den": prec}
            # the persistent site stays fp32 (see init_client_state); the
            # communicated copy is cast to the wire dtype once
            damped = tm.tmap(
                lambda old, fresh: (1.0 - alpha) * old
                + alpha * fresh.astype(jnp.float32),
                site, new)
            return ClientResult(tm.tcast(damped, delta_dtype), metrics,
                                state_update=damped)

        return update

    # -- aggregation ---------------------------------------------------------
    def payload_accum(self, payload):
        """Sites are already natural parameters: the identity, not the
        ``{delta, prec} -> {num, den}`` map of ``fedpa_precision``."""
        return payload

    def abstract_payload(self, params):
        """Uplink = the damped site ``{num, den}``: 2x dense, wire dtype."""
        d = jax.eval_shape(lambda p: tm.tcast(p, self.delta_dtype), params)
        return {"num": d, "den": d}
