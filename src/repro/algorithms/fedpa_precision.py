"""Precision-weighted FedPA (FedEP-flavored, Guo et al. 2023).

Clients ship approximate *natural parameters* instead of a bare delta: the
shrinkage-DP delta together with the diagonal of the shrinkage precision
(1 / diag(Sigma_hat_l), Theorem 3's estimator restricted to the diagonal —
the same O(d) communication cost). The server then aggregates by
precision-weighted averaging, delta = sum_i w_i P_i delta_i / sum_i w_i P_i,
i.e. expectation-propagation-style moment matching under a diagonal
Gaussian family: clients whose posterior is sharp along a coordinate get
more say about it.

The precision also tells the async engine where staleness hurts (the
ROADMAP's per-parameter-discount item): coordinates with above-average
aggregated precision are sharply determined, so a stale delta there is
discounted harder — ``discount ** s`` becomes per-parameter
``discount ** (s * rel_prec)`` with ``rel_prec`` the clipped
precision-to-mean ratio.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.algorithms.base import ClientResult, register_algorithm
from repro.algorithms.fedpa import FedPA
from repro.core import server as server_lib
from repro.core import tree_math as tm
from repro.core.shrinkage import rho_l
from repro.optim import Optimizer

#: Keeps the precision-weighted mean defined when a traced all-zero weight
#: vector degrades the round to a no-op (see ``server.normalized_weights``).
_EPS = 1e-8
#: Per-parameter staleness exponents are clipped to this band so one
#: extreme coordinate cannot zero (or un-discount) its stale update.
_REL_PREC_MIN, _REL_PREC_MAX = 0.25, 4.0


@register_algorithm("fedpa_precision")
class FedPAPrecision(FedPA):
    """FedPA with diagonal-precision payloads and EP-style aggregation."""

    supports_streaming_dp = False

    def _diag_precision(self) -> Callable:
        """Build ``diag_precision(samples) -> 1 / diag(Sigma_hat_l)``.

        ``diag(Sigma_hat_l) = rho_l + (1 - rho_l) * diag(S_l)`` is the
        diagonal of the Theorem 3 estimator (per-coordinate sample variance
        of the IASG samples). With a single sample ``rho_l = 1`` and the
        precision is identically one — the plain FedPA delta. Shared with
        the stateful ``fedep`` sites.
        """
        delta_dtype = self.delta_dtype
        num_samples = self.num_samples
        r = float(rho_l(num_samples, self.fed.shrinkage_rho))

        def diag_precision(samples):
            def leaf(s):
                s32 = s.astype(jnp.float32)
                if num_samples > 1:
                    var = jnp.var(s32, axis=0, ddof=1)
                else:
                    var = jnp.zeros_like(s32[0])
                return (1.0 / (r + (1.0 - r) * var)).astype(delta_dtype)

            return tm.tmap(leaf, samples)

        return diag_precision

    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """IASG + shrinkage-DP delta, plus the diagonal shrinkage precision.

        Payload: ``{"delta": Delta_hat_l, "prec": 1 / diag(Sigma_hat_l)}``
        (see :meth:`_diag_precision`).
        """
        run = self._iasg_delta(grad_fn, client_opt)  # shared FedPA core
        diag_precision = self._diag_precision()

        def update(params, batches):
            delta, res, metrics = run(params, batches)
            payload = {"delta": delta, "prec": diag_precision(res.samples)}
            return ClientResult(payload, metrics)

        return update

    # -- aggregation: precision-weighted averaging ---------------------------
    def init_accum(self, params):
        """Accumulator: precision-weighted delta sum + precision sum (fp32)."""
        return {"num": tm.tzeros_like(params, jnp.float32),
                "den": tm.tzeros_like(params, jnp.float32)}

    def payload_accum(self, payload):
        """Natural-parameter form: ``{num: P * delta, den: P}`` (linear)."""
        return {"num": tm.tmap(jnp.multiply, payload["prec"],
                               payload["delta"]),
                "den": payload["prec"]}

    def finalize(self, agg):
        """Precision-weighted mean ``num / den`` (fp32, cast back once)."""
        return tm.tmap(
            lambda n, d: (n.astype(jnp.float32)
                          / (d.astype(jnp.float32) + _EPS))
            .astype(self.delta_dtype),
            agg["num"], agg["den"])

    def map_components(self, fn: Callable, obj):
        """Payloads/accumulators are dicts of parameter-shaped trees."""
        return {k: fn(v) for k, v in obj.items()}

    def abstract_payload(self, params):
        """Uplink = delta + diagonal precision: 2x dense, both wire dtype."""
        d = jax.eval_shape(lambda p: tm.tcast(p, self.delta_dtype), params)
        return {"delta": d, "prec": d}

    # -- server: per-parameter staleness discount ----------------------------
    def server_update(self, state, agg, server_opt: Optimizer,
                      discount=None):
        """Finalize, then discount stale updates per parameter.

        The scalar ``discount`` (``staleness_discount ** s``) is raised to
        the clipped precision-to-mean ratio of each coordinate, so sharply
        determined coordinates forget stale information faster. With
        ``discount`` exactly 1.0 (or ``None``) this is a bitwise no-op, so
        the staleness=0 async path still matches the fused sync program.
        """
        pseudo_grad = self.finalize(agg)
        if discount is not None:
            den = agg["den"]
            leaves = [d.astype(jnp.float32) for d in
                      jax.tree_util.tree_leaves(den)]
            total = sum(jnp.sum(d) for d in leaves)
            count = sum(d.size for d in leaves)
            mean_prec = jnp.maximum(total / count, _EPS)
            d = jnp.asarray(discount, jnp.float32)
            pseudo_grad = tm.tmap(
                lambda x, p: (jnp.power(
                    d, jnp.clip(p.astype(jnp.float32) / mean_prec,
                                _REL_PREC_MIN, _REL_PREC_MAX))
                    * x.astype(jnp.float32)).astype(x.dtype),
                pseudo_grad, den)
        return server_lib.server_update(state, pseudo_grad, server_opt)
