"""FedPA as a registered algorithm (Algorithms 3 and 4, Appendix C).

IASG posterior sampling + the shrinkage-covariance Sherman-Morrison DP for
the client delta. ``fed.streaming_dp=True`` selects the online/any-time DP
variant (Appendix C): each IASG sample is absorbed into the DP state as its
window closes, so the l x d stacked-sample buffer never exists. Burn-in
rounds run the FedAvg regime (Section 5.2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.algorithms.base import (ClientResult, FedAlgorithm,
                                   get_algorithm_class, register_algorithm)
from repro.core import tree_math as tm
from repro.core.dp_delta import (dp_delta, online_dp_delta, online_dp_init,
                                 online_dp_update)
from repro.core.iasg import iasg_sample, sgd_steps
from repro.optim import Optimizer


@register_algorithm("fedpa")
class FedPA(FedAlgorithm):
    """Posterior averaging with the shrinkage-DP delta."""

    supports_streaming_dp = True
    has_burn_regime = True
    supports_step_budgets = True

    @property
    def num_samples(self) -> int:
        """l: posterior samples per client per round (one per IASG window)."""
        fed = self.fed
        return (fed.local_steps - fed.burn_in_steps) // fed.steps_per_sample

    def validate(self) -> None:
        """Reject configs whose local steps don't form whole IASG windows."""
        super().validate()
        if self.num_samples < 1:
            # equality IS valid: local_steps == burn_in_steps +
            # steps_per_sample gives exactly one IASG window (l = 1)
            raise ValueError(
                "fedpa needs local_steps >= burn_in_steps + steps_per_sample"
            )
        fed = self.fed
        sampling_steps = fed.local_steps - fed.burn_in_steps
        if sampling_steps % fed.steps_per_sample != 0:
            raise ValueError(
                f"fedpa sampling steps must divide into whole IASG "
                f"windows: local_steps - burn_in_steps = "
                f"{fed.local_steps} - {fed.burn_in_steps} = "
                f"{sampling_steps} is not a multiple of "
                f"steps_per_sample = {fed.steps_per_sample} "
                f"({sampling_steps % fed.steps_per_sample} leftover "
                f"batches)")

    def burn_algorithm(self) -> FedAlgorithm:
        """FedAvg on the same client/server knobs (the burn-in regime)."""
        return get_algorithm_class("fedavg")(dataclasses.replace(
            self.fed, algorithm="fedavg", streaming_dp=False))

    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """IASG sampling + shrinkage-DP delta (batch or streaming DP)."""
        if self.fed.streaming_dp:
            return self._make_streaming_update(grad_fn, client_opt)
        return self._make_batch_update(grad_fn, client_opt)

    # -- batch DP (Algorithm 4 + Theorem 3) ---------------------------------
    def _iasg_delta(self, grad_fn, client_opt):
        """Build ``run(params, batches) -> (delta, iasg_result, metrics)``.

        One IASG sampling pass plus the shrinkage-DP delta — the shared
        core of the batch FedPA client and of subclasses that derive extra
        statistics from the samples (``fedpa_precision``).
        """
        fed = self.fed
        delta_dtype = self.delta_dtype
        num_samples = self.num_samples

        def run(params, batches):
            opt_state = client_opt.init(params)
            res = iasg_sample(
                params, client_opt, opt_state, grad_fn, batches,
                burn_in_steps=fed.burn_in_steps,
                steps_per_sample=fed.steps_per_sample,
                num_samples=num_samples,
                sample_dtype=delta_dtype,
            )
            # dp_delta's fp32 scalar coefficients promote bf16 leaves to fp32
            # (jnp weak-typing); pin the configured dtype so scan carries match
            delta = tm.tcast(
                dp_delta(tm.tcast(params, delta_dtype), res.samples,
                         fed.shrinkage_rho),
                delta_dtype,
            )
            first = res.burn_in_losses[0] if fed.burn_in_steps else \
                res.sample_losses[0, 0]
            return delta, res, {"loss_first": first,
                                "loss_last": res.sample_losses[-1, -1]}

        return run

    def _make_batch_update(self, grad_fn, client_opt):
        """Samples stacked first, then one ``lax.scan`` of the online DP."""
        run = self._iasg_delta(grad_fn, client_opt)

        def update(params, batches):
            delta, _, metrics = run(params, batches)
            return ClientResult(delta, metrics)

        return update

    # -- streaming / any-time DP (Appendix C) -------------------------------
    def _make_streaming_update(self, grad_fn, client_opt):
        """Each IASG sample is absorbed into the Sherman-Morrison state as
        soon as its window closes — the l x d stacked-sample buffer never
        exists. Numerically identical to the batch DP
        (tests/test_streaming_and_mime.py)."""
        fed = self.fed
        delta_dtype = self.delta_dtype
        ell = self.num_samples
        rho = fed.shrinkage_rho
        K_s = fed.steps_per_sample

        def update(params, batches):
            opt_state = client_opt.init(params)
            split = lambda tree, a, b: tm.tmap(lambda x: x[a:b], tree)
            p, s = params, opt_state
            loss_first = None
            if fed.burn_in_steps:
                p, s, burn = sgd_steps(p, client_opt, s, grad_fn,
                                       split(batches, 0, fed.burn_in_steps))
                loss_first = burn[0]
            windows = tm.tmap(
                lambda x: x[fed.burn_in_steps:].reshape(
                    (ell, K_s) + x.shape[1:]),
                batches,
            )
            dp0 = online_dp_init(tm.tcast(params, delta_dtype), ell,
                                 dtype=delta_dtype)

            def window(carry, wb):
                p, s, dp = carry

                def step(inner, batch):
                    p, s, acc = inner
                    loss, grads = grad_fn(p, batch)
                    upd, s = client_opt.update(grads, s, p)
                    p = tm.tmap(lambda pi, u: pi + u.astype(pi.dtype), p, upd)
                    acc = tm.tmap(lambda a, pi: a + pi.astype(delta_dtype),
                                  acc, p)
                    return (p, s, acc), loss

                # The IASG sample space IS delta_dtype by contract: this
                # matches iasg.py's batch path bit-for-bit, and the fp32
                # accumulation happens downstream in the Sherman-Morrison
                # online-DP state, not in this window average.
                # fedlint: disable=FL003 -- IASG samples live in delta_dtype by contract
                acc0 = tm.tzeros_like(p, delta_dtype)
                (p, s, acc), losses = jax.lax.scan(step, (p, s, acc0), wb)
                sample = tm.tscale(1.0 / K_s, acc)
                dp = online_dp_update(dp, sample, rho)
                return (p, s, dp), losses

            (p, s, dp), losses = jax.lax.scan(window, (p, s, dp0), windows)
            delta = tm.tcast(online_dp_delta(dp, rho), delta_dtype)
            first = loss_first if loss_first is not None else losses[0, 0]
            return ClientResult(delta, {"loss_first": first,
                                        "loss_last": losses[-1, -1]})

        return update
