"""FedProx as a registered algorithm (Li et al. 2020).

Each client minimizes its local objective plus a proximal anchor to the
broadcast iterate, f_i(theta) + (mu/2) ||theta - theta_0||^2, which bounds
client drift under heterogeneity without any server-side change. In the
paper's posterior framing this is MAP inference against an isotropic
Gaussian prior centered at the server iterate — another instance of the
same local-inference template, which is why it drops into the strategy API
as a pure client-side override.
"""
from __future__ import annotations

from typing import Callable

from repro.algorithms.base import (ClientResult, FedAlgorithm,
                                   register_algorithm)
from repro.core import tree_math as tm
from repro.core.dp_delta import fedavg_delta
from repro.core.iasg import sgd_steps
from repro.optim import Optimizer


@register_algorithm("fedprox")
class FedProx(FedAlgorithm):
    """FedAvg with a proximal term in the local step (``fed.fedprox_mu``)."""

    def validate(self) -> None:
        """Proximal strength must be non-negative (0 reduces to FedAvg)."""
        super().validate()
        if self.fed.fedprox_mu < 0.0:
            raise ValueError(
                f"fedprox_mu must be >= 0, got {self.fed.fedprox_mu}")

    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """K local steps on the proximally-regularized objective."""
        mu = self.fed.fedprox_mu
        delta_dtype = self.delta_dtype

        def update(params, batches):
            def prox_grad_fn(p, batch):
                loss, grads = grad_fn(p, batch)
                grads = tm.tmap(
                    lambda g, pi, p0: g + (mu * (pi - p0)).astype(g.dtype),
                    grads, p, params)
                return loss, grads

            opt_state = client_opt.init(params)
            final, _, losses = sgd_steps(params, client_opt, opt_state,
                                         prox_grad_fn, batches)
            delta = tm.tcast(fedavg_delta(params, final), delta_dtype)
            return ClientResult(delta, {"loss_first": losses[0],
                                        "loss_last": losses[-1]})

        return update
