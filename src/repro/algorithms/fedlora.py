"""Federated LoRA: precision-weighted FedPA over compressed payloads.

The paper's communicated statistic is O(d) per client, but for 27B-class
configs even O(d) is the bottleneck. ``fedlora`` runs the same IASG +
diagonal-precision client update as ``fedpa_precision`` and then ships it
through the ``fed.payload_codec`` chain (``repro.compression``): 2-D
deltas projected onto rank-``lora_rank`` factors against a deterministic
per-(round, leaf) sketch both sides regenerate (the basis never travels),
optionally quantized to int8/int16. The scalable-EP argument (PAPERS.md,
arXiv:2302.04228): approximate each client's posterior statistic in a
compressed subspace and aggregate there.

Aggregation happens IN the encoded space — the round accumulator is the
codec's linear image, so the sequential/chunked placements fold rank-r
factors instead of dense deltas — and the server decodes exactly once per
round inside the jitted cohort program (:meth:`finish_cohort`), using the
dispatch-time round index so the async engine rebuilds the same sketch
the cohort encoded against. The staleness discount is applied by the
server stage *after* that decode, on the dense pseudo-gradient.

What compression loses per round, error feedback restores across rounds:
each client persists ``corrected - decode(encode(corrected))`` as a
residual in the client-state store and re-injects it at its next
participation, so the error is delayed, not lost — and because the
sketch rotates every round, the re-expressed residual eventually escapes
any fixed rank-r subspace. ``fed.error_feedback=False`` disables the
state (and measurably hurts, see ``tests/test_compression.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.algorithms.base import (ClientResult, FedAlgorithm,
                                   get_algorithm_class, register_algorithm)
from repro.algorithms.fedpa_precision import _EPS, FedPAPrecision
from repro.compression import build_codec
from repro.core import tree_math as tm
from repro.optim import Optimizer


@register_algorithm("fedlora")
class FedLoRA(FedPAPrecision):
    """Low-rank (+ quantized) precision-weighted FedPA with error feedback."""

    supports_codec = True

    def __init__(self, fed):
        """Bind the config and build the codec chain once.

        ``stateful`` is per-instance: the error-feedback residual is
        per-client persistent state, so the engines only thread the client
        store when ``fed.error_feedback`` is on.
        """
        super().__init__(fed)
        self.codec = build_codec(fed)
        self.stateful = bool(fed.error_feedback)

    def burn_algorithm(self) -> FedAlgorithm:
        """FedAvg burn-in with DENSE payloads: the codec knobs are reset
        (fedavg rejects a non-"none" codec) and burn rounds never touch
        the residual state."""
        return get_algorithm_class("fedavg")(dataclasses.replace(
            self.fed, algorithm="fedavg", streaming_dp=False,
            payload_codec="none", error_feedback=False))

    # -- persistent state ----------------------------------------------------
    def init_client_state(self, params):
        """Error-feedback residual (zeros), fp32 like every persistent
        accumulator: it collects sub-ulp compression errors across
        participations."""
        return tm.tzeros_like(params, jnp.float32)

    # -- round template hooks ------------------------------------------------
    def broadcast(self, state, server_opt: Optimizer) -> tuple:
        """Ship the round index: clients must build this round's sketch."""
        del server_opt
        return (state.round,)

    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """IASG + precision, encoded through the codec chain.

        ``update(params, batches, [residual,] round_idx) -> ClientResult``
        with payload ``{"delta": encode(delta + residual),
        "prec": suffix(project(prec))}``; the new residual is what the
        round trip lost, persisted for the next participation.
        """
        run = self._iasg_delta(grad_fn, client_opt)   # shared FedPA core
        diag_precision = self._diag_precision()
        codec = self.codec
        delta_dtype = self.delta_dtype

        def encode_pair(params, delta, prec, round_idx, residual):
            corrected = tm.tmap(
                lambda d, r: d.astype(jnp.float32) + r, delta, residual)
            wire = codec.encode(tm.tcast(corrected, delta_dtype), round_idx)
            prec_wire = codec.encode_aux(
                codec.project_precision(prec, round_idx), round_idx)
            payload = {"delta": wire, "prec": prec_wire}
            decoded = codec.decode(wire, round_idx, params)
            new_residual = tm.tmap(
                lambda c, d: c - d.astype(jnp.float32), corrected, decoded)
            return payload, new_residual

        if self.stateful:
            def update(params, batches, residual, round_idx):
                delta, res, metrics = run(params, batches)
                prec = diag_precision(res.samples)
                payload, new_residual = encode_pair(
                    params, delta, prec, round_idx, residual)
                return ClientResult(payload, metrics,
                                    state_update=new_residual)

            return update

        def update(params, batches, round_idx):
            delta, res, metrics = run(params, batches)
            prec = diag_precision(res.samples)
            payload, _ = encode_pair(params, delta, prec, round_idx,
                                     tm.tzeros_like(params, jnp.float32))
            return ClientResult(payload, metrics)

        return update

    # -- aggregation: encoded space ------------------------------------------
    def init_accum(self, params):
        """fp32 ``{num, den}`` zeros in the codec's accumulator space (the
        linear-prefix image: rank-r factors, not dense deltas)."""
        return {"num": self.codec.accum_zeros(params),
                "den": self.codec.accum_zeros(params)}

    def payload_accum(self, payload):
        """Dequantize (undo the nonlinear suffix), then natural-parameter
        form ``{num: P_enc * delta_enc, den: P_enc}`` — linear, so the
        sequential/chunked folds stay exact in the encoded space."""
        d = self.codec.to_accum(payload["delta"])
        p = self.codec.to_accum(payload["prec"])
        return {"num": tm.tmap(jnp.multiply, p, d), "den": p}

    def finish_cohort(self, state, agg):
        """Precision-weighted mean in the encoded space, then ONE decode
        back to parameter space — with the dispatch-time ``state.round``,
        which is the index the cohort encoded against (the async engine
        may apply this aggregate to a newer state)."""
        mean = tm.tmap(
            lambda n, d: n.astype(jnp.float32)
            / (d.astype(jnp.float32) + _EPS),
            agg["num"], agg["den"])
        dense = self.codec.decode_accum(mean, state.round, state.params)
        return {"delta": dense}

    def finalize(self, agg):
        """Cast the decoded mean once; pre-``finish_cohort`` accumulators
        (the fp32-contract tests probe them raw) fall back to the encoded
        precision-weighted mean."""
        if isinstance(agg, dict) and "delta" in agg:
            return tm.tcast(agg["delta"], self.delta_dtype)
        return super().finalize(agg)

    def map_components(self, fn: Callable, obj):
        """Skip the FSDP per-component sharding constraint for non-identity
        codecs: encoded leaves (rank-r factors, int8 ``{q, scale}`` pairs)
        are not parameter-shaped, and at rank r they are small enough to
        stay replicated."""
        if self.codec.is_identity:
            return super().map_components(fn, obj)
        return obj

    # -- server ---------------------------------------------------------------
    def server_update(self, state, agg, server_opt: Optimizer,
                      discount=None):
        """Scalar staleness discount on the DENSE decoded pseudo-gradient.

        ``finish_cohort`` already collapsed the ``{num, den}`` pair, so the
        per-parameter precision discount of ``fedpa_precision`` has no
        ``den`` to read — the base scalar rule applies, post-decode.
        """
        return FedAlgorithm.server_update(self, state, agg, server_opt,
                                          discount)

    # -- communicated-bytes accounting ---------------------------------------
    def abstract_payload(self, params):
        """Exact wire spec: encoded delta + suffix-quantized projected
        precision (scales included), via ``eval_shape`` — no allocation."""

        def spec(p):
            wire = tm.tcast(p, self.delta_dtype)
            return {
                "delta": self.codec.encode(wire, 0),
                "prec": self.codec.encode_aux(
                    self.codec.project_precision(wire, 0), 0),
            }

        return jax.eval_shape(spec, params)

    def abstract_broadcast_extras(self, params):
        """Downlink extra: the i32 round index (sketch synchronization)."""
        del params
        return (jax.ShapeDtypeStruct((), jnp.int32),)
