"""MIME-lite as a registered algorithm (Karimireddy et al. 2020).

The paper's strongest stateless baseline: clients mix a FROZEN server
momentum estimate into every local step plus an SVRG-style control variate.
The defining feature is its broadcast hook — the server ships its momentum
buffer to the cohort alongside the params, read through the explicit
``Optimizer.momentum`` accessor (zeros for momentum-free server optimizers).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.algorithms.base import (ClientResult, FedAlgorithm,
                                   register_algorithm)
from repro.core import tree_math as tm
from repro.core.dp_delta import fedavg_delta
from repro.optim import Optimizer


@register_algorithm("mime")
class Mime(FedAlgorithm):
    """MIME-lite: frozen server momentum + SVRG control variate."""

    def validate(self) -> None:
        """Reject MIME configs with a momentum mix outside [0, 1]."""
        super().validate()
        beta = self.fed.mime_beta
        if not 0.0 <= beta <= 1.0:
            raise ValueError(
                f"mime_beta must lie in [0, 1] (it convexly mixes the local "
                f"gradient with the frozen server momentum); got {beta}")

    def broadcast(self, state, server_opt: Optimizer) -> tuple:
        """Frozen server momentum shipped to MIME clients (Section 6)."""
        return (server_opt.momentum(state.opt_state, state.params),)

    def abstract_broadcast_extras(self, params):
        """Downlink extra: the params-shaped frozen server momentum."""
        return (jax.eval_shape(tm.tzeros_like, params),)

    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """``update(params, batches, server_m) -> ClientResult``.

        theta <- theta - lr[(1-beta) g + beta m_server] with the SVRG-style
        control variate g(theta_k) - g(theta_0) + g_full(theta_0), where the
        full-batch gradient at theta_0 is estimated from the round's batches.
        Note the extra server-statistics argument (MIME's defining feature);
        ``client_opt`` is unused — MIME prescribes its own local step.
        """
        del client_opt
        beta = self.fed.mime_beta
        lr = self.fed.client_lr
        delta_dtype = self.delta_dtype

        def update(params, batches, server_m):
            # control-variate anchor: mean gradient at theta_0 over the
            # round, accumulated in fp32 (ulp(256)=2 in bf16: summing more
            # batches than that silently drops whole gradient increments)
            def accum(carry, batch):
                _, g = grad_fn(params, batch)
                return tm.tmap(lambda c, gi: c + gi.astype(c.dtype),
                               carry, g), None

            K = jax.tree_util.tree_leaves(batches)[0].shape[0]
            gsum, _ = jax.lax.scan(accum, tm.tzeros_like(params, jnp.float32),
                                   batches)
            g_anchor = tm.tmap(lambda a, p: ((1.0 / K) * a).astype(p.dtype),
                               gsum, params)

            def step(carry, batch):
                p = carry
                loss, g = grad_fn(p, batch)
                _, g0 = grad_fn(params, batch)   # same minibatch at theta_0
                g_corr = tm.tmap(lambda a, b, c: a - b + c, g, g0, g_anchor)
                d = tm.tmap(lambda gi, mi: (1.0 - beta) * gi + beta * mi,
                            g_corr, server_m)
                p = tm.tmap(lambda pi, di: pi - lr * di.astype(pi.dtype), p, d)
                return p, loss

            p, losses = jax.lax.scan(step, params, batches)
            delta = tm.tcast(fedavg_delta(params, p), delta_dtype)
            return ClientResult(delta, {"loss_first": losses[0],
                                        "loss_last": losses[-1]})

        return update
