"""The ``FedAlgorithm`` strategy API: one class per federated algorithm.

The paper's central claim is that FedAvg and FedPA are instances of one
posterior-inference template (Algorithm 1): local inference on each client,
an O(d) communicated statistic, and a server-side refinement of the global
iterate. This module makes that template a first-class API instead of
``if fed.algorithm == ...`` branches: every algorithm subclasses
:class:`FedAlgorithm` and registers under a name with
:func:`register_algorithm`; ``FedConfig`` validation, the compiled round
engine (``core/round_program.py``), the async engine, and the launch entry
points all resolve algorithms through :func:`get_algorithm`.

The hook contract (one federated round, in engine order):

* ``validate()``              — eager config checks (run from
  ``FedConfig.__post_init__``).
* ``broadcast(state, server_opt) -> extras`` — server statistics shipped to
  every client alongside the params (MIME's frozen momentum; ``()`` for
  most algorithms).
* ``make_client_update(grad_fn, client_opt) -> update`` where
  ``update(params, batches, *extras) -> ClientResult(payload, metrics)``.
  The payload is a typed pytree — a bare delta for FedAvg/FedPA, a
  ``{"delta", "prec"}`` natural-parameter pair for precision-weighted
  FedPA — not necessarily a single delta tree. *Stateful* algorithms
  (``stateful = True``: SCAFFOLD control variates, FedEP sites) take one
  extra leading argument and return one extra field:
  ``update(params, batches, client_state, *extras) ->
  ClientResult(payload, metrics, state_update)``; the engine gathers
  ``client_state`` from (and scatters ``state_update`` back to) the
  host-side per-client ``ClientStateStore``, inside the jitted round.
* ``aggregate(stacked_payloads, weights) -> pseudo_grad`` — fp32-accumulated
  weighted aggregation. Internally this factors through a *linear
  accumulator space* (``payload_accum`` / ``accumulate`` /
  ``reduce_stacked`` + ``finalize``) so the engine's sequential and chunked
  placements can fold clients into the accumulator without ever
  materializing the stacked cohort, and so non-mean aggregations
  (precision-weighted averaging) stay expressible. The accumulator is
  ALWAYS fp32 regardless of ``fed.delta_dtype``; ``finalize`` casts once.
* ``server_update(state, agg, server_opt, discount) -> state`` — finalize
  the accumulator into a pseudo-gradient, apply the (optionally
  per-parameter) staleness discount, and take one server-optimizer step.
  Algorithms with persistent *server-side* statistics (SCAFFOLD's server
  control variate) keep them in ``ServerState.algo_state``
  (``init_algo_state``) and update them here.

Algorithms whose sampling machinery needs a warm start expose a *burn-in
regime* (``has_burn_regime`` / ``burn_algorithm()``): the algorithm run for
the first ``fed.burn_in_rounds`` rounds (FedPA runs FedAvg, Section 5.2).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core import server as server_lib
from repro.core import tree_math as tm
from repro.optim import Optimizer


class ClientResult(NamedTuple):
    """What one client sends back to the server.

    ``payload`` is the algorithm's typed communicated statistic (a pytree;
    a bare delta tree for FedAvg/FedPA). ``metrics`` is a dict of scalar
    diagnostics and must contain ``loss_first`` and ``loss_last``.
    ``state_update`` is the client's new persistent per-client state
    (``None`` for stateless algorithms): the round engine gathers the
    cohort's state slices from the host-side ``ClientStateStore``, feeds
    them to the client updates, and scatters these updates back — see
    ``core/client_state.py``.
    """

    payload: Any
    metrics: Dict[str, Any]
    state_update: Any = None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["FedAlgorithm"]] = {}


def register_algorithm(name: str, *,
                       override: bool = False) -> Callable[[type], type]:
    """Class decorator: register a :class:`FedAlgorithm` under ``name``.

    The name becomes a valid ``FedConfig.algorithm`` value everywhere —
    config validation, the round engine, ``FedSim``, and the
    ``--algorithm`` launch flags all resolve through the registry, so
    downstream code can add algorithms without touching this package.
    Re-registering an existing name raises (a collision would silently
    swap the round math of every config using it) unless ``override=True``
    is passed explicitly.
    """

    def deco(cls: type) -> type:
        if not (isinstance(cls, type) and issubclass(cls, FedAlgorithm)):
            raise TypeError(f"{cls!r} must subclass FedAlgorithm")
        if not override and name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(
                f"algorithm {name!r} is already registered to "
                f"{_REGISTRY[name]!r}; pass override=True to replace it")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def algorithm_names() -> Tuple[str, ...]:
    """Sorted names of every registered algorithm."""
    return tuple(sorted(_REGISTRY))


def get_algorithm_class(name: str) -> Type["FedAlgorithm"]:
    """Look up a registered algorithm class by name (ValueError if absent)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered: {algorithm_names()}"
        ) from None


def get_algorithm(fed) -> "FedAlgorithm":
    """Instantiate the registered algorithm for ``fed.algorithm``."""
    return get_algorithm_class(fed.algorithm)(fed)


def resolve_algorithm(fed, use_sampling: bool = True) -> "FedAlgorithm":
    """Algorithm for a round: the registered one, or its burn-in regime.

    ``use_sampling=False`` is the round engine's burn-in-round knob: FedPA
    configs run their FedAvg regime (Section 5.2); algorithms without a
    burn regime are returned unchanged.
    """
    alg = get_algorithm(fed)
    return alg if use_sampling else alg.burn_algorithm()


def phase_name(fed, round_idx: int) -> str:
    """Display name for round ``round_idx`` of a run.

    During the first ``fed.burn_in_rounds`` rounds of an algorithm with a
    burn-in regime this reads e.g. ``"fedavg (burn-in)"``; otherwise it is
    the algorithm name. Shared by ``launch/train.py`` and
    ``launch/dryrun.py`` so the log/record strings cannot drift.
    """
    alg = get_algorithm(fed)
    if round_idx < fed.burn_in_rounds and alg.has_burn_regime:
        return f"{alg.burn_algorithm().name} (burn-in)"
    return alg.name


# ---------------------------------------------------------------------------
# The strategy base class
# ---------------------------------------------------------------------------

class FedAlgorithm:
    """Base class for federated algorithms (see module docstring).

    Subclasses must implement :meth:`make_client_update`; everything else
    has defaults implementing the paper's weighted-mean-delta template.
    The default aggregation reduces in fp32 and casts once
    (``core.server.weighted_sum``), exactly matching the pre-API engine.
    """

    #: Registry name, set by :func:`register_algorithm`.
    name: str = "?"
    #: Whether the online/any-time DP (``fed.streaming_dp``) applies.
    supports_streaming_dp: bool = False
    #: Whether the algorithm runs a different regime during burn-in rounds.
    has_burn_regime: bool = False
    #: Whether clients carry persistent per-round state (SCAFFOLD control
    #: variates, FedEP sites). Stateful client updates take a
    #: ``client_state`` argument and return ``ClientResult.state_update``;
    #: the engines thread the cohort's state slices through the jitted
    #: round via ``core.client_state.ClientStateStore``.
    stateful: bool = False
    #: Whether heterogeneous local-step budgets (``fed.min_local_steps``)
    #: are exact for this algorithm. Budgets freeze a client's idle steps
    #: by masking its gradients to zero, which is a true no-op only when
    #: every local step is driven purely by ``grad_fn`` (FedAvg/FedPA
    #: family under ``client_opt="sgd"``); algorithms that add non-gradient
    #: terms to the step (SCAFFOLD's control variate, MIME's frozen
    #: momentum, FedProx's proximal pull) would keep moving the params
    #: during idle steps, so they refuse the knob.
    supports_step_budgets: bool = False
    #: Whether the algorithm honours ``fed.payload_codec`` (fedlora). For
    #: every other algorithm a non-"none" codec would be silently ignored,
    #: so :meth:`validate` rejects it.
    supports_codec: bool = False

    def __init__(self, fed):
        """Bind the algorithm to a ``FedConfig`` (stored as ``self.fed``)."""
        self.fed = fed
        self.delta_dtype = jnp.dtype(fed.delta_dtype)

    # -- config ------------------------------------------------------------
    def validate(self) -> None:
        """Eager config checks; called from ``FedConfig.__post_init__``.

        Raise ``ValueError`` on bad knob combinations so they surface at
        construction, not as opaque trace-time errors. Subclasses extending
        this should call ``super().validate()``.
        """
        if self.fed.streaming_dp and not self.supports_streaming_dp:
            raise ValueError(
                f"streaming_dp=True requires algorithm='fedpa' (the online "
                f"DP of Appendix C); {self.fed.algorithm!r} has no streaming "
                f"client — it would be silently ignored")
        if self.fed.min_local_steps and not self.supports_step_budgets:
            raise ValueError(
                f"min_local_steps > 0 (heterogeneous local-step budgets) is "
                f"not supported by algorithm {self.fed.algorithm!r}: its "
                f"local steps are not purely gradient-driven, so masking "
                f"gradients would not freeze idle steps")
        if self.fed.payload_codec != "none" and not self.supports_codec:
            raise ValueError(
                f"payload_codec={self.fed.payload_codec!r} requires an "
                f"algorithm with compressed payloads (algorithm='fedlora'); "
                f"{self.fed.algorithm!r} ships dense payloads and would "
                f"silently ignore the codec")

    @property
    def num_samples(self) -> int:
        """Posterior samples per client per round (0 for non-sampling)."""
        return 0

    def burn_algorithm(self) -> "FedAlgorithm":
        """Algorithm run during the first ``fed.burn_in_rounds`` rounds."""
        return self

    # -- persistent state ----------------------------------------------------
    def init_client_state(self, params):
        """Per-client persistent state template (one client's zero state).

        Only consulted when ``stateful`` is True; the engines stack it into
        the ``ClientStateStore``'s ``(num_clients, ...)`` buffers lazily,
        the first time a template is available.
        """
        del params
        return ()

    def init_algo_state(self, params):
        """Persistent server-side algorithm state (``ServerState.algo_state``).

        Default: an empty pytree (no leaves), so stateless algorithms cost
        nothing. SCAFFOLD keeps its server control variate here; the state
        is checkpointed with the rest of ``ServerState`` and may be updated
        by ``server_update``.
        """
        del params
        return ()

    # -- round template hooks ----------------------------------------------
    def broadcast(self, state, server_opt: Optimizer) -> tuple:
        """Server statistics shipped to clients alongside the params.

        Returned extras become positional arguments of the client update
        (broadcast, i.e. un-vmapped, across the cohort). Default: none.
        """
        del state, server_opt
        return ()

    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """Build ``update(params, batches, *extras) -> ClientResult``.

        ``batches`` is a pytree with leading axis ``fed.local_steps``; the
        update must be a pure function suitable for ``vmap``/``scan``
        inside one jitted round.
        """
        raise NotImplementedError

    # -- aggregation (accumulator space) ------------------------------------
    def init_accum(self, params):
        """Zero element of the linear accumulator space.

        The accumulator is fp32 REGARDLESS of ``fed.delta_dtype``: the
        sequential and chunked placements fold one client (or chunk) at a
        time into this buffer, and accumulating in bf16 would re-round on
        every fold — violating the fp32-accumulation contract the stacked
        ``reduce_stacked`` path keeps. :meth:`finalize` casts once.
        """
        return tm.tzeros_like(params, jnp.float32)

    def payload_accum(self, payload):
        """Map one client payload into the accumulator space (linear part).

        The engine only ever combines accumulators linearly (weighted
        sums); anything nonlinear belongs in :meth:`finalize`.
        """
        return payload

    def accumulate(self, acc, payload, weight):
        """Fold one client into the accumulator: ``acc + w * accum(p)``.

        The product is formed in the accumulator's fp32 so low-precision
        payloads lose nothing until the single ``finalize`` cast.
        """
        return tm.tmap(lambda a, d: a + (weight * d.astype(a.dtype)),
                       acc, self.payload_accum(payload))

    def reduce_stacked(self, stacked_payloads, weights):
        """Weighted sum of a stacked cohort of payloads, in fp32.

        ``stacked_payloads`` carry a leading client axis; ``weights`` is the
        matching normalized fp32 vector. The result stays in the fp32
        accumulator space — :meth:`finalize` owns the single cast back to
        ``fed.delta_dtype``.
        """
        return server_lib.weighted_sum(
            jax.vmap(self.payload_accum)(stacked_payloads), weights,
            cast=False)

    def finalize(self, agg):
        """Accumulator -> pseudo-gradient: the single cast out of fp32."""
        return tm.tcast(agg, self.delta_dtype)

    def aggregate(self, stacked_payloads, weights):
        """Stacked payloads + normalized weights -> pseudo-gradient.

        Convenience composition of :meth:`reduce_stacked` and
        :meth:`finalize`; the engine calls the two halves separately so the
        server stage (which owns staleness discounting) runs ``finalize``.
        """
        return self.finalize(self.reduce_stacked(stacked_payloads, weights))

    def finish_cohort(self, state, agg):
        """Cohort-stage epilogue on the summed accumulator (traced, once per
        round, inside the cohort program).

        Runs after the placement fold but before the accumulator leaves the
        cohort program — the hook where fedlora decodes the low-rank
        accumulator back to parameter space using the *dispatch-time*
        ``state.round`` (the async engine may apply the result against a
        newer server state, whose round index would rebuild the wrong
        sketch). Default: identity.
        """
        del state
        return agg

    def map_components(self, fn: Callable, obj):
        """Apply ``fn`` to each parameter-shaped component of a payload or
        accumulator (used by the FSDP sharding hooks). Default: the object
        is itself one parameter-shaped tree.
        """
        return fn(obj)

    # -- communicated-bytes accounting --------------------------------------
    def abstract_payload(self, params):
        """Shape/dtype spec of one client's uplink payload.

        ``params`` may be concrete arrays or ShapeDtypeStructs; the result
        is always abstract (``jax.eval_shape`` — no allocation, exact for
        27B-class configs). ``compression.accounting.round_bytes`` turns
        this into the per-round ``bytes_up`` stamped on history records.
        """
        return jax.eval_shape(lambda p: tm.tcast(p, self.delta_dtype), params)

    def abstract_broadcast_extras(self, params):
        """Shape/dtype specs of per-round downlink extras beyond the params
        (:meth:`broadcast`). Default: none. Scalar bookkeeping that rides
        along (round indices) is counted too — the accounting is exact.
        """
        del params
        return ()

    # -- server ------------------------------------------------------------
    def server_update(self, state, agg, server_opt: Optimizer,
                      discount=None):
        """One server step on the aggregated statistic.

        ``discount`` (optional traced scalar, the async engine's
        ``staleness_discount ** s``) scales the pseudo-gradient in fp32 and
        casts back, so ``discount == 1.0`` is a bitwise no-op and the
        ``staleness=0`` async path matches the fused synchronous program.
        """
        pseudo_grad = self.finalize(agg)
        if discount is not None:
            d = jnp.asarray(discount, jnp.float32)
            pseudo_grad = tm.tmap(
                lambda x: (d * x.astype(jnp.float32)).astype(x.dtype),
                pseudo_grad)
        return server_lib.server_update(state, pseudo_grad, server_opt)
