"""FedAvg as a registered algorithm (Algorithm 2).

K local SGD steps, delta = theta_0 - theta_K: federated posterior
averaging with an identity covariance — the paper's biased special case
(Section 4), and the burn-in regime of the FedPA family.
"""
from __future__ import annotations

from typing import Callable

from repro.algorithms.base import (ClientResult, FedAlgorithm,
                                   register_algorithm)
from repro.core import tree_math as tm
from repro.core.dp_delta import fedavg_delta
from repro.core.iasg import sgd_steps
from repro.optim import Optimizer


@register_algorithm("fedavg")
class FedAvg(FedAlgorithm):
    """Weighted-mean-delta FedAvg; the template's defaults unchanged."""

    supports_step_budgets = True

    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """``update(params, batches) -> ClientResult`` — K local SGD steps."""
        delta_dtype = self.delta_dtype

        def update(params, batches):
            opt_state = client_opt.init(params)
            final, _, losses = sgd_steps(params, client_opt, opt_state,
                                         grad_fn, batches)
            delta = tm.tcast(fedavg_delta(params, final), delta_dtype)
            return ClientResult(delta, {"loss_first": losses[0],
                                        "loss_last": losses[-1]})

        return update
