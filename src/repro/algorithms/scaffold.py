"""SCAFFOLD as a registered algorithm (Karimireddy et al. 2020).

The canonical *stateful* federated algorithm: every client keeps a control
variate ``c_i`` (its running estimate of its own drift) and the server
keeps the population mean ``c``; each local step is corrected by
``c - c_i``, cancelling the client-drift bias that makes FedAvg converge
to a heterogeneity-weighted fixed point instead of the global optimum. In
the paper's posterior framing the correction de-biases local inference
toward the *global* posterior mode — exactly the bias FedPA attacks with
covariance estimates, attacked instead with first-order state.

State placement in this codebase:

* ``c_i`` lives in the engine's per-client ``ClientStateStore``
  (``init_client_state`` / the ``client_state`` update argument /
  ``ClientResult.state_update``);
* ``c`` lives in ``ServerState.algo_state`` (``init_algo_state``), is
  broadcast to the cohort through the ``broadcast`` hook, and is updated
  in ``server_update`` from the aggregated ``dc`` half of the payload:
  ``c += scaffold_c_scale * mean_i(c_i^+ - c_i)`` (the exact rule's
  ``|S|/N`` factor is the config knob — 1.0 under full participation).

Clients use *option II* of the paper: after K corrected SGD steps,
``c_i^+ = c_i - c + (theta_0 - theta_K) / (K * lr)`` — the running mean of
the uncorrected local gradients — which reuses the already-computed delta
instead of a second gradient pass.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.algorithms.base import (ClientResult, FedAlgorithm,
                                   register_algorithm)
from repro.core import server as server_lib
from repro.core import tree_math as tm
from repro.core.iasg import sgd_steps
from repro.optim import Optimizer


@register_algorithm("scaffold")
class Scaffold(FedAlgorithm):
    """SCAFFOLD: client + server control variates, option II correction."""

    stateful = True

    def validate(self) -> None:
        """Option II's closed form assumes plain SGD local steps."""
        super().validate()
        if self.fed.client_opt != "sgd":
            raise ValueError(
                f"scaffold requires client_opt='sgd': the option II control "
                f"variate c_i+ = c_i - c + delta/(K*lr) is the mean local "
                f"gradient only for vanilla SGD steps, got "
                f"{self.fed.client_opt!r}")
        if not 0.0 < self.fed.scaffold_c_scale <= 1.0:
            raise ValueError(
                f"scaffold_c_scale must be in (0, 1] (it is |S|/N of the "
                f"exact rule), got {self.fed.scaffold_c_scale}")

    # -- persistent state ----------------------------------------------------
    def init_client_state(self, params):
        """Client control variate c_i (zeros).

        Kept in fp32 REGARDLESS of ``delta_dtype``: the variates are
        running sums updated every participation, and re-rounding them to
        bf16 per round would stall the drift correction once per-round
        increments fall below one ulp — the same per-fold re-rounding the
        fp32 accumulator contract exists to prevent.
        """
        return tm.tzeros_like(params, jnp.float32)

    def init_algo_state(self, params):
        """Server control variate c = mean_i c_i (zeros, fp32 like c_i)."""
        return tm.tzeros_like(params, jnp.float32)

    def broadcast(self, state, server_opt: Optimizer) -> tuple:
        """Ship the server control variate c to the cohort."""
        del server_opt
        return (state.algo_state,)

    # -- client --------------------------------------------------------------
    def make_client_update(self, grad_fn: Callable,
                           client_opt: Optimizer) -> Callable:
        """``update(params, batches, c_i, c) -> ClientResult``.

        K SGD steps on the corrected gradient ``g + c - c_i``; payload is
        ``{"delta": theta_0 - theta_K, "dc": c_i^+ - c_i}`` and the state
        update is ``c_i^+`` (option II).
        """
        lr = self.fed.client_lr
        K = self.fed.local_steps
        delta_dtype = self.delta_dtype

        def update(params, batches, c_i, c):
            def corrected_grad(p, batch):
                loss, g = grad_fn(p, batch)
                g = tm.tmap(
                    lambda gi, cs, ci: gi + (cs - ci).astype(gi.dtype),
                    g, c, c_i)
                return loss, g

            opt_state = client_opt.init(params)
            final, _, losses = sgd_steps(params, client_opt, opt_state,
                                         corrected_grad, batches)
            # the control variate folds the UNcast fp32 delta (c_i and c
            # are fp32 persistent state, see init_client_state); only the
            # shipped delta gets the wire dtype
            delta32 = tm.tmap(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                params, final)
            c_new = tm.tmap(lambda ci, cs, d: ci - cs + d / (K * lr),
                            c_i, c, delta32)
            payload = {"delta": tm.tcast(delta32, delta_dtype),
                       "dc": tm.tsub(c_new, c_i)}
            return ClientResult(payload, {"loss_first": losses[0],
                                          "loss_last": losses[-1]},
                                state_update=c_new)

        return update

    # -- aggregation ---------------------------------------------------------
    def init_accum(self, params):
        """fp32 accumulator over both payload halves (delta and dc)."""
        return {"delta": tm.tzeros_like(params, jnp.float32),
                "dc": tm.tzeros_like(params, jnp.float32)}

    def finalize(self, agg):
        """Pseudo-gradient = the mean-delta half, cast once."""
        return tm.tcast(agg["delta"], self.delta_dtype)

    def map_components(self, fn: Callable, obj):
        """Payloads/accumulators are dicts of parameter-shaped trees."""
        return {k: fn(v) for k, v in obj.items()}

    def abstract_payload(self, params):
        """Uplink = wire-dtype delta + fp32 control-variate update."""
        return {
            "delta": jax.eval_shape(
                lambda p: tm.tcast(p, self.delta_dtype), params),
            "dc": jax.eval_shape(
                lambda p: tm.tzeros_like(p, jnp.float32), params),
        }

    def abstract_broadcast_extras(self, params):
        """Downlink extra: the fp32 server control variate c."""
        return (jax.eval_shape(
            lambda p: tm.tzeros_like(p, jnp.float32), params),)

    # -- server --------------------------------------------------------------
    def server_update(self, state, agg, server_opt: Optimizer,
                      discount=None):
        """Server step on the mean delta + control-variate update.

        ``c += scaffold_c_scale * mean_i(dc_i)``; a staleness ``discount``
        scales both the pseudo-gradient and the dc mean (a stale cohort's
        drift estimate is down-weighted exactly like its delta).
        """
        pseudo_grad = self.finalize(agg)
        dc = agg["dc"]
        if discount is not None:
            d = jnp.asarray(discount, jnp.float32)
            pseudo_grad = tm.tmap(
                lambda x: (d * x.astype(jnp.float32)).astype(x.dtype),
                pseudo_grad)
            dc = tm.tmap(lambda x: d * x, dc)
        scale = self.fed.scaffold_c_scale
        # c is fp32 persistent state, dc an fp32 accumulator: no rounding
        c = tm.tmap(lambda cs, dci: cs + scale * dci, state.algo_state, dc)
        new_state = server_lib.server_update(state, pseudo_grad, server_opt)
        return new_state._replace(algo_state=c)

    # payload_accum is the identity: {"delta", "dc"} is already linear.
