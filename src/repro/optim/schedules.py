"""Learning-rate schedules (server O(1/t) decay is what the paper's
Appendix A convergence discussion assumes; AFO baselines use exponential
client decay)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    """Flat schedule: lr at every step."""
    return lambda step: jnp.asarray(lr, jnp.float32)


def inverse_time_decay(lr: float, decay: float = 1.0):
    """lr / (1 + decay * t) — the O(t^{-1}) schedule of Appendix A.1."""
    return lambda step: lr / (1.0 + decay * step.astype(jnp.float32))


def exponential_decay(lr: float, rate: float, every: int):
    """lr * rate^(t/every) — the AFO-style exponential client decay."""
    return lambda step: lr * rate ** (step.astype(jnp.float32) / every)


def cosine_decay(lr: float, total_steps: int, floor: float = 0.0):
    """Cosine anneal from lr to floor over total_steps."""
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return floor + (lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int, floor: float = 0.0):
    """Linear warmup for ``warmup`` steps, then cosine decay to floor."""
    cos = cosine_decay(lr, max(total_steps - warmup, 1), floor)
    def fn(step):
        s = step.astype(jnp.float32)
        return jnp.where(s < warmup, lr * (s + 1) / warmup, cos(step - warmup))
    return fn
