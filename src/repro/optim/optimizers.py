"""Minimal optax-style optimizers (pure pytree transforms).

The paper's Algorithm 1 needs both a CLIENTOPT (SGD / SGD-momentum) and a
SERVEROPT (SGD-M for EMNIST/CIFAR, Adam for StackOverflow NWP, Adagrad for
StackOverflow LR — Table 4). All five are implemented here; ``update``
returns additive updates (params_new = params + updates), and the learning
rate may be a scalar or a schedule ``fn(step) -> lr``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro import tree_math as tm

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _zero_momentum(state, params):
    """Momentum accessor for momentum-free optimizers: a zeros tree."""
    del state
    return tm.tzeros_like(params)


def _state_momentum(state, params):
    """Momentum accessor for optimizers carrying an ``m`` buffer."""
    del params
    return state["m"]


class Optimizer(NamedTuple):
    """A stateless optimizer triple: init, update, and a momentum accessor."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params) -> (updates, state)
    # momentum(opt_state, params) -> the first-moment buffer (zeros for
    # momentum-free optimizers) — the explicit accessor MIME's broadcast
    # hook reads instead of probing the state dict for an "m" key.
    momentum: Callable[[Any, Any], Any] = _zero_momentum


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else lr


def apply_updates(params, updates):
    """Add updates to params, casting each update to its param's dtype."""
    return tm.tmap(lambda p, u: p + u.astype(p.dtype), params, updates)


def sgd(lr: Schedule) -> Optimizer:
    """Plain SGD: update = -lr * grad."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        a = _lr_at(lr, state["step"])
        return tm.tscale(-a, grads), {"step": state["step"] + 1}

    return Optimizer(init, update)


def sgdm(lr: Schedule, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    """SGD with (optionally Nesterov) momentum."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32), "m": tm.tzeros_like(params)}

    def update(grads, state, params=None):
        m = tm.tmap(lambda mi, g: momentum * mi + g, state["m"], grads)
        d = tm.tmap(lambda mi, g: momentum * mi + g, m, grads) if nesterov else m
        a = _lr_at(lr, state["step"])
        return tm.tscale(-a, d), {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update, momentum=_state_momentum)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.99,
         eps: float = 1e-3) -> Optimizer:
    """Adam with the FL-style large epsilon default (Reddi et al. 2020)."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": tm.tzeros_like(params, jnp.float32),
            "v": tm.tzeros_like(params, jnp.float32),
        }

    def update(grads, state, params=None):
        t = state["step"] + 1
        m = tm.tmap(lambda mi, g: b1 * mi + (1 - b1) * g, state["m"], grads)
        v = tm.tmap(lambda vi, g: b2 * vi + (1 - b2) * g * g, state["v"], grads)
        tf = t.astype(jnp.float32)
        mhat = tm.tscale(1.0 / (1 - b1**tf), m)
        vhat = tm.tscale(1.0 / (1 - b2**tf), v)
        a = _lr_at(lr, state["step"])
        upd = tm.tmap(lambda mi, vi: -a * mi / (jnp.sqrt(vi) + eps), mhat, vhat)
        return upd, {"step": t, "m": m, "v": v}

    return Optimizer(init, update, momentum=_state_momentum)


def adagrad(lr: Schedule, eps: float = 1e-5) -> Optimizer:
    """Adagrad: per-parameter lr scaled by accumulated squared grads."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "v": tm.tzeros_like(params, jnp.float32)}

    def update(grads, state, params=None):
        v = tm.tmap(lambda vi, g: vi + g * g, state["v"], grads)
        a = _lr_at(lr, state["step"])
        upd = tm.tmap(lambda g, vi: -a * g / (jnp.sqrt(vi) + eps), grads, v)
        return upd, {"step": state["step"] + 1, "v": v}

    return Optimizer(init, update)


def yogi(lr: Schedule, b1: float = 0.9, b2: float = 0.99,
         eps: float = 1e-3) -> Optimizer:
    """Yogi (Zaheer et al. 2018): Adam with sign-controlled v updates."""
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": tm.tzeros_like(params, jnp.float32),
            "v": tm.tzeros_like(params, jnp.float32),
        }

    def update(grads, state, params=None):
        t = state["step"] + 1
        m = tm.tmap(lambda mi, g: b1 * mi + (1 - b1) * g, state["m"], grads)
        v = tm.tmap(
            lambda vi, g: vi - (1 - b2) * jnp.sign(vi - g * g) * g * g,
            state["v"], grads,
        )
        a = _lr_at(lr, state["step"])
        upd = tm.tmap(lambda mi, vi: -a * mi / (jnp.sqrt(jnp.abs(vi)) + eps), m, v)
        return upd, {"step": t, "m": m, "v": v}

    return Optimizer(init, update, momentum=_state_momentum)


_REGISTRY = {"sgd": sgd, "sgdm": sgdm, "adam": adam, "adagrad": adagrad,
             "yogi": yogi}


def get_optimizer(name: str, lr: Schedule, momentum: float = 0.9) -> Optimizer:
    """Look up an optimizer by registry name (momentum only used by sgdm)."""
    if name == "sgdm":
        return sgdm(lr, momentum)
    if name not in _REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}; known: {list(_REGISTRY)}")
    return _REGISTRY[name](lr)
