"""Server/client optimizers and learning-rate schedules."""
from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adagrad,
    adam,
    apply_updates,
    get_optimizer,
    sgd,
    sgdm,
    yogi,
)
from repro.optim.schedules import (  # noqa: F401
    constant,
    cosine_decay,
    exponential_decay,
    inverse_time_decay,
    warmup_cosine,
)
