"""Exact per-round communicated-bytes accounting from abstract payloads.

Everything here runs on ``jax.eval_shape`` stand-ins — no device
allocation, so it is exact for the 27B-class configs too. Uplink is the
algorithm's per-client payload (``FedAlgorithm.abstract_payload``);
downlink is the broadcast parameters plus any algorithm extras
(``abstract_broadcast_extras`` — SCAFFOLD's control variate, MIME's
server momentum). Both engines stamp the resulting ``bytes_up`` /
``bytes_down`` into every ``history[t]`` record.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np


def tree_nbytes(tree) -> int:
    """Total bytes of a pytree of arrays or ``ShapeDtypeStruct``s."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(leaf.shape, dtype=np.int64)) * np.dtype(
            leaf.dtype).itemsize
    return int(total)


def round_bytes(fed, params, use_sampling: bool = True) -> Dict[str, int]:
    """Exact per-round wire bytes for ``fed`` on ``params``-shaped models.

    ``params`` may be concrete arrays or ShapeDtypeStructs. Returns
    per-client and per-round (x ``clients_per_round``) uplink/downlink
    totals; ``use_sampling=False`` accounts the burn-in regime's
    algorithm instead (``resolve_algorithm``).
    """
    from repro.algorithms import resolve_algorithm  # noqa: PLC0415 — cycle

    alg = resolve_algorithm(fed, use_sampling)
    abstract = jax.eval_shape(lambda p: p, params)
    up = tree_nbytes(alg.abstract_payload(abstract))
    down = tree_nbytes(abstract) + tree_nbytes(
        alg.abstract_broadcast_extras(abstract))
    c = int(fed.clients_per_round)
    return {
        "bytes_up_per_client": up,
        "bytes_down_per_client": down,
        "bytes_up": c * up,
        "bytes_down": c * down,
    }
