"""Pluggable payload codecs: compress client payloads before aggregation.

A :class:`PayloadCodec` maps a parameter-shaped pytree (a client delta, or
an auxiliary statistic like a diagonal precision) to a compact wire form
and back. Codecs compose left-to-right via ``"+"`` specs — e.g.
``"lowrank+int8"`` projects 2-D deltas onto rank-r factors and then
quantizes the factors — subject to one structural rule: every **linear**
stage must precede every nonlinear one. The linear prefix defines the
*accumulator space* (the server can sum encoded payloads directly, which
keeps sequential/chunked folding cheap), while the nonlinear suffix
(quantization) is undone per-client before accumulation.

The registry mirrors ``algorithms``: codecs self-register by name, and
``FedConfig.payload_codec`` selects a chain eagerly at config time.
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

from jax import numpy as jnp

from repro.core import tree_math as tm


class PayloadCodec:
    """One compression stage; stateless, parameterized by the FedConfig.

    Subclasses set ``name`` (registry key) and ``linear``. Linear stages
    must satisfy ``encode(a*x + b*y) == a*encode(x) + b*encode(y)`` so the
    round accumulator can live in their image; nonlinear stages (e.g.
    quantization) are undone per-client before accumulation.
    """

    name: str = "?"
    #: True when encode is linear in the input tree (accumulation-safe)
    linear: bool = False

    def __init__(self, fed):
        self.fed = fed

    # -- wire form ----------------------------------------------------------
    def encode(self, tree, round_idx):
        """Parameter-shaped (or upstream-encoded) tree -> wire form."""
        raise NotImplementedError

    def decode(self, tree, round_idx, like):
        """Inverse of :meth:`encode`.

        ``like`` is a tree with the *pre-encode* leaf shapes (needed to
        rebuild projection bases); nonlinear codecs may ignore it.
        """
        raise NotImplementedError

    # -- accumulator space (linear stages only) -----------------------------
    def accum_like(self, tree):
        """Map a pre-encode-shaped zeros tree to encoded-shaped fp32 zeros.

        Only meaningful for ``linear`` stages: the result seeds the round
        accumulator without running :meth:`encode` (no sketch/QR work).
        """
        raise NotImplementedError

    def project_precision(self, prec, round_idx):
        """Push a diagonal precision through the stage's projection.

        Identity for stages that do not change leaf shapes. Only linear
        stages are ever asked (precisions ride the accumulator space).
        """
        return prec


_REGISTRY: Dict[str, Type[PayloadCodec]] = {}


def register_codec(name: str, *, override: bool = False):
    """Class decorator: register a codec under ``name`` (sets ``cls.name``).

    Re-registering an existing name raises — a silent swap would change
    what every ``payload_codec`` spec using it decodes to — unless
    ``override=True`` is passed explicitly.
    """

    def wrap(cls: Type[PayloadCodec]) -> Type[PayloadCodec]:
        if not override and name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(
                f"codec {name!r} is already registered to "
                f"{_REGISTRY[name]!r}; pass override=True to replace it")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return wrap


def codec_names() -> Tuple[str, ...]:
    """Sorted names of every registered codec stage."""
    return tuple(sorted(_REGISTRY))


def parse_codec(spec: str) -> Tuple[str, ...]:
    """Split + validate a ``"+"``-composed codec spec, eagerly.

    Raises ``ValueError`` (naming the registry) on unknown stages, on
    ``"none"`` composed with anything, on duplicates, and on a linear
    stage appearing after a nonlinear one — the accumulator must be the
    image of the linear *prefix*.
    """
    if not isinstance(spec, str) or not spec:
        raise ValueError(f"payload_codec must be a non-empty str, got {spec!r}")
    stages = tuple(s.strip() for s in spec.split("+"))
    for s in stages:
        if s not in _REGISTRY:
            raise ValueError(
                f"unknown payload codec {s!r} in spec {spec!r}; "
                f"registered codecs: {codec_names()}")
    if "none" in stages and len(stages) > 1:
        raise ValueError(f"codec 'none' cannot be composed: {spec!r}")
    if len(set(stages)) != len(stages):
        raise ValueError(f"duplicate codec stage in spec {spec!r}")
    seen_nonlinear = False
    for s in stages:
        if _REGISTRY[s].linear and seen_nonlinear:
            raise ValueError(
                f"linear codec {s!r} after a nonlinear stage in {spec!r}: "
                "linear stages must form a prefix (they define the "
                "accumulator space)")
        seen_nonlinear = seen_nonlinear or not _REGISTRY[s].linear
    return stages


class CodecChain:
    """An ordered codec pipeline split into linear prefix + nonlinear suffix.

    ``encode``/``decode`` run the full pipeline (the client wire format);
    ``to_accum`` undoes only the nonlinear suffix (per-client, pre-sum);
    ``decode_accum`` undoes only the linear prefix (server-side, once per
    round, on the summed accumulator).
    """

    def __init__(self, fed):
        names = parse_codec(fed.payload_codec)
        self.stages = tuple(_REGISTRY[n](fed) for n in names)
        self.prefix = tuple(s for s in self.stages if s.linear)
        self.suffix = tuple(s for s in self.stages if not s.linear)

    @property
    def is_identity(self) -> bool:
        """True when the chain is a no-op (the ``none`` codec)."""
        return all(s.name == "none" for s in self.stages)

    def encode(self, tree, round_idx):
        """Full pipeline: parameter-shaped tree -> wire form."""
        for s in self.stages:
            tree = s.encode(tree, round_idx)
        return tree

    def decode(self, tree, round_idx, like):
        """Full inverse pipeline; ``like`` carries pre-encode leaf shapes."""
        for s in reversed(self.stages):
            tree = s.decode(tree, round_idx, like)
        return tree

    def to_accum(self, tree):
        """Undo the nonlinear suffix only: wire form -> accumulator space."""
        for s in reversed(self.suffix):
            tree = s.decode(tree, None, None)
        return tree

    def encode_aux(self, tree, round_idx):
        """Apply the nonlinear suffix only (for already-projected stats)."""
        for s in self.suffix:
            tree = s.encode(tree, round_idx)
        return tree

    def decode_accum(self, tree, round_idx, like):
        """Undo the linear prefix: accumulator space -> parameter space."""
        for s in reversed(self.prefix):
            tree = s.decode(tree, round_idx, like)
        return tree

    def project_precision(self, prec, round_idx):
        """Parameter-shaped diagonal precision -> accumulator space."""
        for s in self.prefix:
            prec = s.project_precision(prec, round_idx)
        return prec

    def accum_zeros(self, params):
        """Fresh fp32 zeros of the accumulator (linear-prefix image) space."""
        z = tm.tzeros_like(params, jnp.float32)
        for s in self.prefix:
            z = s.accum_like(z)
        return z


def build_codec(fed) -> CodecChain:
    """The :class:`CodecChain` selected by ``fed.payload_codec``."""
    return CodecChain(fed)


@register_codec("none")
class IdentityCodec(PayloadCodec):
    """The identity chain: dense payloads, zero compression."""

    linear = True

    def encode(self, tree, round_idx):
        """Identity."""
        del round_idx
        return tree

    def decode(self, tree, round_idx, like):
        """Identity."""
        del round_idx, like
        return tree

    def accum_like(self, tree):
        """Identity (the tree is already fp32 zeros)."""
        return tree
