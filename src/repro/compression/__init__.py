"""Payload compression: codec registry, low-rank sketches, quantization,
and exact communicated-bytes accounting."""
from repro.compression import lowrank, quant  # noqa: F401 — register codecs
from repro.compression.accounting import round_bytes, tree_nbytes
from repro.compression.base import (
    CodecChain,
    PayloadCodec,
    build_codec,
    codec_names,
    parse_codec,
    register_codec,
)

__all__ = [
    "CodecChain",
    "PayloadCodec",
    "build_codec",
    "codec_names",
    "parse_codec",
    "register_codec",
    "round_bytes",
    "tree_nbytes",
]
