"""Symmetric per-leaf integer quantization of payload leaves.

Each array leaf becomes ``{"q": intN, "scale": f32 scalar}`` with
``scale = max(|x|) / qmax`` — 4x fewer wire bytes than fp32 at 8 bits
(2x at 16). Quantization is *nonlinear* (the scale depends on the leaf),
so it always sits at the end of a codec chain and is undone per-client
(``CodecChain.to_accum``) before payloads are summed; the round
accumulator never sees integer leaves. ``FedConfig.quant_bits`` selects
8 or 16 bits.
"""
from __future__ import annotations

import jax
from jax import numpy as jnp

from repro.compression.base import PayloadCodec, register_codec

#: guards the scale against an all-zero leaf (decode then yields zeros)
_SCALE_EPS = 1e-12


def _is_qleaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


@register_codec("int8")
class QuantCodec(PayloadCodec):
    """Symmetric round-to-nearest quantizer; bit width from ``quant_bits``."""

    linear = False

    def __init__(self, fed):
        super().__init__(fed)
        bits = int(fed.quant_bits)
        if bits not in (8, 16):
            raise ValueError(f"quant_bits must be 8 or 16, got {bits}")
        self.qmax = float(2 ** (bits - 1) - 1)
        self.qdtype = jnp.int8 if bits == 8 else jnp.int16

    def encode(self, tree, round_idx):
        """Each array leaf -> ``{"q": intN, "scale": f32 scalar}``."""
        del round_idx

        def leaf(x):
            x32 = x.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(x32)), _SCALE_EPS) / self.qmax
            q = jnp.clip(jnp.round(x32 / scale), -self.qmax,
                         self.qmax).astype(self.qdtype)
            return {"q": q, "scale": scale.astype(jnp.float32)}

        return jax.tree_util.tree_map(leaf, tree)

    def decode(self, tree, round_idx, like):
        """Dequantize every ``{"q", "scale"}`` leaf back to fp32."""
        del round_idx, like
        return jax.tree_util.tree_map(
            lambda d: d["q"].astype(jnp.float32) * d["scale"],
            tree, is_leaf=_is_qleaf)
