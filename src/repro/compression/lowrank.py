"""Low-rank sketch codec: project 2-D+ deltas onto rank-r factors.

Each eligible leaf (``ndim >= 2`` and trailing dim ``> lora_rank``) is
right-multiplied by an orthonormal basis ``V`` of shape
``(last_dim, rank)`` — the federated-LoRA wire format: clients ship the
rank-r factor ``x @ V`` instead of the dense delta. The basis is a
deterministic function of ``(seed, round, leaf_index)`` regenerated on
both sides, so it never travels: downlink stays parameter-sized and
uplink shrinks by ``last_dim / rank`` per eligible leaf. Rotating the
sketch every round means the error-feedback residual (see
``algorithms/fedlora.py``) is re-expressed in a fresh subspace each
participation, which is what lets the composed update span the full
space over time.

The projection is linear, so the round accumulator lives in the sketch
image (``accum_like``) and diagonal precisions push through it via the
variance rule ``var_enc = var @ (V * V)`` (``project_precision``).
"""
from __future__ import annotations

import jax
from jax import numpy as jnp

from repro.compression.base import PayloadCodec, register_codec

#: fixed root seed for basis generation — shared by clients and server;
#: per-round variation comes from folding in the round index
_BASIS_SEED = 0x10A4

_EPS = 1e-12


@register_codec("lowrank")
class LowRankCodec(PayloadCodec):
    """Deterministic per-(round, leaf) Gaussian sketch, orthonormalized."""

    linear = True

    def __init__(self, fed):
        super().__init__(fed)
        self.rank = int(fed.lora_rank)

    def _eligible(self, shape) -> bool:
        return len(shape) >= 2 and shape[-1] > self.rank

    def _basis(self, last_dim: int, round_idx, leaf_idx: int):
        """Orthonormal ``(last_dim, rank)`` basis for one leaf, one round."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(_BASIS_SEED), round_idx),
            leaf_idx)
        g = jax.random.normal(key, (last_dim, self.rank), jnp.float32)
        q, _ = jnp.linalg.qr(g)
        return q

    def _map_leaves(self, tree, like, fn):
        """Apply ``fn(leaf_idx, leaf, ref)`` per leaf; ``ref`` carries the
        pre-encode shape (``like`` defaults to the tree itself)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        refs = (jax.tree_util.tree_leaves(like) if like is not None
                else leaves)
        out = [fn(i, x, ref) for i, (x, ref) in enumerate(zip(leaves, refs))]
        return jax.tree_util.tree_unflatten(treedef, out)

    def encode(self, tree, round_idx):
        """Right-project eligible leaves: ``x -> x @ V`` (fp32 matmul)."""

        def leaf(i, x, ref):
            if not self._eligible(ref.shape):
                return x
            v = self._basis(ref.shape[-1], round_idx, i)
            return (x.astype(jnp.float32) @ v).astype(x.dtype)

        return self._map_leaves(tree, None, leaf)

    def decode(self, tree, round_idx, like):
        """Lift back: ``y -> y @ V.T`` using ``like`` for original shapes."""

        def leaf(i, y, ref):
            if not self._eligible(ref.shape):
                return y
            v = self._basis(ref.shape[-1], round_idx, i)
            return (y.astype(jnp.float32) @ v.T).astype(y.dtype)

        return self._map_leaves(tree, like, leaf)

    def accum_like(self, tree):
        """Encoded-shaped fp32 zeros without any sketch/QR work."""

        def leaf(i, x, ref):
            del i
            if not self._eligible(ref.shape):
                return jnp.zeros(x.shape, jnp.float32)
            return jnp.zeros(x.shape[:-1] + (self.rank,), jnp.float32)

        return self._map_leaves(tree, None, leaf)

    def project_precision(self, prec, round_idx):
        """Diagonal precision -> sketch space via the variance rule.

        A diagonal Gaussian with variance ``1/p`` projected by ``V`` has
        coordinate variances ``(1/p) @ (V * V)`` (exact for orthonormal
        ``V`` up to the dropped off-diagonal terms), so the encoded
        precision is its reciprocal.
        """

        def leaf(i, p, ref):
            if not self._eligible(ref.shape):
                return p
            v = self._basis(ref.shape[-1], round_idx, i)
            var = 1.0 / jnp.maximum(p.astype(jnp.float32), _EPS)
            var_enc = var @ (v * v)
            return (1.0 / jnp.maximum(var_enc, _EPS)).astype(p.dtype)

        return self._map_leaves(prec, None, leaf)
