"""Pytree checkpointing: npz payload + json manifest.

Leaves are addressed by their tree keypath so a checkpoint is readable
without unpickling arbitrary objects, restores are structure-checked, and
dtype/shape mismatches fail loudly. Used for federated server state
(params + server-opt state + round counter).

The sharded family (``save_store_sharded`` / ``restore_store_sharded``)
checkpoints a population-sharded client-state store shard-locally: each
host writes only the rows its devices own, as
``ckpt_<step>.shard<k>of<n>.npz`` next to the (process-0-only) server
checkpoint. Restore prefers the matching shard (same row span) and falls
back to a replicated read — every shard loaded and concatenated — when
the process topology changed between save and restore.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path) or "<root>"


def _pack_leaves(state: Any):
    """Flatten ``state`` into npz-storable arrays + a keypath manifest."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(state)[0]
    arrays = {}
    manifest = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if dtype not in ("float64", "float32", "float16", "int64", "int32",
                         "int16", "int8", "uint8", "uint16", "uint32",
                         "uint64", "bool"):
            # npz can't store ml_dtypes (bfloat16, fp8): store widened,
            # restore casts back via the template dtype (exact for bf16)
            arr = arr.astype(np.float32)
        key = f"leaf_{i}"
        arrays[key] = arr
        manifest.append({"key": key, "path": _keystr(path),
                         "shape": list(arr.shape), "dtype": dtype})
    return arrays, manifest


def _write_base(base: str, arrays: dict, payload: dict) -> str:
    np.savez(base + ".npz", **arrays)
    with open(base + ".json", "w") as f:
        json.dump(payload, f, indent=1)
    return base + ".npz"


def save_checkpoint(ckpt_dir: str, state: Any, step: int,
                    metadata: Optional[dict] = None) -> str:
    """Write ``state`` as ckpt_<step>.npz + a .json path/dtype manifest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, manifest = _pack_leaves(state)
    base = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    return _write_base(base, arrays, {"step": step,
                                      "metadata": metadata or {},
                                      "manifest": manifest})


def latest_checkpoint(ckpt_dir: str) -> Optional[int]:
    """Return the highest checkpoint step in ``ckpt_dir`` (None if empty)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(ckpt_dir)
        if (m := re.match(r"ckpt_(\d+)\.npz$", fn))
    ]
    return max(steps) if steps else None


def _restore_leaves(like: Any, meta: dict, data, *,
                    rows_free: bool = False) -> Any:
    """Rebuild ``like``'s structure from a manifest + npz payload.

    Shapes are verified against the template; with ``rows_free`` the
    leading (row) dimension is exempt — the shard-concatenation path loads
    slices whose row counts depend on the saving topology.
    """
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    if len(leaves_with_paths) != len(meta["manifest"]):
        raise ValueError(
            f"leaf count mismatch: template {len(leaves_with_paths)} vs "
            f"checkpoint {len(meta['manifest'])}"
        )
    by_path = {m["path"]: m for m in meta["manifest"]}
    out = []
    for path, leaf in leaves_with_paths:
        ks = _keystr(path)
        if ks not in by_path:
            raise KeyError(f"checkpoint missing leaf {ks}")
        m = by_path[ks]
        arr = data[m["key"]]
        want = np.asarray(leaf)
        got, exp = list(arr.shape), list(want.shape)
        if rows_free:
            got, exp = got[1:], exp[1:]
        if got != exp:
            raise ValueError(f"{ks}: shape {arr.shape} != template {want.shape}")
        out.append(arr.astype(want.dtype))
    return treedef.unflatten(out)


def restore_checkpoint(ckpt_dir: str, like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    if step is None:
        step = latest_checkpoint(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    base = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    with open(base + ".json") as f:
        meta = json.load(f)
    data = np.load(base + ".npz")
    tree = _restore_leaves(like, meta, data)
    return tree, meta["step"], meta["metadata"]


# ---------------------------------------------------------------------------
# Shard-local client-store checkpoints
# ---------------------------------------------------------------------------

_SHARD_RE = re.compile(r"ckpt_(\d+)\.shard(\d+)of(\d+)\.npz$")


def _shard_base(ckpt_dir: str, step: int, index: int, count: int) -> str:
    return os.path.join(ckpt_dir,
                        f"ckpt_{step:08d}.shard{index}of{count}")


def save_checkpoint_shard(ckpt_dir: str, state: Any, step: int, *,
                          row_offset: int, shard_index: int,
                          num_shards: int,
                          metadata: Optional[dict] = None) -> str:
    """Write one host's slice of a row-sharded state tree.

    The file name (``ckpt_<step>.shard<k>of<n>.npz``) is disjoint from the
    plain ``ckpt_<step>.npz`` family, so ``latest_checkpoint`` never picks
    up a shard. The json records ``row_offset`` — where this shard's rows
    sit in the global population — which is what restore matches against.
    """
    if not 0 <= shard_index < num_shards:
        raise ValueError(f"shard_index {shard_index} out of range for "
                         f"{num_shards} shards")
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, manifest = _pack_leaves(state)
    base = _shard_base(ckpt_dir, step, shard_index, num_shards)
    return _write_base(base, arrays, {
        "step": step, "metadata": metadata or {}, "manifest": manifest,
        "shard": {"index": shard_index, "count": num_shards,
                  "row_offset": row_offset},
    })


def latest_sharded_checkpoint(ckpt_dir: str) -> Optional[int]:
    """Highest step with a *complete* shard set (all n of n files).

    An in-progress save (some hosts finished, some not) is skipped so a
    restore racing a crash lands on the last complete step.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    found: dict = {}
    for fn in os.listdir(ckpt_dir):
        m = _SHARD_RE.match(fn)
        if m:
            step, idx, count = (int(g) for g in m.groups())
            found.setdefault((step, count), set()).add(idx)
    complete = [step for (step, count), idxs in found.items()
                if len(idxs) == count]
    return max(complete) if complete else None


def _read_shard(ckpt_dir: str, step: int, index: int, count: int):
    base = _shard_base(ckpt_dir, step, index, count)
    with open(base + ".json") as f:
        meta = json.load(f)
    return meta, np.load(base + ".npz")


def _shard_metas(ckpt_dir: str, step: int):
    """All shard manifests for ``step`` (json only — npz stays unread)."""
    metas = []
    for fn in sorted(os.listdir(ckpt_dir)):
        m = _SHARD_RE.match(fn)
        if m and int(m.group(1)) == step:
            base = os.path.join(ckpt_dir, fn[:-len(".npz")])
            with open(base + ".json") as f:
                metas.append(json.load(f))
    if not metas:
        raise FileNotFoundError(
            f"no shard checkpoints for step {step} in {ckpt_dir}")
    count = metas[0]["shard"]["count"]
    if len(metas) != count:
        raise FileNotFoundError(
            f"step {step} has {len(metas)}/{count} shards in {ckpt_dir}")
    return metas


def save_store_sharded(ckpt_dir: str, store, step: int,
                       metadata: Optional[dict] = None) -> str:
    """Checkpoint a client-state store shard-locally.

    Every process calls this; each writes only the rows its devices own
    (via the store's ``local_state_dict``). A store without the sharded
    API (the host store) writes its full state as the single shard of 1 —
    same file family, so restore is uniform.
    """
    if hasattr(store, "local_state_dict"):
        state, row_offset = store.local_state_dict()
    else:
        state, row_offset = store.state_dict(), 0
    index = jax.process_index()
    count = jax.process_count()
    return save_checkpoint_shard(ckpt_dir, state, step,
                                 row_offset=row_offset, shard_index=index,
                                 num_shards=count, metadata=metadata)


def restore_store_sharded(ckpt_dir: str, store,
                          step: Optional[int] = None) -> int:
    """Restore a client-state store from its shard files (in place).

    Fast path: a shard whose row span matches the rows this process's
    devices own is loaded alone and written back with
    ``load_local_state_dict`` — nothing crosses the host boundary. When
    the topology changed between save and restore (different process
    count or mesh layout) every shard is read and concatenated in row
    order — the replicated-read fallback — and loaded through the full
    ``load_state_dict``. Returns the restored step.
    """
    if step is None:
        step = latest_sharded_checkpoint(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no shard checkpoints in {ckpt_dir}")
    metas = _shard_metas(ckpt_dir, step)
    count = metas[0]["shard"]["count"]
    sharded = hasattr(store, "local_state_dict")
    if sharded:
        local, row_offset = store.local_state_dict()
    else:
        local, row_offset = store.state_dict(), 0
    local_rows = int(np.asarray(local["stamps"]).shape[0])
    match = next(
        (m for m in metas
         if m["shard"]["row_offset"] == row_offset
         and m["manifest"] and m["manifest"][0]["shape"][0] == local_rows),
        None)
    if match is not None:
        meta, data = _read_shard(ckpt_dir, step, match["shard"]["index"],
                                 count)
        tree = _restore_leaves(local, meta, data)
        if sharded:
            store.load_local_state_dict(tree, row_offset)
        else:
            store.load_state_dict(tree)
        return step
    # replicated read: concatenate every shard's rows in population order
    parts = []
    for m in sorted(metas, key=lambda m: m["shard"]["row_offset"]):
        meta, data = _read_shard(ckpt_dir, step, m["shard"]["index"], count)
        parts.append((m["shard"]["row_offset"],
                      _restore_leaves(local, meta, data, rows_free=True)))
    offsets = [off for off, _ in parts]
    rows = [np.asarray(t["stamps"]).shape[0] for _, t in parts]
    if offsets[0] != 0:
        raise ValueError(f"first shard starts at row {offsets[0]}, not 0")
    for (off, r), nxt in zip(zip(offsets, rows), offsets[1:] + [None]):
        if nxt is not None and off + r != nxt:
            raise ValueError(
                f"shard rows are not contiguous: [{off}, {off + r}) then "
                f"{nxt} — cannot reassemble the population")
    full = jax.tree_util.tree_map(
        lambda *xs: np.concatenate(xs, axis=0), *[t for _, t in parts])
    total = int(np.asarray(full["stamps"]).shape[0])
    if total != store.num_clients:
        raise ValueError(
            f"reassembled population has {total} rows, store expects "
            f"{store.num_clients}")
    store.load_state_dict(full)
    return step
