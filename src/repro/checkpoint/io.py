"""Pytree checkpointing: npz payload + json manifest.

Leaves are addressed by their tree keypath so a checkpoint is readable
without unpickling arbitrary objects, restores are structure-checked, and
dtype/shape mismatches fail loudly. Used for federated server state
(params + server-opt state + round counter).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path) or "<root>"


def save_checkpoint(ckpt_dir: str, state: Any, step: int,
                    metadata: Optional[dict] = None) -> str:
    """Write ``state`` as ckpt_<step>.npz + a .json path/dtype manifest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(state)[0]
    arrays = {}
    manifest = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if dtype not in ("float64", "float32", "float16", "int64", "int32",
                         "int16", "int8", "uint8", "uint16", "uint32",
                         "uint64", "bool"):
            # npz can't store ml_dtypes (bfloat16, fp8): store widened,
            # restore casts back via the template dtype (exact for bf16)
            arr = arr.astype(np.float32)
        key = f"leaf_{i}"
        arrays[key] = arr
        manifest.append({"key": key, "path": _keystr(path),
                         "shape": list(arr.shape), "dtype": dtype})
    base = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    np.savez(base + ".npz", **arrays)
    with open(base + ".json", "w") as f:
        json.dump({"step": step, "metadata": metadata or {},
                   "manifest": manifest}, f, indent=1)
    return base + ".npz"


def latest_checkpoint(ckpt_dir: str) -> Optional[int]:
    """Return the highest checkpoint step in ``ckpt_dir`` (None if empty)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(ckpt_dir)
        if (m := re.match(r"ckpt_(\d+)\.npz$", fn))
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    if step is None:
        step = latest_checkpoint(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    base = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    with open(base + ".json") as f:
        meta = json.load(f)
    data = np.load(base + ".npz")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    if len(leaves_with_paths) != len(meta["manifest"]):
        raise ValueError(
            f"leaf count mismatch: template {len(leaves_with_paths)} vs "
            f"checkpoint {len(meta['manifest'])}"
        )
    by_path = {m["path"]: m for m in meta["manifest"]}
    out = []
    for path, leaf in leaves_with_paths:
        ks = _keystr(path)
        if ks not in by_path:
            raise KeyError(f"checkpoint missing leaf {ks}")
        m = by_path[ks]
        arr = data[m["key"]]
        want = np.asarray(leaf)
        if list(arr.shape) != list(want.shape):
            raise ValueError(f"{ks}: shape {arr.shape} != template {want.shape}")
        out.append(arr.astype(want.dtype))
    return treedef.unflatten(out), meta["step"], meta["metadata"]
