"""Checkpoint save/restore for server state (checkpoint.io)."""
from repro.checkpoint.io import (  # noqa: F401
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
