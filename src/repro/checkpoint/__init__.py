"""Checkpoint save/restore for server state (checkpoint.io)."""
from repro.checkpoint.io import (  # noqa: F401
    latest_checkpoint,
    latest_sharded_checkpoint,
    restore_checkpoint,
    restore_store_sharded,
    save_checkpoint,
    save_checkpoint_shard,
    save_store_sharded,
)
