"""Pallas TPU kernel for one Sherman-Morrison DP step (the paper's Table-1
hot spot).

One DP step over a d-vector with history {v_k}_{k<t} costs O(t d) flops at
arithmetic intensity ~O(1) flop/byte — purely HBM-bandwidth-bound. A naive
jnp implementation makes ~2t+2 separate passes over HBM (one per dot, one
per axpy). The kernel reshapes the step into two fused passes:

  pass A (reduce): per d-tile, read (u, delta~, V[0:t]) once and emit the
      partial dots <v_k, u> for every k, plus <u, u> and <u, delta~>.
      All Sherman-Morrison scalar coefficients derive from these:
      a_t = <u,u> - sum_k c_k <v_k,u>^2  (since v = u - sum c_k <v_k,u> v_k
      and V rows are Sigma~^{-1}-conjugate by construction).
  pass B (map): per d-tile, read (u, delta~, V[0:t]) once and write
      v_t = u - sum_k w_k v_k   and   delta~' = delta~ - s * v_t.

VMEM tiling: blocks are (l_pad, TILE_D) for the history and (1, TILE_D) for
the vectors, TILE_D a multiple of 128 lanes; l_pad is the static history
capacity (samples per round are single digits, so the whole history column
fits VMEM many times over). Validated against ``ref.py`` in interpret mode;
TPU is the target, not the runtime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 512


def _reduce_kernel(u_ref, delta_ref, v_ref, out_ref):
    """out[0, :lp] = partial <v_k, u>; out[0, lp] = <u,u>; out[0, lp+1] = <u,delta>."""
    u = u_ref[0, :].astype(jnp.float32)
    delta = delta_ref[0, :].astype(jnp.float32)
    V = v_ref[...].astype(jnp.float32)              # (lp, TILE_D)
    dots = jnp.sum(V * u[None, :], axis=1)          # (lp,)
    uu = jnp.sum(u * u)
    ud = jnp.sum(u * delta)
    out_ref[0, : dots.shape[0]] = dots
    out_ref[0, dots.shape[0]] = uu
    out_ref[0, dots.shape[0] + 1] = ud


def _map_kernel(w_ref, s_ref, u_ref, delta_ref, v_ref, vout_ref, dout_ref):
    """vout = u - sum_k w[k] V[k];  dout = delta - s * vout."""
    u = u_ref[0, :].astype(jnp.float32)
    delta = delta_ref[0, :].astype(jnp.float32)
    V = v_ref[...].astype(jnp.float32)
    w = w_ref[0, : V.shape[0]].astype(jnp.float32)  # (lp,) (drop lane padding)
    v_new = u - jnp.sum(w[:, None] * V, axis=0)
    s = s_ref[0, 0]
    vout_ref[0, :] = v_new.astype(vout_ref.dtype)
    dout_ref[0, :] = (delta - s * v_new).astype(dout_ref.dtype)


def _pad_to(x, m, axis=-1):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dp_reduce(u, delta, V, *, interpret: bool = True):
    """Fused pass A. u, delta: (d,); V: (lp, d). Returns
    (dots (lp,), uu, ud) accumulated in fp32."""
    lp, d = V.shape
    u2 = _pad_to(u[None, :], TILE_D)
    delta2 = _pad_to(delta[None, :], TILE_D)
    V2 = _pad_to(V, TILE_D)
    dp = u2.shape[1]
    n_tiles = dp // TILE_D
    out_w = ((lp + 2 + 127) // 128) * 128

    partials = pl.pallas_call(
        _reduce_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, TILE_D), lambda i: (0, i)),
            pl.BlockSpec((1, TILE_D), lambda i: (0, i)),
            pl.BlockSpec((lp, TILE_D), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, out_w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, out_w), jnp.float32),
        interpret=interpret,
    )(u2, delta2, V2)
    totals = jnp.sum(partials, axis=0)
    return totals[:lp], totals[lp], totals[lp + 1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dp_map(w, s, u, delta, V, *, interpret: bool = True):
    """Fused pass B. Returns (v_new (d,), delta_new (d,))."""
    lp, d = V.shape
    u2 = _pad_to(u[None, :], TILE_D)
    delta2 = _pad_to(delta[None, :], TILE_D)
    V2 = _pad_to(V, TILE_D)
    dp_ = u2.shape[1]
    n_tiles = dp_ // TILE_D
    w_w = ((lp + 127) // 128) * 128
    w2 = _pad_to(w[None, :].astype(jnp.float32), w_w)
    s2 = jnp.full((1, 1), s, jnp.float32)

    v_new, delta_new = pl.pallas_call(
        _map_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, w_w), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, TILE_D), lambda i: (0, i)),
            pl.BlockSpec((1, TILE_D), lambda i: (0, i)),
            pl.BlockSpec((lp, TILE_D), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_D), lambda i: (0, i)),
            pl.BlockSpec((1, TILE_D), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, dp_), u.dtype),
            jax.ShapeDtypeStruct((1, dp_), delta.dtype),
        ],
        interpret=interpret,
    )(w2, s2, u2, delta2, V2)
    return v_new[0, :d], delta_new[0, :d]
