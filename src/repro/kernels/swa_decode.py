"""Pallas TPU kernel: sliding-window GQA decode attention.

Serves the local layers of gemma3-27b / llama4-scout / recurrentgemma-9b at
``decode_32k`` / ``long_500k``: one query token per request attends to a
ring-buffer KV window. Decode attention is HBM-bandwidth-bound (the whole
window's K/V streams through once per token), so the kernel fuses
QK -> masked online softmax -> PV into a single pass over the window.

Grid: (B, KV, L / TILE_L) with the window dimension innermost — TPU grids
iterate sequentially, so fp32 running (max, sum, out) accumulators live in
VMEM scratch across window tiles (flash-attention decode scheme).
BlockSpecs keep one (TILE_L, dh) K/V tile and the (G, dh) query group in
VMEM; masking is driven by the ring buffer's per-slot token positions, so
the same kernel covers linear (full) and ring (windowed) caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_L = 512
NEG_INF = -2.0e38


def _swa_decode_kernel(pos_ref, window_ref,            # scalar prefetch
                       q_ref, k_ref, v_ref, slot_ref,  # blocks
                       out_ref,                        # output block
                       m_scr, s_scr, acc_scr):         # VMEM scratch
    li = pl.program_id(2)
    n_l = pl.num_programs(2)

    @pl.when(li == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                # (G, dh)
    k = k_ref[0].astype(jnp.float32)                   # (TILE_L, dh)
    v = v_ref[0].astype(jnp.float32)                   # (TILE_L, dh)
    sp = slot_ref[...]                                 # (TILE_L,) int32
    pos = pos_ref[0]
    window = window_ref[0]

    scores = jnp.dot(q, k.T) / jnp.sqrt(jnp.float32(q.shape[-1]))  # (G, T)
    valid = (sp >= 0) & (sp <= pos)
    valid = valid & ((window <= 0) | (sp > pos - window))
    scores = jnp.where(valid[None, :], scores, NEG_INF)

    m_prev = m_scr[...]                                # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                        # (G, T)
    s_scr[...] = s_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(li == n_l - 1)
    def _finalize():
        out_ref[0, 0] = (acc_scr[...] /
                         jnp.maximum(s_scr[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret", "tile_l"))
def swa_decode_attention(q, k, v, slot_pos, pos, *, window: int = 0,
                         interpret: bool = True, tile_l: int = TILE_L):
    """q: (B, KV, G, dh); k, v: (B, L, KV, dh); slot_pos: (L,) int32;
    pos: scalar int32 (position of the new token). Returns (B, KV, G, dh).

    ``window=0`` disables the lower position bound (full-cache decode).
    """
    B, KV, G, dh = q.shape
    L = k.shape[1]
    tile_l = min(tile_l, L)
    assert L % tile_l == 0, (L, tile_l)
    n_l = L // tile_l
    ktf = jnp.swapaxes(k, 1, 2).reshape(B * KV, L, dh)
    vtf = jnp.swapaxes(v, 1, 2).reshape(B * KV, L, dh)
    pos_s = jnp.asarray(pos, jnp.int32).reshape(1)
    win_s = jnp.asarray(window, jnp.int32).reshape(1)

    return pl.pallas_call(
        _swa_decode_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, n_l),
            in_specs=[
                pl.BlockSpec((1, 1, G, dh), lambda b, h, l, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, tile_l, dh),
                             lambda b, h, l, *_: (b * KV + h, l, 0)),
                pl.BlockSpec((1, tile_l, dh),
                             lambda b, h, l, *_: (b * KV + h, l, 0)),
                pl.BlockSpec((tile_l,), lambda b, h, l, *_: (l,)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, dh),
                                   lambda b, h, l, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        interpret=interpret,
    )(pos_s, win_s, q, ktf, vtf, slot_pos)
