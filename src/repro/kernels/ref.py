"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def dp_reduce_ref(u, delta, V):
    """dots_k = <v_k, u>, uu = <u,u>, ud = <u,delta>  (fp32)."""
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Vf = V.astype(jnp.float32)
    return Vf @ uf, jnp.dot(uf, uf), jnp.dot(uf, df)


def dp_map_ref(w, s, u, delta, V):
    """v = u - sum_k w_k v_k;  delta' = delta - s v."""
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Vf = V.astype(jnp.float32)
    v = uf - w.astype(jnp.float32) @ Vf
    return v.astype(u.dtype), (df - s * v).astype(delta.dtype)


def dp_step_ref(u, delta, V, c_hist, t, rho):
    """One full DP step (eqs. 22-23) in dense jnp — see ops.dp_step."""
    dots, uu, ud = dp_reduce_ref(u, delta, V)
    n_hist = c_hist.shape[0]
    mask = jnp.arange(n_hist) < (t - 1)
    w = jnp.where(mask, c_hist * dots, 0.0)
    tf = jnp.asarray(t, jnp.float32)
    g = (tf - 1.0) * rho / tf
    a = uu - jnp.sum(w * dots)          # <u, v> via conjugacy
    b = ud
    scale = (1.0 + g * (tf * b - a) / (1.0 + g * a)) / tf
    v, delta_new = dp_map_ref(w, scale, u, delta, V)
    c_new = g / (1.0 + g * a)
    return v, delta_new, a, c_new


def swa_decode_ref(q, k, v, slot_pos, pos, *, window: int = 0):
    """Masked softmax decode attention. Shapes as in ops.swa_decode."""
    B, KV, G, dh = q.shape
    qf = q.astype(jnp.float32)
    kf = jnp.swapaxes(k, 1, 2).astype(jnp.float32)     # (B, KV, L, dh)
    vf = jnp.swapaxes(v, 1, 2).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhld->bhgl", qf, kf) / jnp.sqrt(jnp.float32(dh))
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window > 0:
        valid = valid & (slot_pos > pos - window)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgl,bhld->bhgd", p, vf)
    return out.astype(q.dtype)
