"""jit'd public wrappers around the Pallas kernels.

``interpret=True`` everywhere by default: this container is CPU-only and
Pallas interpret mode executes the kernel bodies in Python for correctness
validation; on real TPU hardware callers pass ``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fedpa_dp as _dp
from repro.kernels import swa_decode as _swa


@functools.partial(jax.jit, static_argnames=("rho", "interpret"))
def dp_step(u, delta, V, c_hist, t, *, rho: float, interpret: bool = True):
    """One fused Sherman-Morrison DP step (paper eqs. 22-23).

    u: (d,) = x_t - xbar_{t-1}; delta: (d,) = Delta~_{t-1};
    V: (lp, d) history v_2..v_{t-1} (rows >= t-1 ignored);
    c_hist: (lp,) combine coefficients; t: traced scalar sample index (>= 2).

    Returns (v_t, Delta~_t, a_t, c_t). Two HBM passes total (reduce + map)
    instead of the ~2t+2 of the unfused jnp formulation.
    """
    dots, uu, ud = _dp.dp_reduce(u, delta, V, interpret=interpret)
    n_hist = c_hist.shape[0]
    mask = jnp.arange(n_hist) < (t - 1)
    w = jnp.where(mask, c_hist * dots, 0.0)
    tf = jnp.asarray(t, jnp.float32)
    g = (tf - 1.0) * rho / tf
    a = uu - jnp.sum(w * dots)          # <u, v> expanded through the combine
    scale = (1.0 + g * (tf * ud - a) / (1.0 + g * a)) / tf
    v, delta_new = _dp.dp_map(w, scale, u, delta, V, interpret=interpret)
    c_new = g / (1.0 + g * a)
    return v, delta_new, a, c_new


def dp_delta_flat(x0, samples, *, rho: float, interpret: bool = True):
    """Full Delta_hat_l from stacked (l, d) samples using the fused kernels —
    the kernel-path equivalent of ``repro.core.dp_delta.dp_delta`` on flat
    vectors. Python loop over the (static, single-digit) sample count."""
    ell, d = samples.shape
    xbar = samples[0]
    delta = x0 - samples[0]
    lp = max(ell - 1, 1)
    V = jnp.zeros((lp, d), jnp.float32)
    c_hist = jnp.zeros((lp,), jnp.float32)
    for t in range(2, ell + 1):
        u = samples[t - 1] - xbar
        v, delta, _, c_new = dp_step(u, delta, V, c_hist, t, rho=rho,
                                     interpret=interpret)
        V = V.at[t - 2].set(v)
        c_hist = c_hist.at[t - 2].set(c_new)
        xbar = xbar + u / t
    rho_l = 1.0 / (1.0 + (ell - 1.0) * rho)
    return delta / rho_l


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def swa_decode(q, cache_k, cache_v, slot_pos, pos, *, window: int = 0,
               interpret: bool = True):
    """Sliding-window decode attention over a ring-buffer cache.

    q: (B, H, dh) one token's query heads; cache_k/v: (B, L, KV, dh);
    slot_pos: (L,); pos: scalar. Returns (B, H, dh).
    """
    B, H, dh = q.shape
    KV = cache_k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dh)
    out = _swa.swa_decode_attention(qg, cache_k, cache_v, slot_pos, pos,
                                    window=window, interpret=interpret)
    return out.reshape(B, H, dh)
