"""Synthetic federated least-squares problems.

These are the exactly-solvable problems the paper uses for all of its
algorithmic analysis: Fig. 1 (2D two-client quadratics), Fig. 3
(bias/variance of client deltas, via Guyon-style ``make_regression``
problems), Fig. 4 (ESS of IASG samples), and Table 1 (client-update cost).
Pure numpy on the host; returns jnp arrays + exact-posterior views.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.posterior import QuadraticClient, client_from_data


def make_regression(
    n_samples: int,
    n_features: int,
    *,
    n_informative: int | None = None,
    noise: float = 1.0,
    seed: int = 0,
    coef_shift: np.ndarray | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Guyon (2003)-style linear regression generator (scikit-learn's
    ``make_regression`` reimplemented: offline container, no sklearn).

    Returns (X, y, w). ``coef_shift`` perturbs the ground-truth coefficients —
    that is how per-client heterogeneity is injected.
    """
    rng = np.random.default_rng(seed)
    n_informative = n_informative or n_features
    X = rng.standard_normal((n_samples, n_features))
    w = np.zeros(n_features)
    w[:n_informative] = 100.0 * rng.uniform(size=n_informative)
    if coef_shift is not None:
        w = w + coef_shift
    y = X @ w + noise * rng.standard_normal(n_samples)
    return X, y, w


def make_federated_lsq(
    num_clients: int,
    n_per_client: int,
    d: int,
    *,
    heterogeneity: float = 25.0,
    noise: float = 1.0,
    seed: int = 0,
    dtype=jnp.float32,
):
    """A federated least-squares problem with heterogeneous clients.

    Every client shares a base coefficient vector; each gets an independent
    Gaussian shift of scale ``heterogeneity`` (non-IID-ness knob). Returns
    (clients, data) where ``clients`` are exact-posterior QuadraticClient
    views and ``data`` the raw (X, y) pairs for SGD/IASG.
    """
    rng = np.random.default_rng(seed)
    base_shift = rng.standard_normal(d)
    clients: List[QuadraticClient] = []
    data = []
    sizes = np.full(num_clients, n_per_client)
    for i in range(num_clients):
        shift = base_shift + heterogeneity * rng.standard_normal(d)
        X, y, _ = make_regression(
            n_per_client, d, noise=noise, seed=seed * 7919 + i, coef_shift=shift
        )
        Xj = jnp.asarray(X, dtype)
        yj = jnp.asarray(y, dtype)
        q = sizes[i] / sizes.sum()
        clients.append(client_from_data(Xj, yj, weight=q))
        data.append((Xj, yj))
    return clients, data


def make_quadratic_clients(
    num_clients: int,
    d: int,
    *,
    cond: float = 10.0,
    spread: float = 3.0,
    seed: int = 0,
    dtype=jnp.float32,
) -> Sequence[QuadraticClient]:
    """Random quadratic objectives in natural form (Fig. 1's toy setting):
    random PSD precisions with condition number ~``cond`` and optima spread
    ``spread`` apart."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num_clients):
        Q, _ = np.linalg.qr(rng.standard_normal((d, d)))
        eigs = np.exp(rng.uniform(0, np.log(cond), size=d))
        prec = (Q * eigs) @ Q.T
        mu = spread * rng.standard_normal(d)
        out.append(
            QuadraticClient(
                sigma_inv=jnp.asarray(prec, dtype),
                mu=jnp.asarray(mu, dtype),
                weight=jnp.asarray(1.0 / num_clients, dtype),
            )
        )
    return out


def lsq_batches(X, y, batch_size: int, num_steps: int, seed: int = 0):
    """Sample ``num_steps`` minibatches with replacement -> stacked arrays
    with leading step axis (feeds ``iasg_sample``/``sgd_steps``)."""
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    idx = rng.integers(0, n, size=(num_steps, batch_size))
    return {"x": jnp.asarray(np.asarray(X)[idx]),
            "y": jnp.asarray(np.asarray(y)[idx])}
