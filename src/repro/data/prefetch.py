"""Host-side cohort prefetchers: thread- and process-based backends.

``FedSim.stack_cohort`` stacks per-client batch trees in Python each round
(~10ms at 16 clients on the EMNIST CNN config) — serialized with device
compute when done inline in the round loop. Two backends move that work off
the round loop, building cohorts up to ``depth`` rounds ahead
(``make_prefetcher`` picks one by ``FedConfig.prefetch_backend``):

* :class:`ProcessCohortPrefetcher` (``"process"``, the default) — a forked
  child process builds cohorts and hands the numpy leaves to the consumer
  through a ring of shared-memory arena slots, so decode-bound builders
  (numpy unpack/copy that holds the GIL) genuinely overlap the round
  loop's Python. ``get`` copies the leaves out of the arena (one memcpy;
  the decode work is what overlaps) and recycles the slot immediately.
  Restricted to numpy-leaf batch trees (a jax-computing ``build_fn`` must
  use the thread backend: the forked child must never touch the runtime;
  ``make_prefetcher`` probes and falls back with a warning).
* :class:`CohortPrefetcher` (``"thread"``) — the in-process fallback; any
  leaf types (including device arrays), but a builder that holds the GIL
  serializes with the round loop instead of overlapping it.

Both only *build* cohorts; ordering, staleness, and server updates stay
with the consumer (``FedSim`` / ``core.async_engine``).
"""
from __future__ import annotations

import multiprocessing as mp
import pickle
import queue
import threading
import time
import traceback
import warnings
from multiprocessing import resource_tracker, shared_memory
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


def local_row_range(mesh, axes, global_rows: int):
    """The ``[lo, hi)`` leading-axis rows this process's devices own when a
    ``(global_rows, ...)`` array is sharded over mesh ``axes``.

    The multi-host feeding contract: each process builds batches only for
    the cohort rows in its range and :func:`host_shard_to_global` assembles
    them. Requires the process's rows to be contiguous (true for the
    row-major meshes ``make_host_mesh``/``make_production_mesh`` build).
    """
    from jax.sharding import NamedSharding, PartitionSpec  # noqa: PLC0415

    sharding = NamedSharding(mesh, PartitionSpec(axes))
    imap = sharding.addressable_devices_indices_map((global_rows,))
    bounds = set()
    for idx in imap.values():
        lead = idx[0] if idx else slice(0, global_rows)
        lo = 0 if lead.start is None else lead.start
        hi = global_rows if lead.stop is None else lead.stop
        bounds.add((lo, hi))
    starts = sorted(b[0] for b in bounds)
    stops = sorted(b[1] for b in bounds)
    for s, prev_stop in zip(starts[1:], stops[:-1]):
        if s != prev_stop:
            raise RuntimeError(
                f"process-local rows {sorted(bounds)} are not contiguous — "
                f"per-host cohort feeding needs a row-major mesh")
    return starts[0], stops[-1]


def host_shard_to_global(local, mesh, axes, global_rows: int,
                         row_offset: int):
    """One host's contiguous ``(rows, ...)`` slice -> a global jax.Array.

    The returned array has shape ``(global_rows, *local.shape[1:])`` and is
    sharded over mesh ``axes`` along the leading axis; this process
    contributes only ``local`` (placed at ``row_offset``), the other rows
    live on the other hosts — nothing crosses the host boundary. Works
    unchanged in a single process (where the slice is the whole array).
    """
    from jax.sharding import NamedSharding, PartitionSpec  # noqa: PLC0415

    gshape = (global_rows,) + tuple(local.shape[1:])
    sharding = NamedSharding(
        mesh, PartitionSpec(axes, *(None,) * (len(gshape) - 1)))

    def cb(idx):
        lead = idx[0]
        lo = 0 if lead.start is None else lead.start
        hi = gshape[0] if lead.stop is None else lead.stop
        if lo < row_offset or hi > row_offset + local.shape[0]:
            raise RuntimeError(
                f"rows [{lo}, {hi}) requested from a host holding "
                f"[{row_offset}, {row_offset + local.shape[0]})")
        rows = local[lo - row_offset:hi - row_offset]
        return rows[(slice(None),) + tuple(idx[1:])]

    return jax.make_array_from_callback(gshape, sharding, cb)


def globalize_cohort_batches(batches, mesh, axes, global_rows: int,
                             row_offset: int):
    """Per-host stacked batches -> globally sharded batch arrays.

    ``batches`` is this host's ``stack_host`` output covering only its
    ``local_row_range`` rows; every leaf becomes a global array sharded
    over ``axes`` on the leading (client) axis.
    """
    return jax.tree_util.tree_map(
        lambda b: host_shard_to_global(np.asarray(b), mesh, axes,
                                       global_rows, row_offset),
        batches)


def replicate_global(tree, mesh):
    """Host-local (numpy / single-device) leaves -> replicated jax.Arrays.

    In a multi-process run every jit input must be a global array; plain
    numpy operands raise. This lifts the fully-replicated inputs (server
    state, client ids, survivor masks) onto ``mesh`` with every process
    supplying the same values — the per-host cohort feeding counterpart
    for the inputs that are *not* sharded. Jax arrays that already carry a
    committed global sharding pass through untouched.
    """
    from jax.sharding import NamedSharding, PartitionSpec  # noqa: PLC0415

    def lift(x):
        if isinstance(x, jax.Array) and not x.is_fully_addressable:
            return x
        arr = np.asarray(x)
        sharding = NamedSharding(mesh, PartitionSpec())
        return jax.make_array_from_callback(
            arr.shape, sharding, lambda idx: arr[idx])

    return jax.tree_util.tree_map(lift, tree)


def stack_host(trees):
    """Stack a list of identically-structured batch trees along a new
    leading (client) axis, keeping host arrays on the host.

    Numpy leaves are stacked with ``np.stack`` — no device ops enqueued, so
    a background prefetch thread assembling cohorts cannot contend with the
    round program for the accelerator dispatch stream, and the arrays
    transfer once, when the jitted round consumes them. Leaves that are
    already device arrays (a ``batch_fn`` that computes with jax) are
    stacked with ``jnp.stack`` instead: pulling them back to the host would
    add a blocking device-to-host copy per client per round.
    """
    def stack(*xs):
        if isinstance(xs[0], np.ndarray):
            return np.stack(xs)
        return jnp.stack(xs)

    return jax.tree_util.tree_map(stack, *trees)


class Cohort(NamedTuple):
    """One round's materialized inputs: ids are informational, ``batches``
    carries the (C, K, ...) stacked trees, ``weights`` is None for uniform.

    The trailing fields are the fault-injection annotations produced by
    ``data.cohort_source.CohortSource`` (all defaulted so fault-free
    construction is unchanged): ``survivors`` is the (C,) float 0/1
    mid-round-dropout mask the engines thread into the round programs
    (None = no mask faults this run), ``extra_staleness`` the straggler
    lateness in rounds the async engine adds to the discount exponent, and
    ``dropped`` the host-side count of masked-out cohort slots (for round
    history).
    """

    round_idx: int
    client_ids: object
    batches: object
    weights: Optional[object] = None
    survivors: Optional[object] = None
    extra_staleness: int = 0
    dropped: int = 0


#: build_fn(round_idx) -> Cohort
BuildFn = Callable[[int], Cohort]


class CohortPrefetcher:
    """Iterates ``build_fn(start_round) .. build_fn(stop_round - 1)`` on a
    daemon thread, keeping at most ``depth`` finished cohorts queued.

    ``get(round_idx)`` returns cohorts strictly in round order (the round
    loop's dispatch order); a builder exception is re-raised at the next
    ``get`` so failures surface in the consumer, not silently in a thread.
    """

    _DONE = object()

    def __init__(self, build_fn: BuildFn, start_round: int, stop_round: int,
                 depth: int = 2, close_timeout: float = 5.0):
        """Start the worker thread building rounds ``[start, stop)``."""
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._close_timeout = close_timeout
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._error: Optional[BaseException] = None
        self._stop = threading.Event()

        def put(item) -> bool:
            """Blocking put that gives up once close() is requested."""
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for r in range(start_round, stop_round):
                    if self._stop.is_set() or not put(build_fn(r)):
                        return
            except BaseException as e:  # noqa: BLE001 — re-raised in get()
                self._error = e
            put(self._DONE)

        self._thread = threading.Thread(target=worker, daemon=True,
                                        name="cohort-prefetch")
        self._thread.start()

    def get(self, round_idx: int) -> Cohort:
        """Blocking in-order fetch of round ``round_idx``'s cohort
        (re-raises a builder exception, refuses out-of-order reads)."""
        item = self._q.get()
        if item is self._DONE:
            if self._error is not None:
                err, self._error = self._error, None
                raise err
            raise RuntimeError(f"prefetcher exhausted before round {round_idx}")
        if item.round_idx != round_idx:
            raise RuntimeError(
                f"prefetcher out of order: expected round {round_idx}, "
                f"got {item.round_idx}")
        return item

    def _drain(self):
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                return

    def close(self):
        """Stop the worker and drop queued cohorts (idempotent).

        Drain and join are LOOPED until the thread exits: a single
        drain-then-join raced a worker mid-``put`` (the drain frees a slot,
        the put succeeds, the item sits re-enqueued after the drain), and
        ignoring the join timeout left a worker hung inside ``build_fn`` as
        a silent zombie. A worker that does not exit within
        ``close_timeout`` seconds now raises instead.
        """
        self._stop.set()
        deadline = time.monotonic() + self._close_timeout
        while self._thread.is_alive():
            self._drain()
            self._thread.join(timeout=0.05)
            if self._thread.is_alive() and time.monotonic() >= deadline:
                raise RuntimeError(
                    f"cohort-prefetch thread did not exit within "
                    f"{self._close_timeout}s of close() — build_fn is "
                    f"likely hung")
        self._drain()  # anything put between the last drain and exit

    def __enter__(self):
        """Context-manager entry: the prefetcher itself."""
        return self

    def __exit__(self, *exc):
        """Close on exit; a hung-worker error must not mask the with-body's
        own exception."""
        close_prefetcher(self, unwinding=exc[0] is not None)
        return False


# ---------------------------------------------------------------------------
# Process-based backend: forked builder + shared-memory arena ring
# ---------------------------------------------------------------------------

#: Arena slot offsets are aligned so consumer views keep numpy's preferred
#: alignment (and cache lines don't straddle leaves).
_ALIGN = 64


class _ArenaLeaf:
    """Placeholder for one numpy leaf shipped through the arena; the pickled
    cohort skeleton carries these where the arrays were. A plain class, NOT
    a NamedTuple: tree_map must treat it as an opaque leaf, and jax's
    pytree registry traverses NamedTuples as containers."""

    __slots__ = ("index",)

    def __init__(self, index: int):
        """Bind the position in the slot's ordered leaf list."""
        self.index = index

    def __getstate__(self):
        """Pickle as the bare slot index (sent over the worker pipe)."""
        return self.index

    def __setstate__(self, index):
        """Rebuild from the bare slot index."""
        self.index = index


def _align(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


def _untrack(shm: shared_memory.SharedMemory) -> None:
    """Detach a freshly *created* ``shm`` from the child's resource tracker.

    Creating a ``SharedMemory`` registers it with the creating process's
    resource tracker — and the forked child spawns its own tracker, which
    at child exit warns about (and tries to unlink) every arena segment as
    "leaked", racing the parent that still reads them (bpo-39959 family).
    Segment lifetime is owned explicitly instead: the parent's ``close()``
    (or ``__del__``) unlinks every segment it has seen. Attaching in the
    parent registers nothing, so only the create path calls this.
    """
    try:
        resource_tracker.unregister(shm._name, "shared_memory")  # noqa: SLF001
    except Exception:  # noqa: BLE001 — tracker layout is stdlib-internal
        pass


def _strip_cohort(cohort: Cohort):
    """Split a cohort into (pickled skeleton, ordered numpy leaves).

    Containers and small Python leaves (ints, None, strings) stay in the
    skeleton; every ``np.ndarray`` leaf is replaced by an :class:`_ArenaLeaf`
    token and shipped through shared memory. Device arrays are refused —
    the forked child must never touch the jax runtime.
    """
    leaves = []

    def strip(x):
        if isinstance(x, np.ndarray):
            leaves.append(np.ascontiguousarray(x))
            return _ArenaLeaf(len(leaves) - 1)
        if isinstance(x, jax.Array):
            raise TypeError(
                "the process-based cohort prefetcher requires numpy-leaf "
                "batch trees (the forked child must never touch the jax "
                "runtime); this build_fn produced a jax array — use "
                "prefetch_backend='thread'")
        return x

    skeleton = jax.tree_util.tree_map(strip, cohort)
    return pickle.dumps(skeleton), leaves


def _fill_cohort(skeleton_bytes: bytes, views):
    """Rebuild a cohort from its pickled skeleton + arena leaf views."""
    skeleton = pickle.loads(skeleton_bytes)
    return jax.tree_util.tree_map(
        lambda x: views[x.index] if isinstance(x, _ArenaLeaf) else x,
        skeleton)


def _arena_worker(build_fn: BuildFn, start: int, stop: int, free_r, meta_w,
                  base_name: str) -> None:
    """Child-process loop: build cohorts into shared-memory arena slots.

    Waits for a free slot index (``None`` = stop), builds the round's
    cohort, writes its numpy leaves into the slot's segment (re-created
    larger under a fresh name when a cohort outgrows it), and sends the
    slot's metadata. The channels are raw ``Pipe`` connections, not
    ``mp.Queue``s: a queue ships every ``put`` through a per-process
    feeder thread, and the parent-side feeder would contend for the
    parent's GIL — the very contention this backend exists to remove.
    Segments are only ever *unlinked* by the parent's ``close()`` — the
    child exiting must not invalidate names the parent has yet to attach.
    """
    slots = {}          # slot idx -> SharedMemory
    gen = 0
    try:
        for r in range(start, stop):
            slot = free_r.recv()
            if slot is None:
                return
            cohort = build_fn(r)
            skeleton, leaves = _strip_cohort(cohort)
            descs, total = [], 0
            for x in leaves:
                off = _align(total)
                # the dtype OBJECT, not dtype.str: extension dtypes like
                # ml_dtypes' bfloat16 stringify to a bare void ('<V2') that
                # cannot be reconstructed; the object pickles fine through
                # the meta queue
                descs.append((off, x.shape, x.dtype))
                total = off + x.nbytes
            shm = slots.get(slot)
            if shm is None or shm.size < total:
                if shm is not None:
                    shm.close()
                gen += 1
                shm = shared_memory.SharedMemory(
                    name=f"{base_name}-{slot}-{gen}", create=True,
                    size=max(total, 1))
                _untrack(shm)
                slots[slot] = shm
            for x, (off, shape, dtype) in zip(leaves, descs):
                dst = np.ndarray(shape, dtype, buffer=shm.buf, offset=off)
                dst[...] = x
            meta_w.send(("item", r, (slot, shm.name, skeleton, descs)))
        meta_w.send(("done", None, None))
    except BaseException:  # noqa: BLE001 — re-raised in the parent's get()
        meta_w.send(("error", None, traceback.format_exc()))
    finally:
        for shm in slots.values():
            shm.close()


class ProcessCohortPrefetcher:
    """Builds rounds ``[start, stop)`` in a forked child process, handing
    cohorts to the consumer through a ring of ``depth`` shared-memory
    arena slots.

    Same consumer contract as :class:`CohortPrefetcher` — strictly in-order
    ``get(round_idx)``, builder exceptions re-raised at the next ``get``,
    and the returned cohort owns its leaves (copied out of the arena — see
    :meth:`get` for why views would be unsafe under jax's CPU-backend
    zero-copy aliasing).

    The child is forked, so ``build_fn`` closures need no pickling — but
    the child must stay off the jax runtime (forked XLA locks can
    deadlock): build_fns must produce numpy-leaf trees, enforced loudly in
    the child. Use the thread backend for jax-computing builders.
    """

    def __init__(self, build_fn: BuildFn, start_round: int, stop_round: int,
                 depth: int = 2, close_timeout: float = 5.0):
        """Fork the arena worker building rounds ``[start, stop)``."""
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._close_timeout = close_timeout
        ctx = mp.get_context("fork")
        # raw pipes, not mp.Queues: queues route every put through a feeder
        # thread, and the parent's feeder would contend for the parent GIL
        # (the contention this backend removes); the parent keeps all four
        # connection ends open for the prefetcher's lifetime so sends never
        # see a broken pipe and recvs never EOF mid-protocol
        self._free_r, self._free_w = ctx.Pipe(duplex=False)
        self._meta_r, self._meta_w = ctx.Pipe(duplex=False)
        self._attached = {}        # shm name -> SharedMemory (parent side)
        self._closed = False
        base = f"coharena-{mp.current_process().pid}-{id(self):x}"
        for slot in range(depth):
            self._free_w.send(slot)
        self._proc = ctx.Process(
            target=_arena_worker,
            args=(build_fn, start_round, stop_round, self._free_r,
                  self._meta_w, base),
            daemon=True, name="cohort-arena")
        with warnings.catch_warnings():
            # jax registers an at-fork hook warning that a forked child of a
            # multithreaded process may deadlock; this child stays strictly
            # on numpy (enforced in _strip_cohort), so the condition the
            # warning guards against cannot occur
            warnings.filterwarnings(
                "ignore", message=r"os\.fork\(\) was called",
                category=RuntimeWarning)
            self._proc.start()

    def get(self, round_idx: int) -> Cohort:
        """Blocking in-order fetch; leaves are COPIED out of the arena.

        The copy is what makes the returned cohort unconditionally safe:
        jax's CPU backend may alias a numpy buffer zero-copy at dispatch,
        and the async engine fetches the next cohort before the previous
        round's compute has consumed its inputs — handing out live views
        of a slot that is about to be recycled corrupted in-flight rounds
        (the overwriting cohort's survivor mask bled into the dispatched
        one). The slot is recycled to the child immediately after the
        copy, so the ring pipelines at full depth.
        """
        while True:
            if self._meta_r.poll(0.2):
                kind, r, payload = self._meta_r.recv()
                break
            if not self._proc.is_alive():
                if self._meta_r.poll(0):   # reported, then exited: drain it
                    continue
                raise RuntimeError(
                    "cohort-arena process died without reporting an "
                    "error (killed?)")
        if kind == "error":
            raise RuntimeError(
                f"cohort-arena build_fn failed:\n{payload}")
        if kind == "done":
            raise RuntimeError(f"prefetcher exhausted before round "
                               f"{round_idx}")
        if r != round_idx:
            raise RuntimeError(
                f"prefetcher out of order: expected round {round_idx}, "
                f"got {r}")
        slot, name, skeleton, descs = payload
        shm = self._attached.get(name)
        if shm is None:
            shm = shared_memory.SharedMemory(name=name)
            self._attached[name] = shm
        leaves = [np.ndarray(shape, dtype, buffer=shm.buf,
                             offset=off).copy()
                  for off, shape, dtype in descs]
        self._free_w.send(slot)
        return _fill_cohort(skeleton, leaves)

    def close(self):
        """Stop the child, detach, and unlink every arena segment
        (idempotent; raises if the child outlives ``close_timeout``)."""
        if self._closed:
            return
        self._closed = True
        try:
            self._free_w.send(None)          # poison: wake a waiting child
        except (OSError, ValueError):
            pass
        self._proc.join(timeout=self._close_timeout)
        hung = self._proc.is_alive()
        if hung:
            self._proc.terminate()
            self._proc.join(timeout=1.0)
        # collect segment names still in flight, then destroy everything
        while True:
            try:
                if not self._meta_r.poll(0):
                    break
                kind, _, payload = self._meta_r.recv()
            except (EOFError, OSError):
                break
            if kind == "item":
                slot, name, *_ = payload
                if name not in self._attached:
                    try:
                        shm = shared_memory.SharedMemory(name=name)
                        self._attached[name] = shm
                    except FileNotFoundError:
                        pass
        for shm in self._attached.values():
            shm.close()
            try:
                shm.unlink()
            except FileNotFoundError:
                pass
        self._attached = {}
        for conn in (self._free_r, self._free_w, self._meta_r, self._meta_w):
            conn.close()
        if hung:
            raise RuntimeError(
                f"cohort-arena process did not exit within "
                f"{self._close_timeout}s of close() — build_fn is likely "
                f"hung (terminated)")

    def __del__(self):
        """Best-effort cleanup for consumers that crashed before
        ``close()`` (the resource tracker covers anything left)."""
        try:
            self.close()
        except Exception:  # noqa: BLE001 — never raise from a finalizer
            pass

    def __enter__(self):
        """Context-manager entry: the prefetcher itself."""
        return self

    def __exit__(self, *exc):
        """Close on exit without masking the with-body's own exception."""
        close_prefetcher(self, unwinding=exc[0] is not None)
        return False


#: Prefetcher backends by ``FedConfig.prefetch_backend`` value.
PREFETCHERS = {"thread": CohortPrefetcher, "process": ProcessCohortPrefetcher}


def make_prefetcher(backend: str, build_fn: BuildFn, start_round: int,
                    stop_round: int, depth: int = 2,
                    close_timeout: float = 5.0):
    """Instantiate the prefetcher for a ``prefetch_backend`` value.

    The process backend is probed before forking: one cohort is built in
    the parent, and if any leaf is a device array the call falls back to
    the thread backend with a warning instead of failing on the first
    ``get`` (the forked child must never touch the jax runtime, so it
    cannot ship device arrays through the arena). The probe cohort is
    discarded — ``build_fn`` is deterministic per round, so the chosen
    backend rebuilds it identically.
    """
    try:
        cls = PREFETCHERS[backend]
    except KeyError:
        raise ValueError(
            f"unknown prefetch_backend {backend!r}; "
            f"known: {tuple(PREFETCHERS)}") from None
    if cls is ProcessCohortPrefetcher and stop_round > start_round:
        probe = build_fn(start_round)
        if any(isinstance(leaf, jax.Array)
               for leaf in jax.tree_util.tree_leaves(probe)):
            warnings.warn(
                "prefetch_backend='process' needs numpy-leaf batch trees, "
                "but this build_fn produces jax arrays — falling back to "
                "the thread backend (set prefetch_backend='thread' to "
                "silence, or return numpy leaves from batch_fn to use the "
                "shared-memory arena)", RuntimeWarning, stacklevel=2)
            cls = CohortPrefetcher
    return cls(build_fn, start_round, stop_round, depth=depth,
               close_timeout=close_timeout)


def close_prefetcher(prefetcher: "CohortPrefetcher", unwinding: bool) -> None:
    """Close a prefetcher from a consumer's ``finally`` block.

    ``unwinding=True`` means the consumer's round loop is already
    propagating its own exception: the hung-worker ``RuntimeError`` that
    :meth:`CohortPrefetcher.close` may raise is then demoted to a warning
    so it cannot mask the real error. On a clean exit it stays loud.
    (The caller must pass an explicit flag — inside a ``finally`` there is
    no reliable way to distinguish the two cases after ``close()`` has
    itself raised.)
    """
    try:
        prefetcher.close()
    except RuntimeError:
        if not unwinding:
            raise
        warnings.warn(
            "cohort prefetcher did not shut down cleanly while handling a "
            "round-loop error", RuntimeWarning)
