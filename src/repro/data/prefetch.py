"""Host-side cohort prefetcher.

``FedSim.stack_cohort`` stacks per-client batch trees in Python each round
(~10ms at 16 clients on the EMNIST CNN config) — serialized with device
compute when done inline in the round loop. ``CohortPrefetcher`` moves that
work to a background thread that samples client ids and stacks/pads cohort
batch trees up to ``depth`` rounds ahead, so round t's host-side input
pipeline overlaps round t-1's device compute. The thread only *builds*
cohorts; ordering, staleness, and server updates stay with the consumer
(``FedSim`` / ``core.async_engine``).
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


def stack_host(trees):
    """Stack a list of identically-structured batch trees along a new
    leading (client) axis, keeping host arrays on the host.

    Numpy leaves are stacked with ``np.stack`` — no device ops enqueued, so
    a background prefetch thread assembling cohorts cannot contend with the
    round program for the accelerator dispatch stream, and the arrays
    transfer once, when the jitted round consumes them. Leaves that are
    already device arrays (a ``batch_fn`` that computes with jax) are
    stacked with ``jnp.stack`` instead: pulling them back to the host would
    add a blocking device-to-host copy per client per round.
    """
    def stack(*xs):
        if isinstance(xs[0], np.ndarray):
            return np.stack(xs)
        return jnp.stack(xs)

    return jax.tree_util.tree_map(stack, *trees)


class Cohort(NamedTuple):
    """One round's materialized inputs: ids are informational, ``batches``
    carries the (C, K, ...) stacked trees, ``weights`` is None for uniform."""

    round_idx: int
    client_ids: object
    batches: object
    weights: Optional[object] = None


#: build_fn(round_idx) -> Cohort
BuildFn = Callable[[int], Cohort]


class CohortPrefetcher:
    """Iterates ``build_fn(start_round) .. build_fn(stop_round - 1)`` on a
    daemon thread, keeping at most ``depth`` finished cohorts queued.

    ``get(round_idx)`` returns cohorts strictly in round order (the round
    loop's dispatch order); a builder exception is re-raised at the next
    ``get`` so failures surface in the consumer, not silently in a thread.
    """

    _DONE = object()

    def __init__(self, build_fn: BuildFn, start_round: int, stop_round: int,
                 depth: int = 2, close_timeout: float = 5.0):
        """Start the worker thread building rounds ``[start, stop)``."""
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._close_timeout = close_timeout
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._error: Optional[BaseException] = None
        self._stop = threading.Event()

        def put(item) -> bool:
            """Blocking put that gives up once close() is requested."""
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for r in range(start_round, stop_round):
                    if self._stop.is_set() or not put(build_fn(r)):
                        return
            except BaseException as e:  # noqa: BLE001 — re-raised in get()
                self._error = e
            put(self._DONE)

        self._thread = threading.Thread(target=worker, daemon=True,
                                        name="cohort-prefetch")
        self._thread.start()

    def get(self, round_idx: int) -> Cohort:
        """Blocking in-order fetch of round ``round_idx``'s cohort
        (re-raises a builder exception, refuses out-of-order reads)."""
        item = self._q.get()
        if item is self._DONE:
            if self._error is not None:
                err, self._error = self._error, None
                raise err
            raise RuntimeError(f"prefetcher exhausted before round {round_idx}")
        if item.round_idx != round_idx:
            raise RuntimeError(
                f"prefetcher out of order: expected round {round_idx}, "
                f"got {item.round_idx}")
        return item

    def _drain(self):
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                return

    def close(self):
        """Stop the worker and drop queued cohorts (idempotent).

        Drain and join are LOOPED until the thread exits: a single
        drain-then-join raced a worker mid-``put`` (the drain frees a slot,
        the put succeeds, the item sits re-enqueued after the drain), and
        ignoring the join timeout left a worker hung inside ``build_fn`` as
        a silent zombie. A worker that does not exit within
        ``close_timeout`` seconds now raises instead.
        """
        self._stop.set()
        deadline = time.monotonic() + self._close_timeout
        while self._thread.is_alive():
            self._drain()
            self._thread.join(timeout=0.05)
            if self._thread.is_alive() and time.monotonic() >= deadline:
                raise RuntimeError(
                    f"cohort-prefetch thread did not exit within "
                    f"{self._close_timeout}s of close() — build_fn is "
                    f"likely hung")
        self._drain()  # anything put between the last drain and exit

    def __enter__(self):
        """Context-manager entry: the prefetcher itself."""
        return self

    def __exit__(self, *exc):
        """Close on exit; a hung-worker error must not mask the with-body's
        own exception."""
        close_prefetcher(self, unwinding=exc[0] is not None)
        return False


def close_prefetcher(prefetcher: "CohortPrefetcher", unwinding: bool) -> None:
    """Close a prefetcher from a consumer's ``finally`` block.

    ``unwinding=True`` means the consumer's round loop is already
    propagating its own exception: the hung-worker ``RuntimeError`` that
    :meth:`CohortPrefetcher.close` may raise is then demoted to a warning
    so it cannot mask the real error. On a clean exit it stays loud.
    (The caller must pass an explicit flag — inside a ``finally`` there is
    no reliable way to distinguish the two cases after ``close()`` has
    itself raised.)
    """
    try:
        prefetcher.close()
    except RuntimeError:
        if not unwinding:
            raise
        warnings.warn(
            "cohort prefetcher did not shut down cleanly while handling a "
            "round-loop error", RuntimeWarning)
