"""Synthetic-token federated LM data pipeline.

Feeds the decoder-LM architectures. Each client owns a deterministic token
stream generated from a client-specific 2-gram process over a Zipf
marginal — heterogeneity comes from per-client transition matrices (like
StackOverflow's per-user language), determinism from hashing
(seed, client_id, step). Pure numpy on the host (the real system's data
loader), batched into the (steps, batch, seq+1) layout the client scan
consumes. For VLM/audio archs the pipeline also emits stub frontend
embeddings (the one allowed carve-out — see DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


def _client_rng(seed: int, client_id: int, salt: int = 0) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(client_id, salt))
    )


@dataclass
class SyntheticLMData:
    """Federated synthetic LM corpus: ``num_clients`` stateless clients."""

    vocab_size: int
    num_clients: int
    seed: int = 0
    zipf_a: float = 1.2
    # number of "hot" tokens whose transition structure is client-specific
    hot_tokens: int = 512

    def _marginal(self) -> np.ndarray:
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        return p / p.sum()

    def client_tokens(self, client_id: int, n_tokens: int,
                      salt: int = 0) -> np.ndarray:
        """Deterministic token stream for one client."""
        rng = _client_rng(self.seed, client_id, salt)
        p = self._marginal()
        base = rng.choice(self.vocab_size, size=n_tokens, p=p)
        # client-specific bigram habit: each hot token deterministically
        # prefers a client-specific successor half of the time
        succ = rng.integers(0, self.vocab_size, size=self.hot_tokens)
        hot = base[:-1] < self.hot_tokens
        flip = rng.random(n_tokens - 1) < 0.5
        nxt = base[1:].copy()
        idx = hot & flip
        nxt[idx] = succ[base[:-1][idx]]
        return np.concatenate([base[:1], nxt]).astype(np.int32)

    def client_batches(self, client_id: int, num_steps: int, batch: int,
                       seq_len: int, salt: int = 0, host: bool = False):
        """(num_steps, batch, seq_len+1) token ids: input = [:, :, :-1],
        target = [:, :, 1:]. ``host=True`` returns the numpy array the
        stream is generated as (required by the process-based cohort
        prefetcher, whose forked builder must stay off the jax runtime)."""
        need = num_steps * batch * (seq_len + 1)
        toks = self.client_tokens(client_id, need, salt)
        arr = toks.reshape(num_steps, batch, seq_len + 1)
        return arr if host else jnp.asarray(arr)

    def round_batches(self, client_ids, num_steps: int, batch: int,
                      seq_len: int, round_idx: int = 0, host: bool = False):
        """Stacked per-client batches for one federated round:
        (num_clients, num_steps, batch, seq_len+1); ``host=True`` keeps the
        stack in numpy (process-prefetcher-safe)."""
        per = [
            self.client_batches(cid, num_steps, batch, seq_len,
                                salt=round_idx, host=host)
            for cid in client_ids
        ]
        return np.stack(per) if host else jnp.stack(per)

    def frontend_embeddings(self, client_id: int, batch: int, tokens: int,
                            d_model: int, salt: int = 0, host: bool = False):
        """Stub modality-frontend output: deterministic pseudo-embeddings of
        the right shape (B, tokens, d_model) standing in for ViT patches /
        EnCodec conditioning frames. ``host=True`` stays in numpy float32
        (process-prefetcher-safe; the consumer casts on device)."""
        rng = _client_rng(self.seed, client_id, salt + 10_000)
        e = rng.standard_normal((batch, tokens, d_model)).astype(np.float32)
        scaled = (e / np.sqrt(d_model)).astype(np.float32)
        return scaled if host else jnp.asarray(scaled)
