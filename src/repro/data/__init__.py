"""Data pipelines: synthetic federated problems, sampling, prefetching."""
from repro.data.cohort_source import CohortSource, RoundFaults  # noqa: F401
from repro.data.dirichlet import make_dirichlet_classification  # noqa: F401
from repro.data.lm_synthetic import SyntheticLMData  # noqa: F401
from repro.data.prefetch import (  # noqa: F401
    Cohort,
    CohortPrefetcher,
    ProcessCohortPrefetcher,
    make_prefetcher,
    stack_host,
)
from repro.data.sampling import ClientSampler  # noqa: F401
from repro.data.synthetic_lsq import (  # noqa: F401
    make_federated_lsq,
    make_quadratic_clients,
    make_regression,
)
