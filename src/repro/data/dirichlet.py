"""Dirichlet non-IID federated classification (the Table-3 stand-in).

The paper's benchmark suite (EMNIST/CIFAR/StackOverflow) is network-gated in
this container, so the Table-3-style comparison runs on a synthetic task with
the same statistical structure Reddi et al. (2020) used to build federated
CIFAR-100: per-client label distributions drawn from a Dirichlet(alpha)
prior (alpha small => highly heterogeneous clients). Features are noisy
class prototypes, so a linear/MLP model has a well-defined global optimum
while client optima differ — exactly the regime where FedAvg stagnates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class FederatedClassification(NamedTuple):
    """One federated classification problem: per-client shards + test set."""

    client_x: list          # list of (n_i, d) float arrays
    client_y: list          # list of (n_i,) int arrays
    weights: np.ndarray     # q_i proportional to n_i
    test_x: jnp.ndarray
    test_y: jnp.ndarray
    num_classes: int
    d: int


def make_dirichlet_classification(
    num_clients: int,
    num_classes: int,
    d: int,
    *,
    n_per_client: int = 100,
    alpha: float = 0.1,
    proto_scale: float = 3.0,
    noise: float = 1.0,
    n_test: int = 1000,
    seed: int = 0,
) -> FederatedClassification:
    """Build the synthetic non-IID problem: per-client label distributions
    ~ Dirichlet(alpha), features = noisy class prototypes, test set drawn
    from the global (uniform) label distribution."""
    rng = np.random.default_rng(seed)
    protos = proto_scale * rng.standard_normal((num_classes, d))

    def sample(n, label_p):
        ys = rng.choice(num_classes, size=n, p=label_p)
        xs = protos[ys] + noise * rng.standard_normal((n, d))
        return xs.astype(np.float32), ys.astype(np.int32)

    client_x, client_y = [], []
    for _ in range(num_clients):
        p = rng.dirichlet(alpha * np.ones(num_classes))
        xs, ys = sample(n_per_client, p)
        client_x.append(xs)
        client_y.append(ys)
    # test set is drawn from the *global* (uniform) label distribution
    tx, ty = sample(n_test, np.ones(num_classes) / num_classes)
    weights = np.full(num_clients, 1.0 / num_clients)
    return FederatedClassification(
        client_x, client_y, weights, jnp.asarray(tx), jnp.asarray(ty),
        num_classes, d,
    )


def classification_batches(xs, ys, batch_size: int, num_steps: int, seed: int = 0):
    """One client-round's ``{"x", "y"}`` batches with a leading step axis
    (sampled with replacement from the client's shard)."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, xs.shape[0], size=(num_steps, batch_size))
    return {"x": jnp.asarray(xs[idx]), "y": jnp.asarray(ys[idx])}
