"""Fault-injecting cohort source: the cross-device regime made unreliable.

The paper's cross-device setting assumes the average client participates in
roughly one round — but ``ClientSampler`` still draws from an always-on,
always-finishing population, so none of the unreliable-participation
conditions that motivated federated optimization in the first place
(Konecny et al., arXiv:1610.02527) are ever exercised.
:class:`CohortSource` is the streaming layer that injects them, strictly on
the host side of the engine boundary:

* **Diurnal availability** (``fed.availability="diurnal"``) — each client
  is up for an ``availability_duty`` fraction of an
  ``availability_period``-round cycle, with a per-client phase; cohorts
  draw only from the currently-available set. If fewer than
  ``clients_per_round`` clients are up, the cohort is topped up from the
  unavailable set to keep the jitted round's shapes static, and the
  conscripted clients are masked out as non-survivors (they were scheduled
  but never report).
* **Mid-round dropout** (``fed.dropout_rate``) — each sampled client drops
  with probability ``dropout_rate``; the cohort ships with a (C,) float
  0/1 ``survivors`` mask that the round programs thread through the
  weighted aggregation (survivors renormalize; an all-dropped round
  degrades to a zero delta) and the client-state stores honour as a write
  mask (a dropped client's half-finished state never lands).
* **Straggler timeouts** (``fed.straggler_rate``, async engine only) — a
  whole cohort misses its round deadline with probability
  ``straggler_rate`` and picks up ``extra_staleness`` in
  ``[1, straggler_max_lateness]`` rounds of lateness; the async engine
  adds it to the staleness exponent, so the late delta is discounted by
  the existing ``staleness_discount ** s`` path.
* **Heterogeneous local-step budgets** (``fed.min_local_steps``) — each
  sampled client runs a budget drawn uniformly from
  ``[min_local_steps, local_steps]``; the remaining scheduled steps are
  frozen by the engine's gradient masking (see ``make_cohort_program``),
  keyed off the ``"_active"`` (C, K) leaf this source injects into dict
  batch trees.

Every draw is a pure function of ``(seed, round_idx)``:
:meth:`CohortSource.draw` replays a round's cohort ids and fault
annotations bit-identically without materializing batches, which is what
makes fault histories reproducible. With every fault knob at its default,
:meth:`cohort` reproduces today's ``ClientSampler`` cohorts bitwise (same
underlying rng stream) and ships ``survivors=None``, so the engines trace
the exact mask-free round programs of a fault-free config.

Deterministic stream layout under one run seed (``np.random.SeedSequence``
spawn keys; keys of different lengths can never collide):

* ``(round,)`` — the cohort draw (``ClientSampler``'s own stream,
  delegated so the zero-fault path is bit-identical);
* ``(round, k)`` — per-round fault streams (dropout / straggler /
  budgets);
* ``(0, 0, k)`` — run-static streams (the per-client diurnal phases).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import numpy as np

from repro.configs.base import FedConfig
from repro.data.prefetch import Cohort
from repro.data.sampling import ClientSampler

#: Per-round fault streams: spawn key ``(round_idx, k)``.
_STREAM_DROPOUT = 1
_STREAM_STRAGGLER = 2
_STREAM_BUDGETS = 3
#: Run-static streams: spawn key ``(0, 0, k)``.
_STATIC_PHASES = 1


def _rng(seed: int, *key: int) -> np.random.Generator:
    """The deterministic generator for one ``(seed, *key)`` stream."""
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=key))


class RoundFaults(NamedTuple):
    """One round's fault draw — a pure function of ``(seed, round_idx)``.

    ``survivors`` is the (C,) float 0/1 mid-round mask (None when this run
    has no mask faults, so the engines trace mask-free programs);
    ``budgets`` the per-client local-step budgets (None = homogeneous);
    ``extra_staleness`` the cohort's straggler lateness in rounds (0 = on
    time); ``dropped`` the count of masked-out cohort slots.
    """

    survivors: Optional[np.ndarray]
    budgets: Optional[np.ndarray]
    extra_staleness: int
    dropped: int


class CohortSource:
    """Streaming cohort source with deterministic fault injection.

    ``stack_batches(client_ids, round_idx)`` materializes the cohort's
    stacked (C, K, ...) batch tree (``FedSim.stack_cohort`` or the launch
    scripts' equivalents); everything else — sampling, availability,
    dropout, stragglers, budgets, per-client weights — lives here, so the
    engines consume finished :class:`~repro.data.prefetch.Cohort` records
    and ``CohortPrefetcher`` / the process-based prefetcher can build them
    off the round loop.
    """

    def __init__(self, fed: FedConfig, num_clients: int,
                 stack_batches: Callable[[np.ndarray, int], object],
                 client_weights: Optional[np.ndarray] = None, seed: int = 0):
        """Bind the config, population, batch builder, and run seed."""
        self.fed = fed
        self.num_clients = num_clients
        self.stack_batches = stack_batches
        self.client_weights = client_weights
        self.seed = seed
        # the zero-fault cohort draw IS ClientSampler's (same stream), so
        # zero-rate configs reproduce its cohorts bitwise
        self.sampler = ClientSampler(num_clients, fed.clients_per_round,
                                     seed)
        self._phases = (_rng(seed, 0, 0, _STATIC_PHASES).random(num_clients)
                        if fed.availability == "diurnal" else None)
        #: Whether cohorts carry a survivors mask at all — fixed per run so
        #: every round traces the same jitted program (a per-round
        #: None/array flip would recompile).
        self.mask_faults = (fed.availability != "always"
                            or fed.dropout_rate > 0)

    def available(self, round_idx: int) -> np.ndarray:
        """(N,) bool availability mask for round ``round_idx``.

        Diurnal model: client ``i`` is up iff the fractional position of
        ``round_idx / availability_period + phase_i`` within its cycle is
        below ``availability_duty``. ``availability="always"`` is all-ones.
        """
        if self._phases is None:
            return np.ones(self.num_clients, bool)
        fed = self.fed
        pos = (round_idx / fed.availability_period + self._phases) % 1.0
        return pos < fed.availability_duty

    def sample(self, round_idx: int) -> np.ndarray:
        """Round ``round_idx``'s cohort ids (``ClientSampler`` API parity)."""
        return self.draw(round_idx)[0]

    def draw(self, round_idx: int):
        """``(client_ids, RoundFaults)`` — the full replayable round draw.

        No batches are materialized, so tests and history tooling can
        replay a run's fault matrix from ``(seed, round)`` alone.
        """
        fed = self.fed
        M = fed.clients_per_round
        if self._phases is None:
            ids = self.sampler.sample(round_idx)
            conscripted = np.zeros(M, bool)
        else:
            avail = self.available(round_idx)
            up = np.flatnonzero(avail)
            rng = _rng(self.seed, round_idx)
            if up.shape[0] >= M:
                ids = up[rng.choice(up.shape[0], size=M, replace=False)]
                conscripted = np.zeros(M, bool)
            else:
                # not enough clients up: conscript the shortfall from the
                # unavailable set (masked out below) so the round program's
                # cohort shape stays static
                down = np.flatnonzero(~avail)
                extra = down[rng.choice(down.shape[0],
                                        size=M - up.shape[0],
                                        replace=False)]
                ids = np.concatenate([up, extra])
                conscripted = np.concatenate(
                    [np.zeros(up.shape[0], bool),
                     np.ones(extra.shape[0], bool)])

        dead = conscripted
        if fed.dropout_rate > 0:
            drops = (_rng(self.seed, round_idx, _STREAM_DROPOUT).random(M)
                     < fed.dropout_rate)
            dead = dead | drops
        survivors = (1.0 - dead).astype(np.float32) if self.mask_faults \
            else None

        extra_staleness = 0
        if fed.straggler_rate > 0:
            srng = _rng(self.seed, round_idx, _STREAM_STRAGGLER)
            if srng.random() < fed.straggler_rate:
                extra_staleness = int(
                    srng.integers(1, fed.straggler_max_lateness + 1))

        budgets = None
        if fed.min_local_steps:
            budgets = _rng(self.seed, round_idx, _STREAM_BUDGETS).integers(
                fed.min_local_steps, fed.local_steps + 1, size=M)

        return ids, RoundFaults(survivors, budgets, extra_staleness,
                                int(dead.sum()))

    def cohort(self, round_idx: int) -> Cohort:
        """Materialize round ``round_idx``: the prefetchers' build_fn.

        Stacks the cohort's batches, injects the ``"_active"`` (C, K)
        budget mask into dict batch trees when budgets are on, resolves
        per-client weights (eagerly checked — the raw, pre-mask weights
        must be positive; the survivor masking happens traced, inside the
        round program, where an all-zero sum degrades to zero weights),
        and attaches the round's fault annotations.
        """
        ids, faults = self.draw(round_idx)
        batches = self.stack_batches(ids, round_idx)
        if faults.budgets is not None:
            if not isinstance(batches, dict):
                raise TypeError(
                    f"min_local_steps > 0 needs dict batch trees to carry "
                    f"the '_active' per-step budget mask; got "
                    f"{type(batches).__name__}")
            K = self.fed.local_steps
            active = np.arange(K)[None, :] < faults.budgets[:, None]
            batches = dict(batches)
            batches["_active"] = active.astype(np.float32)
        if self.client_weights is None:
            weights = None
        else:
            # late import: data -> core.server -> core/__init__ -> round ->
            # data.cohort_source would cycle at module load
            from repro.core.server import check_weight_total  # noqa: PLC0415
            weights = np.asarray([self.client_weights[int(c)] for c in ids],
                                 np.float32)
            check_weight_total(float(weights.sum()), weights.shape,
                               context=f"round {round_idx}: ")
        return Cohort(round_idx, ids, batches, weights, faults.survivors,
                      faults.extra_staleness, faults.dropped)
