"""Client sampling for the cross-device setting: each round draws M of N
clients uniformly without replacement, deterministically per (seed, round) —
the stateless-clients regime the paper targets (the average client
participates in ~a single round)."""
from __future__ import annotations

import numpy as np


class ClientSampler:
    def __init__(self, num_clients: int, clients_per_round: int, seed: int = 0):
        if clients_per_round > num_clients:
            raise ValueError("clients_per_round > num_clients")
        self.num_clients = num_clients
        self.clients_per_round = clients_per_round
        self.seed = seed

    def sample(self, round_idx: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(round_idx,))
        )
        return rng.choice(self.num_clients, size=self.clients_per_round,
                          replace=False)

    def participation_counts(self, num_rounds: int) -> np.ndarray:
        counts = np.zeros(self.num_clients, dtype=np.int64)
        for r in range(num_rounds):
            counts[self.sample(r)] += 1
        return counts
