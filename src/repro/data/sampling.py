"""Client sampling for the cross-device setting: each round draws M of N
clients uniformly without replacement, deterministically per (seed, round) —
the stateless-clients regime the paper targets (the average client
participates in ~a single round)."""
from __future__ import annotations

import numpy as np


class ClientSampler:
    """Deterministic per-round cohort sampler (M of N, no replacement)."""

    def __init__(self, num_clients: int, clients_per_round: int, seed: int = 0):
        """Bind the population size, cohort size, and run seed.

        Bounds are validated eagerly and by name: a non-positive population
        or cohort size used to surface rounds later as an opaque numpy
        ``choice`` error.
        """
        if num_clients <= 0:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        if clients_per_round <= 0:
            raise ValueError(
                f"clients_per_round must be >= 1, got {clients_per_round}")
        if clients_per_round > num_clients:
            raise ValueError(
                f"clients_per_round ({clients_per_round}) > num_clients "
                f"({num_clients})")
        self.num_clients = num_clients
        self.clients_per_round = clients_per_round
        self.seed = seed

    def sample(self, round_idx: int) -> np.ndarray:
        """Round ``round_idx``'s cohort ids — a pure function of
        ``(seed, round_idx)``, so re-running a round resamples identically
        (the sampler never draws a client twice within one round)."""
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(round_idx,))
        )
        return rng.choice(self.num_clients, size=self.clients_per_round,
                          replace=False)

    def participation_counts(self, num_rounds: int) -> np.ndarray:
        """How many of the first ``num_rounds`` rounds each client joins.

        The per-round draws are unavoidable (each is its own rng stream),
        but the tally is one vectorized ``bincount`` over the stacked
        cohorts instead of ``num_rounds`` fancy-indexed increments.
        """
        if num_rounds <= 0:
            return np.zeros(self.num_clients, dtype=np.int64)
        cohorts = np.stack([self.sample(r) for r in range(num_rounds)])
        return np.bincount(cohorts.ravel(),
                           minlength=self.num_clients).astype(np.int64)
