"""The generic pattern decoder: one model builder for all ten assigned
architectures.

A config describes a *pattern* of layers (mixer + ffn) repeated R times plus
an optional tail. Parameters for each pattern position are stacked over
repeats, and the forward pass is a single ``lax.scan`` over repeats — so the
lowered HLO (and XLA compile time, which matters for the 512-device CPU
dry-run) is independent of depth. Mixers: full/sliding-window GQA attention,
mLSTM, sLSTM, RG-LRU. FFNs: SwiGLU, MoE, none.

Decode state mirrors the parameter layout: per-pattern-position caches
stacked over repeats, scanned in lockstep with the params.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import rms_norm, swiglu
from repro.sharding import constrain

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_mixer(rng, cfg: ModelConfig, spec: LayerSpec, dtype):
    if spec.mixer in ("attn", "swa"):
        return attn.init_attn_params(rng, cfg, dtype)
    if spec.mixer == "mlstm":
        return xlstm_mod.init_mlstm_params(rng, cfg, dtype)
    if spec.mixer == "slstm":
        return xlstm_mod.init_slstm_params(rng, cfg, dtype)
    if spec.mixer == "rglru":
        return rglru_mod.init_rglru_params(rng, cfg, dtype)
    raise ValueError(spec.mixer)


def _init_ffn(rng, cfg: ModelConfig, spec: LayerSpec, dtype):
    if spec.ffn == "none":
        return {}
    if spec.ffn == "dense":
        d, ff = cfg.d_model, cfg.d_ff
        ks = jax.random.split(rng, 3)
        s = lambda fan: 1.0 / jnp.sqrt(fan)
        return {
            "norm": jnp.zeros((d,), dtype),
            "w_gate": jax.random.normal(ks[0], (d, ff), dtype) * s(d),
            "w_up": jax.random.normal(ks[1], (d, ff), dtype) * s(d),
            "w_down": jax.random.normal(ks[2], (ff, d), dtype) * s(ff),
        }
    if spec.ffn == "moe":
        return {"norm": jnp.zeros((cfg.d_model,), dtype),
                "moe": moe_mod.init_moe_params(rng, cfg, dtype)}
    raise ValueError(spec.ffn)


def _init_layer(rng, cfg: ModelConfig, spec: LayerSpec, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "norm": jnp.zeros((cfg.d_model,), dtype),
        "mixer": _init_mixer(k1, cfg, spec, dtype),
        "ffn": _init_ffn(k2, cfg, spec, dtype),
    }


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    """Init the full decoder: embed + stacked pattern/tail layers + norms."""
    k_embed, k_pat, k_tail, k_un = jax.random.split(rng, 4)
    V, d = cfg.padded_vocab, cfg.d_model
    params: Dict[str, Any] = {
        "embed": jax.random.normal(k_embed, (V, d), dtype) / jnp.sqrt(d),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(k_un, (d, V), dtype) / jnp.sqrt(d)

    pattern = {}
    for i, spec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(k_pat, i), cfg.repeats)
        pattern[f"pos_{i}"] = jax.vmap(
            lambda k: _init_layer(k, cfg, spec, dtype)
        )(keys)
    params["pattern"] = pattern

    tail = {}
    for i, spec in enumerate(cfg.tail):
        tail[f"layer_{i}"] = _init_layer(
            jax.random.fold_in(k_tail, i), cfg, spec, dtype
        )
    if tail:
        params["tail"] = tail
    return params


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStruct pytree of the params — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, dtype)
    )


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count via abstract shapes (no allocation)."""
    leaves = jax.tree_util.tree_leaves(abstract_params(cfg))
    return sum(x.size for x in leaves)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_layer(p, x, cfg: ModelConfig, spec: LayerSpec, q_chunk: int,
                 return_cache: bool):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    if spec.mixer in ("attn", "swa"):
        y, cache = attn.attn_forward(p["mixer"], h, cfg, spec,
                                     q_chunk=q_chunk,
                                     return_cache=return_cache)
    elif spec.mixer == "mlstm":
        y, cache = xlstm_mod.mlstm_forward(p["mixer"], h, cfg,
                                           return_cache=return_cache)
    elif spec.mixer == "slstm":
        y, cache = xlstm_mod.slstm_forward(p["mixer"], h, cfg,
                                           return_cache=return_cache)
    elif spec.mixer == "rglru":
        y, cache = rglru_mod.rglru_forward(p["mixer"], h, cfg,
                                           return_cache=return_cache)
    else:
        raise ValueError(spec.mixer)
    if cfg.tp_out_constraint:
        y = constrain(y, "batch", None, None)
    x = x + y.astype(x.dtype)
    x = constrain(x, "batch", None, None)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "dense":
        f = p["ffn"]
        u = rms_norm(x, f["norm"], cfg.norm_eps)
        y = swiglu(u, f["w_gate"], f["w_up"], f["w_down"])
        if cfg.tp_out_constraint:
            y = constrain(y, "batch", None, None)
        x = x + y
    elif spec.ffn == "moe":
        f = p["ffn"]
        u = rms_norm(x, f["norm"], cfg.norm_eps)
        y, aux = moe_mod.moe_ffn(f["moe"], u, cfg)
        if cfg.tp_out_constraint:
            y = constrain(y, "batch", None, None)
        x = x + y
    x = constrain(x, "batch", None, None)
    return x, aux, cache


def _embed_inputs(params, cfg: ModelConfig, tokens, frontend,
                  compute_dtype):
    emb = params["embed"].astype(compute_dtype)
    x = emb[tokens]                                   # (B, S_text, d)
    if cfg.frontend:
        if frontend is None:
            raise ValueError(f"{cfg.name} requires frontend embeddings")
        x = jnp.concatenate([frontend.astype(compute_dtype), x], axis=1)
    return constrain(x, "batch", None, None)


def forward(params, tokens, cfg: ModelConfig, *, frontend=None,
            compute_dtype=jnp.bfloat16, q_chunk: int = 1024,
            remat: str = "full", logits_slice: Optional[int] = None):
    """tokens: (B, S_text) int32 -> (logits (B, S_out, V), aux-loss scalar).

    ``logits_slice``: if given, only the logits of the last N positions are
    computed (prefill wants just the final position's logits).
    """
    cparams = jax.tree_util.tree_map(
        lambda t: t.astype(compute_dtype)
        if jnp.issubdtype(t.dtype, jnp.floating) else t,
        params,
    )
    x = _embed_inputs(cparams, cfg, tokens, frontend, compute_dtype)

    def unit(x, unit_params):
        """Apply one repeat of the whole pattern."""
        aux_total = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.pattern):
            x, aux, _ = _apply_layer(unit_params[f"pos_{i}"], x, cfg, spec,
                                     q_chunk, False)
            aux_total = aux_total + aux
        return x, aux_total

    if remat == "full":
        unit = jax.checkpoint(unit,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        unit = jax.checkpoint(
            unit,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )

    x, auxs = jax.lax.scan(unit, x, cparams["pattern"])
    aux = jnp.sum(auxs)
    for i, spec in enumerate(cfg.tail):
        x, a, _ = _apply_layer(cparams["tail"][f"layer_{i}"], x, cfg, spec,
                               q_chunk, False)
        aux = aux + a
    x = rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    logits = _unembed(cparams, x)
    return logits, aux


def _unembed(cparams, x):
    if "unembed" in cparams:
        logits = x @ cparams["unembed"]
    else:
        logits = x @ cparams["embed"].T
    return constrain(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """All per-layer decode caches plus the current token position."""

    pattern: Dict[str, Any]   # per pattern position: cache stacked over repeats
    tail: Dict[str, Any]
    pos: jnp.ndarray          # scalar int32: number of tokens already consumed


def _init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_len: int, dtype):
    if spec.mixer in ("attn", "swa"):
        return attn.init_attn_cache(cfg, spec, batch, max_len, dtype)
    if spec.mixer == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if spec.mixer == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch)
    if spec.mixer == "rglru":
        return rglru_mod.init_rglru_state(cfg, batch)
    raise ValueError(spec.mixer)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      cache_dtype=jnp.bfloat16) -> DecodeState:
    """Allocate empty decode caches for every layer (stacked over repeats)."""
    pattern = {}
    for i, spec in enumerate(cfg.pattern):
        one = _init_layer_cache(cfg, spec, batch, max_len, cache_dtype)
        pattern[f"pos_{i}"] = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (cfg.repeats,) + t.shape).copy(), one
        )
    tail = {
        f"layer_{i}": _init_layer_cache(cfg, spec, batch, max_len, cache_dtype)
        for i, spec in enumerate(cfg.tail)
    }
    return DecodeState(pattern, tail, jnp.zeros((), jnp.int32))


def abstract_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                          cache_dtype=jnp.bfloat16):
    """Shape/dtype tree of init_decode_state without allocating."""
    return jax.eval_shape(
        functools.partial(init_decode_state, cfg, batch, max_len, cache_dtype)
    )


def _decode_layer(p, x, cache, cfg: ModelConfig, spec: LayerSpec, pos,
                  use_pallas: bool = False):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    if spec.mixer in ("attn", "swa"):
        y, cache = attn.attn_decode(p["mixer"], h, cache, cfg, spec, pos,
                                    use_pallas=use_pallas)
    elif spec.mixer == "mlstm":
        y, cache = xlstm_mod.mlstm_decode(p["mixer"], h, cache, cfg)
    elif spec.mixer == "slstm":
        y, cache = xlstm_mod.slstm_decode(p["mixer"], h, cache, cfg)
    elif spec.mixer == "rglru":
        y, cache = rglru_mod.rglru_decode(p["mixer"], h, cache, cfg)
    else:
        raise ValueError(spec.mixer)
    x = x + y.astype(x.dtype)
    if spec.ffn == "dense":
        f = p["ffn"]
        u = rms_norm(x, f["norm"], cfg.norm_eps)
        x = x + swiglu(u, f["w_gate"], f["w_up"], f["w_down"])
    elif spec.ffn == "moe":
        f = p["ffn"]
        u = rms_norm(x, f["norm"], cfg.norm_eps)
        y, _ = moe_mod.moe_ffn(f["moe"], u, cfg)
        x = x + y
    return x, cache


def decode_step(params, token, state: DecodeState, cfg: ModelConfig, *,
                compute_dtype=jnp.bfloat16, use_pallas: bool = False):
    """One token for the whole batch. token: (B,) int32. Returns
    (logits (B, V), new_state)."""
    cparams = jax.tree_util.tree_map(
        lambda t: t.astype(compute_dtype)
        if jnp.issubdtype(t.dtype, jnp.floating) else t,
        params,
    )
    x = cparams["embed"][token][:, None, :]          # (B, 1, d)
    pos = state.pos

    def unit(x, xs):
        unit_params, unit_cache = xs
        new_caches = {}
        for i, spec in enumerate(cfg.pattern):
            x, c = _decode_layer(unit_params[f"pos_{i}"], x,
                                 unit_cache[f"pos_{i}"], cfg, spec, pos,
                                 use_pallas=use_pallas)
            new_caches[f"pos_{i}"] = c
        return x, new_caches

    x, new_pattern = jax.lax.scan(unit, x, (cparams["pattern"], state.pattern))
    new_tail = {}
    for i, spec in enumerate(cfg.tail):
        x, c = _decode_layer(cparams["tail"][f"layer_{i}"], x,
                             state.tail[f"layer_{i}"], cfg, spec, pos,
                             use_pallas=use_pallas)
        new_tail[f"layer_{i}"] = c
    x = rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    logits = _unembed(cparams, x)[:, 0]
    return logits, DecodeState(new_pattern, new_tail, pos + 1)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params, tokens, cfg: ModelConfig, max_len: int, *, frontend=None,
            compute_dtype=jnp.bfloat16, q_chunk: int = 1024,
            cache_dtype=jnp.bfloat16):
    """Run the full prompt, build the decode state, return last-token logits.

    Note: implemented as forward-with-cache per layer (no scan-over-repeats
    here would force cache restacking; instead we reuse the scan and rebuild
    attention caches from the returned raw k/v)."""
    cparams = jax.tree_util.tree_map(
        lambda t: t.astype(compute_dtype)
        if jnp.issubdtype(t.dtype, jnp.floating) else t,
        params,
    )
    x = _embed_inputs(cparams, cfg, tokens, frontend, compute_dtype)
    B, S = x.shape[:2]

    def unit(x, xs):
        unit_params = xs
        caches = {}
        for i, spec in enumerate(cfg.pattern):
            x, _, cache = _apply_layer(unit_params[f"pos_{i}"], x, cfg, spec,
                                       q_chunk, True)
            if spec.mixer in ("attn", "swa"):
                cache = attn.cache_from_prefill(cfg, spec, cache, max_len,
                                                cache_dtype)
            caches[f"pos_{i}"] = cache
        return x, caches

    x, pattern_caches = jax.lax.scan(unit, x, cparams["pattern"])
    tail_caches = {}
    for i, spec in enumerate(cfg.tail):
        x, _, cache = _apply_layer(cparams["tail"][f"layer_{i}"], x, cfg,
                                   spec, q_chunk, True)
        if spec.mixer in ("attn", "swa"):
            cache = attn.cache_from_prefill(cfg, spec, cache, max_len,
                                            cache_dtype)
        tail_caches[f"layer_{i}"] = cache
    x = rms_norm(x, cparams["final_norm"], cfg.norm_eps)
    logits = _unembed(cparams, x[:, -1:])[:, 0]
    state = DecodeState(pattern_caches, tail_caches,
                        jnp.asarray(S, jnp.int32))
    return logits, state
