"""The paper's own EMNIST-62 model: 2x(conv 3x3 + maxpool) + 128-dense
(TFF reference architecture, Reddi et al. 2020). Used by the Table-3-style
simulated benchmark; dropout omitted (deterministic evaluation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.emnist_cnn import CNNConfig


def init_cnn_params(rng, cfg: CNNConfig, dtype=jnp.float32):
    """Init the two conv layers and two dense layers of the EMNIST CNN."""
    ks = jax.random.split(rng, 4)
    c0, c1 = cfg.conv_channels
    k = cfg.kernel_size
    s = lambda fan: 1.0 / jnp.sqrt(fan)
    # spatial size after two 'SAME' conv + 2x2 maxpool stages
    side = cfg.image_size // 4
    flat = side * side * c1
    return {
        "conv0": jax.random.normal(ks[0], (k, k, cfg.in_channels, c0), dtype) * s(k * k * cfg.in_channels),
        "b0": jnp.zeros((c0,), dtype),
        "conv1": jax.random.normal(ks[1], (k, k, c0, c1), dtype) * s(k * k * c0),
        "b1": jnp.zeros((c1,), dtype),
        "dense": jax.random.normal(ks[2], (flat, cfg.hidden), dtype) * s(flat),
        "bd": jnp.zeros((cfg.hidden,), dtype),
        "out": jax.random.normal(ks[3], (cfg.hidden, cfg.num_classes), dtype) * s(cfg.hidden),
        "bo": jnp.zeros((cfg.num_classes,), dtype),
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(params, x, cfg: CNNConfig):
    """x: (B, H, W, C) -> logits (B, num_classes)."""
    h = jax.nn.relu(_conv(x, params["conv0"], params["b0"]))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv(h, params["conv1"], params["b1"]))
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense"] + params["bd"])
    return h @ params["out"] + params["bo"]


def cnn_loss(params, batch, cfg: CNNConfig):
    """Mean softmax cross-entropy over a {"x", "y"} batch."""
    logits = cnn_forward(params, batch["x"], cfg)
    labels = batch["y"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def cnn_accuracy(params, x, y, cfg: CNNConfig):
    """Top-1 accuracy of the CNN on (x, y)."""
    return jnp.mean(jnp.argmax(cnn_forward(params, x, cfg), axis=-1) == y)
