"""RG-LRU recurrent block (Griffin / RecurrentGemma). [arXiv:2402.19427]

The Real-Gated Linear Recurrent Unit is a *diagonal linear* recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * r_t),
    r_t = sigmoid(blockdiag(W_a) x_t + b_a),  i_t = sigmoid(blockdiag(W_x) x_t + b_x)

TPU adaptation: linearity + diagonality means the whole sequence reduces
with ``lax.associative_scan`` (log-depth parallel prefix) instead of a
sequential loop — this is the Griffin paper's own TPU implementation
strategy and what makes RG-LRU training seq-parallel. Decode is the single
recurrence step with streaming conv state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import causal_conv1d

_C = 8.0          # Griffin's fixed decay sharpness
_NB = 8           # gate projection block-diagonal blocks


class RGLRUState(NamedTuple):
    """RG-LRU decode state: recurrent vector + streaming-conv tail."""

    h: jnp.ndarray      # (B, e) recurrent state
    conv: jnp.ndarray   # (B, cw-1, e) streaming conv state


def _e(cfg: ModelConfig) -> int:
    return int(cfg.expansion * (cfg.lru_d or cfg.d_model))


def init_rglru_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    """Init the Griffin RG-LRU block (gates, block-diag recurrences, conv)."""
    d, e = cfg.d_model, _e(cfg)
    eb = e // _NB
    ks = jax.random.split(rng, 6)
    s = lambda fan: 1.0 / jnp.sqrt(fan)
    return {
        "w_gate": jax.random.normal(ks[0], (d, e), dtype) * s(d),
        "w_x": jax.random.normal(ks[1], (d, e), dtype) * s(d),
        "conv": jax.random.normal(ks[2], (cfg.conv_width, e), dtype) * s(cfg.conv_width),
        "rg_a": jax.random.normal(ks[3], (_NB, eb, eb), dtype) * s(eb),
        "b_a": jnp.zeros((e,), dtype),
        "rg_x": jax.random.normal(ks[4], (_NB, eb, eb), dtype) * s(eb),
        "b_x": jnp.zeros((e,), dtype),
        # Lambda init so a^c in ~(0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.linspace(0.5, 4.0, e).astype(dtype),
        "w_down": jax.random.normal(ks[5], (e, d), dtype) * s(e),
    }


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    """Zero-initialise the RG-LRU decode state."""
    e = _e(cfg)
    return RGLRUState(
        h=jnp.zeros((batch, e), dtype),
        conv=jnp.zeros((batch, cfg.conv_width - 1, e), dtype),
    )


def _blockdiag(x, w):
    """x: (..., e) @ block-diagonal w: (nb, e/nb, e/nb) -> (..., e)."""
    nb, eb, _ = w.shape
    xs = x.reshape(x.shape[:-1] + (nb, eb))
    ys = jnp.einsum("...ne,nef->...nf", xs, w)
    return ys.reshape(x.shape)


def _rglru_gates(p, xc):
    """Per-step decay a_t (log-space) and gated input. xc: (..., e) fp32."""
    r = jax.nn.sigmoid(_blockdiag(xc, p["rg_a"].astype(xc.dtype)) + p["b_a"])
    i = jax.nn.sigmoid(_blockdiag(xc, p["rg_x"].astype(xc.dtype)) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"].astype(xc.dtype)) * r
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return jnp.exp(log_a), multiplier * i * xc


def rglru_forward(p, x, cfg: ModelConfig, return_cache: bool = False):
    """Full-sequence Griffin recurrent block. x: (B, S, d) -> (B, S, d)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    xi = x @ p["w_x"]
    xc, conv_state = causal_conv1d(xi, p["conv"])
    a, b = _rglru_gates(p, xc.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    y = (h * gate) @ p["w_down"]
    state = RGLRUState(h[:, -1].astype(jnp.float32), conv_state) \
        if return_cache else None
    return y, state


def rglru_decode(p, x, state: RGLRUState, cfg: ModelConfig):
    """One-token step. x: (B, 1, d)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    xi = x @ p["w_x"]
    xc, conv_state = causal_conv1d(xi, p["conv"], state.conv)
    a, b = _rglru_gates(p, xc[:, 0].astype(jnp.float32))
    h = a * state.h.astype(jnp.float32) + b
    y = (h[:, None, :].astype(x.dtype) * gate) @ p["w_down"]
    return y, RGLRUState(h, conv_state)
