"""Mixture-of-Experts FFN: GShard-style one-hot dispatch, chunked over tokens.

TPU adaptation (DESIGN.md §3): routing is expressed as dense one-hot
dispatch/combine einsums (the Mesh-TensorFlow/GShard formulation) because
that is the form GSPMD shards automatically — with tokens sharded over the
``data`` axis and experts over the ``model`` axis, the dispatch einsum
lowers to the expert-parallel all-to-all. Tokens are processed in chunks via
``lax.scan`` so the (chunk, E, C) dispatch tensor stays bounded regardless
of batch x seq. Capacity overflow drops tokens (residual passes them
through), and the router returns the switch-transformer load-balancing aux
loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.sharding import constrain


def init_moe_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    """Init router + per-expert FFN stacks (and shared expert if any)."""
    m = cfg.moe
    d, eff = cfg.d_model, m.expert_d_ff
    ks = jax.random.split(rng, 7)
    s = lambda fan: 1.0 / jnp.sqrt(fan)
    p = {
        "router": jax.random.normal(ks[0], (d, m.num_experts), dtype) * s(d),
        "w_gate": jax.random.normal(ks[1], (m.num_experts, d, eff), dtype) * s(d),
        "w_up": jax.random.normal(ks[2], (m.num_experts, d, eff), dtype) * s(d),
        "w_down": jax.random.normal(ks[3], (m.num_experts, eff, d), dtype) * s(eff),
    }
    if m.shared_expert_d_ff:
        sf = m.shared_expert_d_ff
        p["ws_gate"] = jax.random.normal(ks[4], (d, sf), dtype) * s(d)
        p["ws_up"] = jax.random.normal(ks[5], (d, sf), dtype) * s(d)
        p["ws_down"] = jax.random.normal(ks[6], (sf, d), dtype) * s(sf)
    return p


def capacity(m: MoEConfig, chunk_tokens: int) -> int:
    """Per-expert token capacity for a chunk, rounded up to a multiple of 4."""
    c = int(chunk_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, ((c + 3) // 4) * 4)


def _route_chunk(xc, p, m: MoEConfig):
    """One chunk of tokens. xc: (T, d). Returns (y: (T, d), aux scalar)."""
    T, d = xc.shape
    E, K = m.num_experts, m.top_k
    C = capacity(m, T)

    logits = (xc @ p["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)       # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )                                                     # renormalize top-k

    # GShard position assignment: slot 0 has priority, then slot 1, ...
    combine = jnp.zeros((T, E, C), jnp.float32)
    counts = jnp.zeros((E,), jnp.float32)
    for s in range(K):
        onehot_e = jax.nn.one_hot(expert_idx[:, s], E)    # (T, E)
        pos = jnp.cumsum(onehot_e, axis=0) - 1.0 + counts # (T, E)
        keep = (pos < C) & (onehot_e > 0)
        counts = counts + jnp.sum(onehot_e, axis=0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C) # (T, E, C)
        combine = combine + (
            gate_vals[:, s, None, None]
            * keep[..., None].astype(jnp.float32)
            * pos_oh
        )

    dispatch = (combine > 0).astype(xc.dtype)             # (T, E, C)
    expert_in = jnp.einsum("tec,td->ecd", dispatch, xc)   # (E, C, d)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    h = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])    # (E, C, d)
    y = jnp.einsum("tec,ecd->td", combine.astype(xc.dtype), h)

    # switch-transformer load-balance loss (first-choice fractions)
    first = jax.nn.one_hot(expert_idx[:, 0], E)
    frac_tokens = jnp.mean(first, axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux


def _route_chunk_sort(xc, p, m: MoEConfig):
    """Sort-based routing (§Perf): replaces the dense one-hot dispatch and
    combine einsums — 2*T*E*C*d MXU flops and a (T,E,C) tensor each — with an
    argsort + gather into expert slots and a scatter-add back. The expert
    matmuls are unchanged; routing becomes pure data movement.

    Drop semantics differ slightly from GShard under overflow (tokens are
    dropped per expert in token order across all k-slots rather than
    slot-major); with ample capacity the two are exactly equivalent
    (tests/test_moe_routing.py).
    """
    T, d = xc.shape
    E, K = m.num_experts, m.top_k
    C = capacity(m, T)

    logits = (xc @ p["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)       # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    flat_e = expert_idx.reshape(-1)                       # (T*K,)
    flat_g = gate_vals.reshape(-1)
    flat_t = jnp.arange(T * K) // K                       # source token ids
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = flat_t[order]
    sg = flat_g[order]
    # position within each expert's run of the sorted assignment list
    first = jnp.searchsorted(se, jnp.arange(E), side="left")   # (E,)
    pos = jnp.arange(T * K) - first[se]
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)           # E*C = drop bucket

    gathered = xc[st] * keep[:, None].astype(xc.dtype)    # (T*K, d)
    gathered = constrain(gathered, "batch", None)
    buf = jnp.zeros((E * C, d), xc.dtype).at[slot].add(gathered, mode="drop")
    # pin the expert buffer to expert-parallel layout so the scatter lowers
    # to token->expert redistribution instead of replicate+all-reduce (§Perf)
    expert_in = constrain(buf.reshape(E, C, d), "experts", None, None)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    h = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])
    h = constrain(h, "experts", None, None).reshape(E * C, d)

    contrib = h[jnp.minimum(slot, E * C - 1)] * (
        sg * keep.astype(jnp.float32))[:, None].astype(xc.dtype)
    y = jnp.zeros((T, d), xc.dtype).at[st].add(contrib)
    y = constrain(y, "batch", None)

    first_choice = jax.nn.one_hot(expert_idx[:, 0], E)
    aux = E * jnp.sum(jnp.mean(first_choice, axis=0) * jnp.mean(probs, axis=0))
    return y, aux


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y: (B, S, d), aux-loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    chunk = min(m.chunk_tokens, T)
    if T % chunk:  # pad to a whole number of chunks (dropped on output)
        pad = chunk - T % chunk
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), xf.dtype)])
    nch = xf.shape[0] // chunk
    xch = xf.reshape(nch, chunk, d)

    route = _route_chunk_sort if m.routing == "sort" else _route_chunk

    def body(_, xc):
        y, aux = route(xc, p, m)
        return None, (y, aux)

    _, (ych, aux) = jax.lax.scan(body, None, xch)
    y = ych.reshape(-1, d)[:T].reshape(B, S, d)
    if m.shared_expert_d_ff:
        g = jax.nn.silu(x @ p["ws_gate"])
        y = y + (g * (x @ p["ws_up"])) @ p["ws_down"]
    return y, jnp.mean(aux)
