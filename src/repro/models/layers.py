"""Common layers: RMSNorm, RoPE, SwiGLU FFN, causal conv."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with (1 + scale) weighting (gemma convention; scale init 0)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def group_norm_heads(x, scale, eps: float = 1e-6):
    """Per-head RMS normalization for (..., H, dh) tensors (xLSTM blocks)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    out = out * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embeddings. x: (..., S, H, dh), positions: (S,) or (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    # broadcast over heads: (..., S, 1, half)
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN: silu(x Wg) * (x Wu) Wd."""
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def causal_conv1d(x, kernel, state=None):
    """Depthwise causal conv along the sequence axis.

    x: (B, S, C), kernel: (W, C). With ``state`` (B, W-1, C) provided,
    performs the streaming update (decode): returns (y, new_state) where
    x has S=1. Without state, left-pads with zeros (train/prefill) and
    returns (y, final_state).
    """
    w = kernel.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (w - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * kernel[i] for i in range(w))
    new_state = xp[:, -(w - 1):, :] if w > 1 else jnp.zeros_like(pad)
    return y.astype(x.dtype), new_state


def softmax_cross_entropy(logits, targets, valid_vocab: int | None = None,
                          mask=None):
    """Mean token-level cross entropy. logits fp32 (B, S, V); targets int
    (B, S). ``valid_vocab`` masks out padded vocab rows."""
    logits = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        pad = jnp.arange(logits.shape[-1]) >= valid_vocab
        logits = jnp.where(pad, -1e30, logits)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
