"""GQA attention: q-chunked full/sliding-window forward + cached decode.

TPU adaptation notes (DESIGN.md §3): the forward pass is chunked over query
blocks with a ``lax.scan`` so the score matrix never materializes beyond
(B, KV, G, q_chunk, S_k) — the flash-attention memory shape without a custom
kernel (XLA fuses the masked-softmax chain well on TPU). Sliding-window
layers slice a (W + q_chunk) key window per chunk, making local layers
O(S * W) instead of O(S^2) — this is what makes gemma3/llama4/recurrentgemma
long-context shapes lowerable. Decode keeps a ring-buffer cache for windowed
layers and a linear cache for full layers, with per-slot positions so one
mask rule covers both.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.layers import rms_norm, rope

NEG_INF = -2.0e38


class AttnCache(NamedTuple):
    """Per-layer KV decode cache plus the token position held by each slot."""

    k: jnp.ndarray          # (B, L, KV, dh)
    v: jnp.ndarray          # (B, L, KV, dh)
    slot_pos: jnp.ndarray   # (L,) int32 token position held by each slot (-1 empty)


def init_attn_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    """Init q/k/v/o projections (plus qk-norm scales when enabled)."""
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(rng, 4)
    s = lambda fan_in: 1.0 / jnp.sqrt(fan_in)
    p = {
        "wq": jax.random.normal(ks[0], (d, qd), dtype) * s(d),
        "wk": jax.random.normal(ks[1], (d, kvd), dtype) * s(d),
        "wv": jax.random.normal(ks[2], (d, kvd), dtype) * s(d),
        "wo": jax.random.normal(ks[3], (qd, d), dtype) * s(qd),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_scale"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, KV, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, KV, dh)
    v = (x @ p["wv"]).reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_scale"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scores_softmax_v(q, k, v, qpos, kpos, window: int, dh: int):
    """q: (B,Sq,H,dh) grouped against k/v: (B,Sk,KV,dh). Returns (B,Sq,H*dh)."""
    B, Sq, H, _ = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] >= 0)
    if window:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H * dh)


def attn_forward(p, x, cfg: ModelConfig, spec: LayerSpec, pos0: int = 0,
                 q_chunk: int = 1024, return_cache: bool = False):
    """Full-sequence attention (train / prefill). Returns (y, kv) where kv is
    the raw (k, v) if ``return_cache`` else None."""
    B, S, d = x.shape
    window = spec.window if spec.mixer == "swa" else 0
    positions = pos0 + jnp.arange(S)
    q, k, v = _project_qkv(p, x, cfg, positions)

    q_chunk = min(q_chunk, S)
    # ragged tails: pad queries up to a whole number of chunks; the padded
    # rows attend causally to nothing new and are sliced off below
    S_pad = ((S + q_chunk - 1) // q_chunk) * q_chunk
    if S_pad != S:
        q = jnp.pad(q, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    n_chunks = S_pad // q_chunk

    if window and window < S:
        # local layer: each q chunk only sees a (window + chunk) key slice
        W = window
        pad = lambda t: jnp.concatenate(
            [jnp.zeros(t.shape[:1] + (W,) + t.shape[2:], t.dtype), t,
             jnp.zeros(t.shape[:1] + (S_pad - S,) + t.shape[2:], t.dtype)],
            axis=1,
        )
        kp, vp = pad(k), pad(v)

        def chunk_fn(_, i):
            qs = i * q_chunk
            qc = jax.lax.dynamic_slice_in_dim(q, qs, q_chunk, axis=1)
            kc = jax.lax.dynamic_slice_in_dim(kp, qs, W + q_chunk, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vp, qs, W + q_chunk, axis=1)
            qpos = pos0 + qs + jnp.arange(q_chunk)
            kpos = pos0 + qs - W + jnp.arange(W + q_chunk)
            return None, _scores_softmax_v(qc, kc, vc, qpos, kpos, W,
                                           cfg.head_dim)
    else:

        def chunk_fn(_, i):
            qs = i * q_chunk
            qc = jax.lax.dynamic_slice_in_dim(q, qs, q_chunk, axis=1)
            qpos = pos0 + qs + jnp.arange(q_chunk)
            kpos = pos0 + jnp.arange(S)
            return None, _scores_softmax_v(qc, k, v, qpos, kpos, window,
                                           cfg.head_dim)

    _, outs = jax.lax.scan(chunk_fn, None, jnp.arange(n_chunks))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S_pad, cfg.q_dim)[:, :S]
    y = out @ p["wo"]
    return y, ((k, v) if return_cache else None)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def attn_cache_len(cfg: ModelConfig, spec: LayerSpec, max_len: int) -> int:
    """Cache slots a layer needs: its window if sliding, else ``max_len``."""
    if spec.mixer == "swa" and spec.window < max_len:
        return spec.window
    return max_len


def init_attn_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                    max_len: int, dtype=jnp.bfloat16) -> AttnCache:
    """Allocate an empty KV cache (slot positions start at -1)."""
    L = attn_cache_len(cfg, spec, max_len)
    KV, dh = cfg.num_kv_heads, cfg.head_dim
    return AttnCache(
        k=jnp.zeros((batch, L, KV, dh), dtype),
        v=jnp.zeros((batch, L, KV, dh), dtype),
        slot_pos=jnp.full((L,), -1, jnp.int32),
    )


def cache_from_prefill(cfg: ModelConfig, spec: LayerSpec, kv, max_len: int,
                       dtype=jnp.bfloat16) -> AttnCache:
    """Build a decode cache from prefill's raw (k, v) of S tokens."""
    k, v = kv
    B, S = k.shape[:2]
    L = attn_cache_len(cfg, spec, max_len)
    cache = init_attn_cache(cfg, spec, B, max_len, dtype)
    take = min(S, L)
    kk = k[:, S - take:].astype(dtype)
    vv = v[:, S - take:].astype(dtype)
    if L == spec.window and spec.mixer == "swa":
        # ring layout: token position p lives in slot p % L
        slots = (jnp.arange(S - take, S)) % L
        ck = cache.k.at[:, slots].set(kk)
        cv = cache.v.at[:, slots].set(vv)
        sp = cache.slot_pos.at[slots].set(jnp.arange(S - take, S))
    else:
        ck = cache.k.at[:, S - take : S].set(kk)
        cv = cache.v.at[:, S - take : S].set(vv)
        sp = cache.slot_pos.at[S - take : S].set(jnp.arange(S - take, S))
    return AttnCache(ck, cv, sp)


def attn_decode(p, x, cache: AttnCache, cfg: ModelConfig, spec: LayerSpec,
                pos, use_pallas: bool = False):
    """One-token decode. x: (B, 1, d); pos: traced scalar = index of the new
    token. Returns (y, new_cache).

    ``use_pallas=True`` routes the attention itself through the fused
    ``kernels.swa_decode`` Pallas kernel (flash-decode over the ring
    buffer); default is the pure-jnp path the kernel is validated against.
    """
    B = x.shape[0]
    L = cache.k.shape[1]
    window = spec.window if spec.mixer == "swa" else 0
    positions = jnp.asarray(pos)[None]
    q, k, v = _project_qkv(p, x, cfg, positions)

    # token position p lives in slot p % L (identity for linear caches, ring
    # layout for window caches where L == window)
    slot = (pos % L).astype(jnp.int32)
    ck = jax.lax.dynamic_update_index_in_dim(cache.k, k[:, 0].astype(cache.k.dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_index_in_dim(cache.v, v[:, 0].astype(cache.v.dtype), slot, axis=1)
    sp = jax.lax.dynamic_update_index_in_dim(cache.slot_pos,
                                             pos.astype(jnp.int32), slot, axis=0)

    if use_pallas:
        from repro.kernels import ops as kernel_ops  # lazy: pallas import
        out = kernel_ops.swa_decode(
            q[:, 0], ck.astype(q.dtype), cv.astype(q.dtype), sp,
            jnp.asarray(pos, jnp.int32), window=window,
        ).reshape(B, 1, cfg.q_dim)
    else:
        qpos = jnp.asarray(pos)[None]
        out = _scores_softmax_v(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                qpos, sp, window, cfg.head_dim)
    y = out @ p["wo"]
    return y, AttnCache(ck, cv, sp)
