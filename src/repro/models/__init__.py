"""Model zoo: transformer/recurrent/MoE blocks and the shared LM API."""
from repro.models.model import (  # noqa: F401
    DecodeState,
    abstract_decode_state,
    abstract_params,
    count_params,
    decode_step,
    forward,
    init_decode_state,
    init_params,
    prefill,
)
from repro.models.steps import (  # noqa: F401
    centralized_train_step,
    lm_grad_fn,
    lm_loss,
    prefill_step,
    serve_step,
)
