"""Loss / step functions consumed by the federated round, the smoke tests,
and the dry-run."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.layers import softmax_cross_entropy


def lm_loss(params, batch, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            q_chunk: int = 1024, remat: str = "full"):
    """batch: {"tokens": (B, S_text+1) int32, ["frontend": (B, F, d)]}.
    Returns (total_loss, metrics)."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits, aux = model_lib.forward(
        params, inputs, cfg, frontend=batch.get("frontend"),
        compute_dtype=compute_dtype, q_chunk=q_chunk, remat=remat,
    )
    if cfg.frontend:
        logits = logits[:, cfg.frontend_tokens:]
    xent = softmax_cross_entropy(logits, targets, valid_vocab=cfg.vocab_size)
    aux_w = cfg.moe.router_aux_weight if cfg.moe.enabled else 0.0
    total = xent + aux_w * aux
    return total, {"xent": xent, "moe_aux": aux}


def lm_grad_fn(cfg: ModelConfig, **kw):
    """The (loss, grads) client gradient function FedAvg/FedPA scan over."""
    def fn(params, batch):
        (loss, _), grads = jax.value_and_grad(
            functools.partial(lm_loss, cfg=cfg, **kw), has_aux=True
        )(params, batch)
        return loss, grads
    return fn


def centralized_train_step(params, opt_state, batch, cfg: ModelConfig, opt,
                           **kw):
    """Plain (non-federated) SGD step — the MB-SGD baseline of Fig. 1 at LM
    scale, and the smoke tests' single-step sanity check."""
    (loss, metrics), grads = jax.value_and_grad(
        functools.partial(lm_loss, cfg=cfg, **kw), has_aux=True
    )(params, batch)
    updates, opt_state = opt.update(grads, opt_state, params)
    params = jax.tree_util.tree_map(
        lambda p, u: p + u.astype(p.dtype), params, updates
    )
    return params, opt_state, loss, metrics


def serve_step(params, token, state, cfg: ModelConfig, *,
               compute_dtype=jnp.bfloat16, sample: bool = False,
               rng: Optional[jax.Array] = None, temperature: float = 1.0,
               use_pallas: bool = False):
    """One decode step for a batch of requests. token: (B,) int32.
    Returns (next_token (B,), logits (B, V), new_state)."""
    logits, state = model_lib.decode_step(params, token, state, cfg,
                                          compute_dtype=compute_dtype,
                                          use_pallas=use_pallas)
    # padded vocab rows must never be sampled
    pad_mask = jnp.arange(logits.shape[-1]) >= cfg.vocab_size
    logits = jnp.where(pad_mask, -1e30, logits.astype(jnp.float32))
    if sample:
        nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
    else:
        nxt = jnp.argmax(logits, axis=-1)
    return nxt.astype(jnp.int32), logits, state


def prefill_step(params, tokens, cfg: ModelConfig, max_len: int, *,
                 frontend=None, compute_dtype=jnp.bfloat16,
                 q_chunk: int = 1024):
    """Prompt ingestion: returns (last-token logits, decode state)."""
    return model_lib.prefill(params, tokens, cfg, max_len, frontend=frontend,
                             compute_dtype=compute_dtype, q_chunk=q_chunk)
