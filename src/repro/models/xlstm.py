"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
exponential gating). [arXiv:2405.04517]

TPU adaptation (DESIGN.md §3): the mLSTM is implemented in *chunkwise
recurrent* form — a ``lax.scan`` over sequence chunks carrying the
(C, n, m) state, with the intra-chunk part computed as a decay-masked
quadratic attention block. This keeps compute MXU-shaped (dense matmuls per
chunk), memory linear in sequence length, and the log-decay accumulators
chunk-local so fp32 cumsums never grow with S (they would lose precision at
500k tokens in a global-cumsum formulation). The sLSTM is inherently
sequential (its recurrence is nonlinear), so it scans over time steps.

Consistency between the chunkwise forward and the per-token decode step is
asserted in tests/test_xlstm.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import causal_conv1d, group_norm_heads, rms_norm


def _e(cfg: ModelConfig) -> int:
    return int(cfg.expansion * cfg.d_model)


def _slstm_ff(cfg: ModelConfig) -> int:
    f = (4 * cfg.d_model) // 3
    return ((f + 127) // 128) * 128


# ===========================================================================
# mLSTM
# ===========================================================================

class MLSTMState(NamedTuple):
    """mLSTM decode state: matrix memory, normalizer, stabilizer, conv tail."""

    C: jnp.ndarray       # (B, H, dh, dh) matrix memory (k-major)
    n: jnp.ndarray       # (B, H, dh) normalizer state
    m: jnp.ndarray       # (B, H) log stabilizer
    conv: jnp.ndarray    # (B, cw-1, e) streaming conv state


def init_mlstm_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    """Init the mLSTM block (up-proj, conv, q/k/v, gates, down-proj)."""
    d, e, H = cfg.d_model, _e(cfg), cfg.num_heads
    ks = jax.random.split(rng, 7)
    s = lambda fan: 1.0 / jnp.sqrt(fan)
    return {
        "w_up": jax.random.normal(ks[0], (d, 2 * e), dtype) * s(d),
        "conv": jax.random.normal(ks[1], (cfg.conv_width, e), dtype) * s(cfg.conv_width),
        "wq": jax.random.normal(ks[2], (e, e), dtype) * s(e),
        "wk": jax.random.normal(ks[3], (e, e), dtype) * s(e),
        "wv": jax.random.normal(ks[4], (e, e), dtype) * s(e),
        "w_gates": jax.random.normal(ks[5], (e, 2 * H), dtype) * s(e),
        # forget-gate bias init positive => long memory at init (xLSTM paper)
        "b_gates": jnp.concatenate(
            [jnp.full((H,), -3.0, dtype), jnp.full((H,), 3.0, dtype)]
        ),
        "gn_scale": jnp.zeros((e,), dtype),
        "w_down": jax.random.normal(ks[6], (e, d), dtype) * s(e),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MLSTMState:
    """Zero-initialise the mLSTM decode state."""
    e, H = _e(cfg), cfg.num_heads
    dh = e // H
    return MLSTMState(
        C=jnp.zeros((batch, H, dh, dh), dtype),
        n=jnp.zeros((batch, H, dh), dtype),
        m=jnp.full((batch, H), -1e30, dtype),
        conv=jnp.zeros((batch, cfg.conv_width - 1, e), dtype),
    )


def _mlstm_qkv_gates(p, x, cfg: ModelConfig, conv_state=None):
    """Shared projection path. x: (B, S, d). Returns q,k,v (B,S,H,dh),
    i_pre/f_pre (B,S,H), z (B,S,e), new conv state."""
    B, S, _ = x.shape
    e, H = _e(cfg), cfg.num_heads
    dh = e // H
    up = x @ p["w_up"]
    xi, z = up[..., :e], up[..., e:]
    xc, conv_state = causal_conv1d(xi, p["conv"], conv_state)
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(B, S, H, dh)
    k = (xc @ p["wk"]).reshape(B, S, H, dh) / jnp.sqrt(jnp.float32(dh)).astype(x.dtype)
    v = (xi @ p["wv"]).reshape(B, S, H, dh)
    gates = (xc @ p["w_gates"] + p["b_gates"]).astype(jnp.float32)
    i_pre, f_pre = gates[..., :H], gates[..., H:]
    return q, k, v, i_pre, f_pre, z, conv_state


def _mlstm_chunk(q, k, v, i_pre, f_pre, C, n, m):
    """One chunk of the stabilized chunkwise recurrence.

    q,k,v: (B,T,H,dh); i_pre,f_pre: (B,T,H) fp32; state (C,n,m).
    Returns (h (B,T,H,dh), C', n', m').
    """
    B, T, H, dh = q.shape
    lf = jax.nn.log_sigmoid(f_pre)                      # (B,T,H)
    F = jnp.cumsum(lf, axis=1)                          # inclusive: F[t]=sum_{s<=t}
    # log weight of sample s surviving to t (s <= t): F[t] - F[s] + i[s]
    Dt = (F[:, :, None, :] - F[:, None, :, :]
          + i_pre[:, None, :, :])                       # (B, t, s, H)
    causal = jnp.tril(jnp.ones((T, T), bool))
    Dt = jnp.where(causal[None, :, :, None], Dt, -jnp.inf)
    b = F + m[:, None, :]                               # (B,T,H) inter log-scale
    m_t = jnp.maximum(jnp.max(Dt, axis=2), b)           # (B,T,H)
    m_t = jnp.maximum(m_t, -1e30)                       # guard all--inf rows
    w_intra = jnp.exp(Dt - m_t[:, :, None, :])          # (B,t,s,H)
    w_inter = jnp.exp(b - m_t)                          # (B,T,H)

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * w_intra
    num = jnp.einsum("btsh,bshd->bthd", scores, vf)
    num = num + w_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qf,
                                                C.astype(jnp.float32))
    den = jnp.sum(scores, axis=2)                       # (B,T,H)
    den = den + w_inter * jnp.einsum("bthd,bhd->bth", qf, n.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    h = (num / den[..., None]).astype(q.dtype)

    # ---- carry update to the chunk end ----
    FT = F[:, -1, :]                                    # (B,H)
    ws = FT[:, None, :] - F + i_pre                     # (B,T,H) log w of s into state
    m_next = jnp.maximum(m + FT, jnp.max(ws, axis=1))
    m_next = jnp.maximum(m_next, -1e30)
    decay = jnp.exp(m + FT - m_next)                    # (B,H)
    w_in = jnp.exp(ws - m_next[:, None, :])             # (B,T,H)
    C_new = decay[..., None, None] * C.astype(jnp.float32) + jnp.einsum(
        "bsh,bshd,bshe->bhde", w_in, kf, vf
    )
    n_new = decay[..., None] * n.astype(jnp.float32) + jnp.einsum(
        "bsh,bshd->bhd", w_in, kf
    )
    return h, C_new.astype(C.dtype), n_new.astype(n.dtype), m_next.astype(m.dtype)


def mlstm_forward(p, x, cfg: ModelConfig, chunk: int = 256,
                  return_cache: bool = False):
    """Full-sequence mLSTM block. x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    e, H = _e(cfg), cfg.num_heads
    dh = e // H
    q, k, v, i_pre, f_pre, z, conv_state = _mlstm_qkv_gates(p, x, cfg)
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    st0 = init_mlstm_state(cfg, B)

    resh = lambda t: t.reshape((B, nc, chunk) + t.shape[2:]).swapaxes(0, 1)
    qs, ks, vs = resh(q), resh(k), resh(v)
    is_, fs_ = resh(i_pre), resh(f_pre)

    def body(carry, xs):
        C, n, m = carry
        qc, kc, vc, ic, fc = xs
        h, C, n, m = _mlstm_chunk(qc, kc, vc, ic, fc, C, n, m)
        return (C, n, m), h

    (C, n, m), hs = jax.lax.scan(body, (st0.C, st0.n, st0.m),
                                 (qs, ks, vs, is_, fs_))
    h = hs.swapaxes(0, 1).reshape(B, S, H, dh)
    h = group_norm_heads(h, p["gn_scale"].reshape(H, dh), cfg.norm_eps)
    y = (h.reshape(B, S, e) * jax.nn.silu(z)) @ p["w_down"]
    state = MLSTMState(C, n, m, conv_state) if return_cache else None
    return y, state


def mlstm_decode(p, x, state: MLSTMState, cfg: ModelConfig):
    """One-token recurrent step. x: (B, 1, d)."""
    B = x.shape[0]
    e, H = _e(cfg), cfg.num_heads
    dh = e // H
    q, k, v, i_pre, f_pre, z, conv_state = _mlstm_qkv_gates(
        p, x, cfg, conv_state=state.conv
    )
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                 # (B,H,dh)
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]             # (B,H)
    lf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(lf + state.m, i_pre)
    decay = jnp.exp(lf + state.m - m_new)
    inp = jnp.exp(i_pre - m_new)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    C = decay[..., None, None] * state.C.astype(jnp.float32) + \
        inp[..., None, None] * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = decay[..., None] * state.n.astype(jnp.float32) + inp[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(x.dtype)
    h = group_norm_heads(h, p["gn_scale"].reshape(H, dh), cfg.norm_eps)
    y = (h.reshape(B, 1, e) * jax.nn.silu(z)) @ p["w_down"]
    new_state = MLSTMState(C.astype(state.C.dtype), n.astype(state.n.dtype),
                           m_new.astype(state.m.dtype), conv_state)
    return y, new_state


# ===========================================================================
# sLSTM
# ===========================================================================

class SLSTMState(NamedTuple):
    """sLSTM decode state: cell, normalizer, hidden, stabilizer, conv tail."""

    c: jnp.ndarray       # (B, d)
    n: jnp.ndarray       # (B, d)
    h: jnp.ndarray       # (B, d)
    m: jnp.ndarray       # (B, d)
    conv: jnp.ndarray    # (B, cw-1, d)


def init_slstm_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    """Init the sLSTM block (conv, input/recurrent gate stacks, MLP)."""
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    ff = _slstm_ff(cfg)
    ks = jax.random.split(rng, 5)
    s = lambda fan: 1.0 / jnp.sqrt(fan)
    return {
        "conv": jax.random.normal(ks[0], (cfg.conv_width, d), dtype) * s(cfg.conv_width),
        "w": jax.random.normal(ks[1], (d, 4 * d), dtype) * s(d),
        "r": jax.random.normal(ks[2], (H, dh, 4 * dh), dtype) * s(dh),
        # gate order (z, i, f, o); forget bias positive
        "b": jnp.concatenate([
            jnp.zeros((d,), dtype), jnp.full((d,), -3.0, dtype),
            jnp.full((d,), 3.0, dtype), jnp.zeros((d,), dtype),
        ]),
        "gn_scale": jnp.zeros((d,), dtype),
        "mlp_norm": jnp.zeros((d,), dtype),
        "w_mlp_up": jax.random.normal(ks[3], (d, ff), dtype) * s(d),
        "w_mlp_down": jax.random.normal(ks[4], (ff, d), dtype) * s(ff),
    }


def init_slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SLSTMState:
    """Zero-initialise the sLSTM decode state (stabilizer at -inf)."""
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), dtype)
    return SLSTMState(c=z(), n=z(), h=z(),
                      m=jnp.full((batch, d), -1e30, dtype),
                      conv=jnp.zeros((batch, cfg.conv_width - 1, d), dtype))


def _slstm_cell(p, wx_t, st: SLSTMState, cfg: ModelConfig):
    """One recurrence step. wx_t: (B, 4d) precomputed input contribution."""
    B, d = st.h.shape
    H = cfg.num_heads
    dh = d // H
    rec = jnp.einsum("bhd,hde->bhe", st.h.reshape(B, H, dh).astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(B, 4 * d)
    g = wx_t.astype(jnp.float32) + rec
    z_, i_, f_, o_ = jnp.split(g, 4, axis=-1)
    lf = jax.nn.log_sigmoid(f_)
    m_new = jnp.maximum(lf + st.m, i_)
    ig = jnp.exp(i_ - m_new)
    fg = jnp.exp(lf + st.m - m_new)
    c = fg * st.c.astype(jnp.float32) + ig * jnp.tanh(z_)
    n = fg * st.n.astype(jnp.float32) + ig
    h = jax.nn.sigmoid(o_) * c / jnp.maximum(n, jnp.exp(-m_new))
    dt = st.h.dtype
    return SLSTMState(c.astype(dt), n.astype(dt), h.astype(dt),
                      m_new.astype(dt), st.conv)


def _slstm_out(p, h, cfg: ModelConfig):
    """GroupNorm + post-MLP (the sLSTM block's internal FFN)."""
    B, S, d = h.shape
    H = cfg.num_heads
    hn = group_norm_heads(h.reshape(B, S, H, d // H),
                          p["gn_scale"].reshape(H, d // H),
                          cfg.norm_eps).reshape(B, S, d)
    u = rms_norm(hn, p["mlp_norm"], cfg.norm_eps)
    return hn + jax.nn.gelu(u @ p["w_mlp_up"]) @ p["w_mlp_down"]


def slstm_forward(p, x, cfg: ModelConfig, return_cache: bool = False):
    """Run the sLSTM over a full sequence via lax.scan over time."""
    B, S, d = x.shape
    xc, conv_state = causal_conv1d(x, p["conv"])
    xc = jax.nn.silu(xc)
    wx = xc @ p["w"] + p["b"]

    st0 = init_slstm_state(cfg, B, dtype=x.dtype)

    def body(st, wx_t):
        st = _slstm_cell(p, wx_t, st, cfg)
        return st, st.h

    st, hs = jax.lax.scan(body, st0, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)                               # (B,S,d)
    y = _slstm_out(p, h, cfg)
    state = st._replace(conv=conv_state) if return_cache else None
    return y, state


def slstm_decode(p, x, state: SLSTMState, cfg: ModelConfig):
    """Advance the sLSTM one token from cached state."""
    xc, conv_state = causal_conv1d(x, p["conv"], state.conv)
    xc = jax.nn.silu(xc)
    wx = (xc @ p["w"] + p["b"])[:, 0]
    st = _slstm_cell(p, wx, state, cfg)
    y = _slstm_out(p, st.h[:, None, :], cfg)
    return y, st._replace(conv=conv_state)
