"""Pytree vector-space operations.

FedPA's dynamic program is pure vector algebra (dots, axpys, scalings) over
the model parameter vector. Implementing those ops directly on pytrees —
rather than ravelling to a single flat vector — keeps every leaf in its own
(possibly sharded) layout, which is what lets the same DP code run on a
3-parameter toy quadratic and on a tensor-parallel 47B-parameter model
without any cross-leaf reshard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tmap(fn, *trees):
    """Alias for jax.tree_util.tree_map."""
    return jax.tree_util.tree_map(fn, *trees)


def tadd(a, b):
    """a + b, leafwise."""
    return tmap(jnp.add, a, b)


def tsub(a, b):
    """a - b, leafwise."""
    return tmap(jnp.subtract, a, b)


def tscale(s, a):
    """s * a, leafwise."""
    return tmap(lambda x: s * x, a)


def taxpy(s, x, y):
    """y + s * x, leafwise."""
    return tmap(lambda xi, yi: yi + s * xi, x, y)


def tvdot(a, b, dtype=None):
    """Global dot product across all leaves (accumulated in >= fp32)."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    if dtype is None:
        # at least fp32; keep fp64 if the inputs carry it
        promoted = jnp.promote_types(leaves_a[0].dtype, jnp.float32)
        dtype = jnp.promote_types(promoted, leaves_b[0].dtype)
    parts = [
        jnp.vdot(x.astype(dtype), y.astype(dtype))
        for x, y in zip(leaves_a, leaves_b)
    ]
    return jnp.sum(jnp.stack(parts))


def tnorm(a):
    """Global L2 norm across all leaves."""
    return jnp.sqrt(tvdot(a, a))


def tzeros_like(a, dtype=None):
    """Zeros with each leaf's shape (and dtype unless overridden)."""
    return tmap(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), a)


def tcast(a, dtype):
    """Cast every leaf to ``dtype``."""
    return tmap(lambda x: x.astype(dtype), a)


def tstack(trees):
    """Stack a list of identically-structured trees along a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def tindex(tree, i):
    """Select index ``i`` along the leading axis of every leaf."""
    return tmap(lambda x: x[i], tree)


def tdynamic_index(tree, i):
    """Like tindex but with a traced index (lax.dynamic_index_in_dim)."""
    return tmap(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, axis=0, keepdims=False), tree
    )


def tdynamic_update(tree, update, i):
    """Write ``update`` into slot ``i`` of the leading axis of every leaf."""
    return tmap(
        lambda buf, u: jax.lax.dynamic_update_index_in_dim(buf, u, i, axis=0),
        tree,
        update,
    )


def tree_size(a) -> int:
    """Total element count across all leaves."""
    return sum(x.size for x in jax.tree_util.tree_leaves(a))


def tree_bytes(a) -> int:
    """Total bytes across all leaves."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(a))
