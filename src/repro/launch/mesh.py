"""Production mesh construction + multi-host ``jax.distributed`` setup.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use,
and ``init_distributed`` must run before the backend spins up.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshConfig
from repro.sharding import make_mesh_compat


def init_distributed(coordinator: Optional[str] = None,
                     process_id: Optional[int] = None,
                     num_processes: Optional[int] = None) -> bool:
    """Initialize ``jax.distributed`` for a multi-process (multi-host) run.

    Call before any other jax use (device queries included). With
    ``num_processes`` unset/0/1 this is a no-op returning False — the
    single-process paths never pay for it. Returns True after
    ``jax.distributed.initialize`` connects this process to the
    coordinator, at which point ``jax.devices()`` spans every host (each
    host's own slice is ``jax.local_devices()``) and collectives cross
    processes. On the CPU backend the gloo collectives implementation is
    selected first (the default ring transport has no cross-host story),
    which is what the 2-process smoke test runs on.
    """
    if not num_processes or num_processes <= 1:
        return False
    if coordinator is None or process_id is None:
        raise ValueError(
            "multi-process launch needs --coordinator host:port and "
            "--process-id (0..num_processes-1) on every process")
    if not 0 <= process_id < num_processes:
        raise ValueError(
            f"process_id {process_id} out of range for "
            f"{num_processes} processes")
    # probing the backend here would initialize it too early; the option
    # is CPU-only and inert elsewhere, so set it unconditionally
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis. Axis semantics: ("pod",) "data" carry federated clients / batch;
    "model" is tensor/expert parallel.

    With 512 placeholder devices forced (the dry-run), the single-pod mesh
    uses the first 256 — pod 0."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)"
        )
    return make_mesh_compat(shape, axes, devices=devices)


def mesh_from_config(mc: MeshConfig):
    """Materialize a ``MeshConfig`` as a jax mesh over the visible devices."""
    return make_mesh_compat(mc.shape, mc.axes)


def mesh_config(multi_pod: bool = False) -> MeshConfig:
    """The production ``MeshConfig`` for one pod or the two-pod slice."""
    return MULTI_POD if multi_pod else SINGLE_POD


def make_host_mesh():
    """Whatever devices exist, as a 1D ("data",) mesh — CPU simulation."""
    n = jax.device_count()
    return make_mesh_compat((n,), ("data",))
