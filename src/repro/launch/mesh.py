"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

import jax

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshConfig
from repro.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis. Axis semantics: ("pod",) "data" carry federated clients / batch;
    "model" is tensor/expert parallel.

    With 512 placeholder devices forced (the dry-run), the single-pod mesh
    uses the first 256 — pod 0."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)"
        )
    return make_mesh_compat(shape, axes, devices=devices)


def mesh_from_config(mc: MeshConfig):
    """Materialize a ``MeshConfig`` as a jax mesh over the visible devices."""
    return make_mesh_compat(mc.shape, mc.axes)


def mesh_config(multi_pod: bool = False) -> MeshConfig:
    """The production ``MeshConfig`` for one pod or the two-pod slice."""
    return MULTI_POD if multi_pod else SINGLE_POD


def make_host_mesh():
    """Whatever devices exist, as a 1D ("data",) mesh — CPU simulation."""
    n = jax.device_count()
    return make_mesh_compat((n,), ("data",))
