"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh and extract the roofline terms.

The programs lowered here are exactly what ``core.engine.RoundEngine``
dispatches at runtime — the fused round (``make_fed_round``) on the
window=1 path — so a config that compiles in the dry-run runs in the
unified loop.

The two ``os.environ`` statements below MUST stay ahead of every other
import: jax locks the device count on first initialization, and the
dry-run needs 512 placeholder host devices for ``jax.make_mesh`` to build
the production meshes. Tests override via REPRO_XLA_FLAGS.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --out dryrun.jsonl
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                                     # noqa: E402
from repro.algorithms import algorithm_names, phase_name      # noqa: E402
from repro.configs.base import SHAPES, FedConfig              # noqa: E402
from repro.core.sharded_round import (default_placement,      # noqa: E402
                                      make_fed_round)
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch.specs import (client_axes, input_specs,     # noqa: E402
                                store_population_layout)
from repro.models.steps import prefill_step, serve_step       # noqa: E402
from repro.sharding import axis_rules                         # noqa: E402
from repro.sharding.hlo_cost import (analyze as hlo_analyze,  # noqa: E402
                                     xla_cost_analysis)
from repro.sharding.roofline import derive, format_table      # noqa: E402


def default_fed_config(algorithm: str = "fedpa") -> FedConfig:
    """Dry-run federated config: K=8 local steps, l=2 IASG samples."""
    return FedConfig(
        algorithm=algorithm, local_steps=8, burn_in_steps=4,
        steps_per_sample=2, shrinkage_rho=0.1,
        server_opt="sgdm", server_lr=0.5, client_opt="sgd", client_lr=0.01,
    )


def should_skip(cfg, shape) -> str:
    """long_500k needs sub-quadratic decode (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return ("skip: pure full-attention arch — long_500k decode cache "
                "is unbounded (documented in DESIGN.md)")
    return ""


def _apply_knobs(cfg, fed, rec, *, delta_dtype, client_state_placement,
                 dropout_rate, moe_chunk, moe_routing, cache_shard,
                 tp_boundary, remat, payload_codec="none", lora_rank=4,
                 quant_bits=8):
    """Fold the perf/fault knob overrides into (cfg, fed), recording every
    non-default on the result record."""
    if delta_dtype != "float32":
        fed = dataclasses.replace(fed, delta_dtype=delta_dtype)
        rec["delta_dtype"] = delta_dtype
    if payload_codec != "none":
        # compressed-payload round (requires a supports_codec algorithm,
        # i.e. --algorithm fedlora; FedConfig validation enforces it)
        fed = dataclasses.replace(fed, payload_codec=payload_codec,
                                  lora_rank=lora_rank, quant_bits=quant_bits)
        rec["payload_codec"] = payload_codec
        rec["lora_rank"] = lora_rank
        rec["quant_bits"] = quant_bits
    if client_state_placement != "host":
        fed = dataclasses.replace(
            fed, client_state_placement=client_state_placement)
        rec["client_state_placement"] = client_state_placement
    if dropout_rate:
        # fault-injecting round variant: threads the (C,) survivor mask
        # through the weighted aggregation (round_program)
        fed = dataclasses.replace(fed, dropout_rate=dropout_rate)
        rec["dropout_rate"] = dropout_rate
    if remat != "full":
        rec["remat"] = remat
    if moe_chunk and cfg.moe.enabled:  # §Perf knob
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, chunk_tokens=moe_chunk))
        rec["moe_chunk"] = moe_chunk
    if moe_routing != "onehot" and cfg.moe.enabled:  # §Perf knob
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, routing=moe_routing))
        rec["moe_routing"] = moe_routing
    if cache_shard != "greedy":
        rec["cache_shard"] = cache_shard
    if tp_boundary:
        cfg = dataclasses.replace(cfg, tp_out_constraint=True)
        rec["tp_boundary"] = True
    return cfg, fed


def _lower_step(cfg, fed, shape, spec, mesh, placement, q_chunk, remat):
    """Lower the shape's step (train round / prefill / decode) against the
    mesh; returns ``(lowered, local_steps)``."""
    if shape.kind == "train":
        caxes = client_axes(mesh)
        round_fn = make_fed_round(
            cfg, fed, placement=placement,
            spmd_axes=(caxes if len(caxes) > 1 else caxes[0])
            if placement == "parallel" else None,
            q_chunk=q_chunk, remat=remat,
        )
        rules = ({"batch": (), "clients": caxes}
                 if placement == "parallel" else None)
        # stateful rounds return (state, metrics, new_client_states) — or
        # (state, metrics, new_store_state) with the device store; either
        # way the third output's sharding sits at args index 3 (keyed off
        # the explicit flag: a fault-injecting stateless round also has
        # extra args, so arity is not a statefulness signal)
        out_sh = ((spec["shardings"][0], None, spec["shardings"][3])
                  if spec["stateful"] else (spec["shardings"][0], None))
        with axis_rules(mesh, rules):
            lowered = jax.jit(
                round_fn,
                in_shardings=spec["shardings"],
                out_shardings=out_sh,
            ).lower(*spec["args"])
        return lowered, fed.local_steps
    if shape.kind == "prefill":
        def step(params, batch):
            return prefill_step(params, batch["tokens"], cfg, shape.seq_len,
                                frontend=batch.get("frontend"),
                                q_chunk=q_chunk)
        with axis_rules(mesh):
            lowered = jax.jit(
                step, in_shardings=spec["shardings"], out_shardings=None
            ).lower(*spec["args"])
        return lowered, 1
    # decode
    def step(params, token, state):
        return serve_step(params, token, state, cfg)
    with axis_rules(mesh):
        lowered = jax.jit(
            step, in_shardings=spec["shardings"],
            out_shardings=(None, None, spec["shardings"][2]),
        ).lower(*spec["args"])
    return lowered, 1


def _save_hlo_text(save_hlo, hlo_text, rec, arch, shape_name, *,
                   cache_shard, moe_chunk, moe_routing, tp_boundary,
                   delta_dtype):
    """Dump compiled HLO text (gzip) under a knob-variant filename."""
    import gzip
    os.makedirs(save_hlo, exist_ok=True)
    variant = ""
    if cache_shard != "greedy":
        variant += f"__cache-{cache_shard}"
    if moe_chunk:
        variant += f"__chunk-{moe_chunk}"
    if moe_routing != "onehot":
        variant += f"__route-{moe_routing}"
    if tp_boundary:
        variant += "__tpb"
    if delta_dtype != "float32":
        variant += "__delta-bf16"
    fn = os.path.join(save_hlo,
                      f"{arch}__{shape_name}__{rec['mesh']}{variant}.hlo.gz")
    with gzip.open(fn, "wt") as f:
        f.write(hlo_text)
    rec["hlo_file"] = fn


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              algorithm: str = "fedpa", placement: str = "auto",
              remat: str = "full", q_chunk: int = 1024,
              fed: FedConfig = None, compile_: bool = True,
              mesh=None, save_hlo: str = None,
              cache_shard: str = "greedy", moe_chunk: int = 0,
              tp_boundary: bool = False, moe_routing: str = "onehot",
              delta_dtype: str = "float32",
              client_state_placement: str = "host",
              num_clients: int = 64,
              dropout_rate: float = 0.0,
              payload_codec: str = "none", lora_rank: int = 4,
              quant_bits: int = 8) -> dict:
    """Lower (and optionally compile) one (arch, shape, mesh) combination;
    returns the record dict (roofline terms, memory, collectives, or the
    skip/error status). ``client_state_placement="device"`` lowers the
    stateful round with the device-resident client-state store —
    ``num_clients`` sizes its population axis."""
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    skip = should_skip(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "algorithm": algorithm}
    if skip:
        rec["status"] = skip
        return rec

    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    fed = fed or default_fed_config(algorithm)
    # same display-name helper as launch.train; the dry-run lowers the
    # sampling-regime round, so label it with the first post-burn-in round
    rec["algorithm"] = phase_name(fed, fed.burn_in_rounds)
    cfg, fed = _apply_knobs(
        cfg, fed, rec, delta_dtype=delta_dtype,
        client_state_placement=client_state_placement,
        dropout_rate=dropout_rate, moe_chunk=moe_chunk,
        moe_routing=moe_routing, cache_shard=cache_shard,
        tp_boundary=tp_boundary, remat=remat,
        payload_codec=payload_codec, lora_rank=lora_rank,
        quant_bits=quant_bits)
    if placement == "auto":
        placement = default_placement(cfg)
    rec["placement"] = placement if shape.kind == "train" else "-"
    rec["chips"] = chips

    if client_state_placement == "device":
        # the store's population layout (launch.specs is the source of
        # truth): sharded over the client axes, padded — a 1M-client
        # scaffold store holds padded_N/extent rows per device
        layout = store_population_layout(mesh, num_clients)
        rec["store_population"] = {
            "num_clients": layout.num_clients,
            "padded_num_clients": layout.padded_num_clients,
            "shard_extent": layout.extent,
            "rows_per_device": layout.padded_num_clients
            // max(layout.extent, 1),
        }
    spec = input_specs(cfg, shape, fed, mesh, placement,
                       cache_shard=cache_shard, num_clients=num_clients)
    if shape.kind == "train":
        # exact per-round wire bytes from the abstract specs (uplink may be
        # compressed; downlink is params + broadcast extras) — no allocation
        from repro.compression import round_bytes  # noqa: PLC0415
        rec["payload_bytes"] = round_bytes(fed, spec["args"][0].params)
    t0 = time.time()
    lowered, local_steps = _lower_step(cfg, fed, shape, spec, mesh,
                                       placement, q_chunk, remat)
    rec["lower_s"] = round(time.time() - t0, 2)
    if not compile_:
        rec["status"] = "lowered"
        return rec

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    # XLA's cost_analysis counts loop bodies once (no trip scaling) — see
    # EXPERIMENTS.md §Roofline/Methodology. Use the trip-count-aware HLO
    # walker for the real per-device numbers; keep XLA's raw view on record.
    raw_cost = xla_cost_analysis(compiled)
    rec["cost_xla_raw"] = {k: raw_cost[k] for k in ("flops", "bytes accessed")
                           if k in raw_cost}
    hlo_text = compiled.as_text()
    if save_hlo:
        _save_hlo_text(save_hlo, hlo_text, rec, arch, shape_name,
                       cache_shard=cache_shard, moe_chunk=moe_chunk,
                       moe_routing=moe_routing, tp_boundary=tp_boundary,
                       delta_dtype=delta_dtype)
    hlo = hlo_analyze(hlo_text)
    cost = {"flops": hlo["flops"], "bytes accessed": hlo["bytes"]}
    rec["cost"] = cost
    coll = hlo["collectives"]
    rec["collectives"] = coll
    # sequential placement: the round runs clients_per_round clients back to
    # back, each doing local_steps of the full global batch
    eff_steps = local_steps
    if shape.kind == "train" and rec.get("placement") == "sequential":
        eff_steps = local_steps * fed.clients_per_round
    report = derive(arch, shape, cfg, rec["mesh"], chips, cost, coll,
                    local_steps=eff_steps if shape.kind == "train" else 1)
    rec["roofline"] = report.as_row()
    rec["status"] = "ok"
    return rec


def main():
    """CLI: sweep (arch x shape x mesh) combos, print the roofline table."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--algorithm", default="fedpa",
                    choices=algorithm_names(),
                    help="registered federated algorithm "
                         f"(repro.algorithms): {', '.join(algorithm_names())}")
    ap.add_argument("--placement", default="auto",
                    choices=("auto", "parallel", "sequential"))
    ap.add_argument("--remat", default="full",
                    choices=("full", "dots", "none"))
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--cache-shard", default="greedy",
                    choices=("greedy", "flash"),
                    help="decode KV-cache sharding strategy (§Perf)")
    ap.add_argument("--moe-chunk", type=int, default=0,
                    help="override MoE chunk_tokens (§Perf)")
    ap.add_argument("--delta-dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="FedPA sample/DP-state dtype (§Perf)")
    ap.add_argument("--payload-codec", default="none",
                    help="client payload codec chain (repro.compression): "
                         "none | lowrank | int8 | lowrank+int8; non-'none' "
                         "requires --algorithm fedlora")
    ap.add_argument("--lora-rank", type=int, default=4,
                    help="rank of the 'lowrank' codec's sketch")
    ap.add_argument("--quant-bits", type=int, default=8, choices=(8, 16),
                    help="bit width of the 'int8' codec's quantizer")
    ap.add_argument("--client-state-placement", default="host",
                    choices=("host", "device"),
                    help="client-state store for stateful algorithms: "
                         "host numpy or device-resident buffers traced "
                         "through the round (core/client_state.py)")
    ap.add_argument("--num-clients", type=int, default=64,
                    help="population size of the device-resident "
                         "client-state store (device placement only)")
    ap.add_argument("--dropout-rate", type=float, default=0.0,
                    help="lower the fault-injecting round variant: a (C,) "
                         "survivor mask threads through the aggregation "
                         "(data/cohort_source.py)")
    ap.add_argument("--moe-routing", default="onehot",
                    choices=("onehot", "sort"),
                    help="MoE dispatch implementation (§Perf)")
    ap.add_argument("--tp-boundary", action="store_true",
                    help="pin TP all-reduces at mixer/ffn outputs (§Perf)")
    ap.add_argument("--save-hlo", default=None,
                    help="dump compiled HLO text (gzip) into this dir")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = configs.ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = lower_one(
                        arch, shape, multi_pod=mp, algorithm=args.algorithm,
                        placement=args.placement, remat=args.remat,
                        q_chunk=args.q_chunk, compile_=not args.no_compile,
                        save_hlo=args.save_hlo, cache_shard=args.cache_shard,
                        moe_chunk=args.moe_chunk,
                        tp_boundary=args.tp_boundary,
                        moe_routing=args.moe_routing,
                        delta_dtype=args.delta_dtype,
                        client_state_placement=args.client_state_placement,
                        num_clients=args.num_clients,
                        dropout_rate=args.dropout_rate,
                        payload_codec=args.payload_codec,
                        lora_rank=args.lora_rank,
                        quant_bits=args.quant_bits,
                    )
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": f"ERROR: {e}",
                           "traceback": traceback.format_exc()}
                records.append(rec)
                status = rec.get("status", "?")
                print(f"[{rec['mesh']}] {arch} x {shape}: {status} "
                      f"(lower {rec.get('lower_s', '-')}s, "
                      f"compile {rec.get('compile_s', '-')}s)", flush=True)
                if rec.get("memory"):
                    per_dev = rec["memory"].get("temp_size_in_bytes", 0)
                    print(f"    temp/device: {per_dev/2**30:.2f} GiB; "
                          f"args: {rec['memory'].get('argument_size_in_bytes',0)/2**30:.2f} GiB; "
                          f"collective bytes: {rec['collectives']['total_bytes']/2**20:.1f} MiB",
                          flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")

    ok = [r.get("roofline") for r in records if r.get("roofline")]
    if ok:
        print("\n" + format_table(ok))
    n_err = sum(1 for r in records if str(r.get("status", "")).startswith("ERROR"))
    print(f"\n{len(records)} combos, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
