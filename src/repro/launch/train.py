"""Federated LM training driver (end-to-end example entry point).

Runs real federated rounds of the selected architecture on whatever devices
exist (CPU simulation here; the same code paths the dry-run lowers for the
production mesh). FedPA vs FedAvg is a flag; checkpoints + metrics logged.

  PYTHONPATH=src python -m repro.launch.train --arch fedlm-100m --smoke \
      --rounds 20 --algorithm fedpa

Multi-host: launch one process per host with ``--coordinator host:port
--num-processes N --process-id k``. The population axis (client-state
store + cohort batches) shards over the global device mesh; each process
builds only its shard's batches (``data/prefetch.py``), the server state
is replicated, and checkpoints split into a process-0 server file plus
per-host store shards. Single-host population sharding (over local
devices) is ``--shard-population``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.algorithms import algorithm_names, get_algorithm, phase_name
from repro.checkpoint import (restore_checkpoint, restore_store_sharded,
                              save_checkpoint, save_store_sharded)
from repro.compression import round_bytes
from repro.configs.base import FedConfig
from repro.core.client_state import make_client_store
from repro.core.engine import RoundEngine
from repro.core.server import init_server_state
from repro.core.sharded_round import make_fed_round, make_fed_round_split
from repro.data import SyntheticLMData
from repro.data.cohort_source import CohortSource
from repro.data.prefetch import (globalize_cohort_batches, local_row_range,
                                 replicate_global)
from repro.launch.mesh import init_distributed, make_host_mesh
from repro.models import init_params, lm_loss
from repro.optim import get_optimizer


def build_fed(args) -> FedConfig:
    """CLI flags -> the run's ``FedConfig``."""
    return FedConfig(
        algorithm=args.algorithm,
        clients_per_round=args.clients,
        local_steps=args.local_steps,
        burn_in_steps=args.burn_in_steps,
        steps_per_sample=args.steps_per_sample,
        shrinkage_rho=args.rho,
        server_opt=args.server_opt, server_lr=args.server_lr,
        client_opt=args.client_opt, client_lr=args.client_lr,
        burn_in_rounds=args.burn_in_rounds,
        payload_codec=args.payload_codec,
        lora_rank=args.lora_rank,
        quant_bits=args.quant_bits,
        error_feedback=not args.no_error_feedback,
        async_rounds=args.async_rounds,
        max_staleness=args.max_staleness,
        staleness_discount=args.staleness_discount,
        prefetch_rounds=args.prefetch_rounds,
        prefetch_backend=args.prefetch_backend,
        client_state_placement=args.client_state_placement,
        availability=args.availability,
        availability_period=args.availability_period,
        availability_duty=args.availability_duty,
        dropout_rate=args.dropout_rate,
        straggler_rate=args.straggler_rate,
        straggler_max_lateness=args.straggler_max_lateness,
        min_local_steps=args.min_local_steps,
    )


def parse_args(argv=None):
    """CLI flags for the training driver."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedlm-100m",
                    choices=configs.ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--algorithm", default="fedpa",
                    choices=algorithm_names(),
                    help="registered federated algorithm "
                         f"(repro.algorithms): {', '.join(algorithm_names())}")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--num-clients", type=int, default=64,
                    help="population size")
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--burn-in-steps", type=int, default=4)
    ap.add_argument("--steps-per-sample", type=int, default=2)
    ap.add_argument("--burn-in-rounds", type=int, default=5)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--server-opt", default="sgdm")
    ap.add_argument("--server-lr", type=float, default=0.5)
    ap.add_argument("--client-opt", default="sgdm",
                    help="client optimizer (scaffold requires 'sgd')")
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--payload-codec", default="none",
                    help="client payload codec chain (repro.compression): "
                         "none | lowrank | int8 | lowrank+int8; non-'none' "
                         "requires --algorithm fedlora")
    ap.add_argument("--lora-rank", type=int, default=4,
                    help="rank of the 'lowrank' codec's per-(round, leaf) "
                         "sketch")
    ap.add_argument("--quant-bits", type=int, default=8, choices=(8, 16),
                    help="bit width of the 'int8' codec's quantizer")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable fedlora's per-client compression-error "
                         "residual (the client-state store stays unused)")
    ap.add_argument("--async-rounds", action="store_true",
                    help="double-buffered rounds: overlap cohort t+1's "
                         "client compute with round t's server update "
                         "(the wide-window path of core/engine.py)")
    ap.add_argument("--max-staleness", type=int, default=1,
                    help="cohorts in flight beyond the one being applied; "
                         "0 matches the sync path numerically")
    ap.add_argument("--staleness-discount", type=float, default=0.9,
                    help="a staleness-s delta is scaled by discount**s")
    ap.add_argument("--prefetch-rounds", type=int, default=2,
                    help="cohort batches stacked ahead by a host thread "
                         "(0 = inline)")
    ap.add_argument("--prefetch-backend", default="process",
                    choices=("process", "thread"),
                    help="cohort prefetcher: forked shared-memory arena "
                         "builder (overlaps GIL-bound decode) or in-process "
                         "thread (data/prefetch.py)")
    ap.add_argument("--availability", default="always",
                    choices=("always", "diurnal"),
                    help="client availability trace; 'diurnal' samples "
                         "cohorts only from currently-up clients "
                         "(data/cohort_source.py)")
    ap.add_argument("--availability-period", type=int, default=24,
                    help="diurnal cycle length in rounds")
    ap.add_argument("--availability-duty", type=float, default=0.5,
                    help="fraction of the cycle each client is up")
    ap.add_argument("--dropout-rate", type=float, default=0.0,
                    help="per-client mid-round dropout probability; "
                         "survivors' partial aggregate is renormalized and "
                         "dropped clients' state writes are masked")
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    help="probability a cohort misses its round deadline "
                         "(requires --async-rounds; late deltas are "
                         "discounted by staleness_discount**s)")
    ap.add_argument("--straggler-max-lateness", type=int, default=2,
                    help="max extra rounds of straggler lateness")
    ap.add_argument("--min-local-steps", type=int, default=0,
                    help="heterogeneous per-client step budgets in "
                         "[min, local_steps]; 0 = homogeneous (requires "
                         "--client-opt sgd on a gradient-pure algorithm)")
    ap.add_argument("--client-state-placement", default="host",
                    choices=("host", "device"),
                    help="where stateful algorithms' per-client state "
                         "lives: host numpy store (one device sync per "
                         "stateful round at scatter time) or device "
                         "buffers threaded through the jitted round "
                         "(sync-free; pulled to host only at checkpoints)")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 for a multi-host run "
                         "(jax.distributed); every process passes the "
                         "same value")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank in [0, num_processes)")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total processes in the multi-host run "
                         "(unset/1 = single-process)")
    ap.add_argument("--shard-population", action="store_true",
                    help="shard the population axis (client-state store + "
                         "cohort batches) over the device mesh; implied "
                         "by a multi-process launch")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log", default=None, help="JSONL metrics path")
    return ap.parse_args(argv)


def make_round_batches(args, cfg, fed, data, s_text):
    """Cohort batch builder ``(round, ids) -> batches`` for the round fns.

    The process prefetcher's forked builder must stay off the jax runtime,
    so its cohorts are assembled as numpy (bf16 is a numpy dtype via
    ml_dtypes; the jitted round casts on transfer)."""
    host_batches = fed.prefetch_backend == "process"

    def round_batches(r, ids):
        toks = data.round_batches(ids, fed.local_steps, args.batch, s_text,
                                  round_idx=r, host=host_batches)
        batches = {"tokens": toks}
        if cfg.frontend:
            fe = np.stack([
                np.stack([
                    data.frontend_embeddings(
                        int(c), args.batch, cfg.frontend_tokens, cfg.d_model,
                        salt=r * 1000 + k, host=True)
                    for k in range(fed.local_steps)
                ]) for c in ids
            ])
            batches["frontend"] = (fe.astype(jnp.bfloat16) if host_batches
                                   else jnp.asarray(fe, jnp.bfloat16))
        return batches

    return round_batches


def make_eval_fn(args, cfg, data, s_text, q_chunk):
    """Jitted held-out eval loss on a batch from an unseen client id."""
    eval_batch = {
        "tokens": data.client_batches(args.num_clients + 1, 1, args.batch,
                                      s_text)[0]
    }
    if cfg.frontend:
        eval_batch["frontend"] = jnp.asarray(
            data.frontend_embeddings(args.num_clients + 1, args.batch,
                                     cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    return jax.jit(lambda p: lm_loss(p, eval_batch, cfg,
                                     q_chunk=q_chunk)[0])


def restore_if_present(args, state, store, ckpt_tree):
    """Resume from ``--ckpt-dir`` when a checkpoint exists.

    Returns ``(state, start_round)``; client state is loaded back into the
    store in place."""
    start_round = 0
    if args.ckpt_dir and os.path.isdir(args.ckpt_dir):
        try:
            restored, start_round, _ = restore_checkpoint(args.ckpt_dir,
                                                          ckpt_tree(state))
            if store is None:
                state = restored
            else:
                state = restored["server"]
                store.load_state_dict(restored["clients"])
            print(f"restored checkpoint at round {start_round}")
        except FileNotFoundError:
            pass
    return state, start_round


def main():
    """Parse flags, build the round programs, drive the training loop."""
    args = parse_args()
    # before ANY jax device use: distributed init must see an
    # uninitialized backend
    distributed = init_distributed(args.coordinator, args.process_id,
                                   args.num_processes)
    shard_pop = args.shard_population or distributed
    if distributed and args.async_rounds:
        raise SystemExit("--async-rounds is single-host only (the async "
                         "engine's apply-order write-back has no "
                         "cross-process story yet); drop the flag")
    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    fed = build_fed(args)
    if shard_pop and fed.prefetch_backend == "process":
        # per-host feeding assembles global jax arrays in the builder; the
        # forked arena child must never touch the jax runtime
        fed = dataclasses.replace(fed, prefetch_backend="thread")
    pop_mesh = make_host_mesh() if shard_pop else None
    is_main = jax.process_index() == 0
    if is_main:
        print(f"arch={cfg.name} params={configs.get_smoke(args.arch).param_count() if args.smoke else cfg.param_count():,} "
              f"algorithm={fed.algorithm} rounds={args.rounds}"
              + (f" processes={jax.process_count()}" if distributed else "")
              + (f" population_mesh={tuple(pop_mesh.shape.values())}"
                 if pop_mesh is not None else ""))

    data = SyntheticLMData(vocab_size=cfg.vocab_size,
                           num_clients=args.num_clients, seed=args.seed)
    s_text = args.seq_len - (cfg.frontend_tokens if cfg.frontend else 0)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    alg = get_algorithm(fed)
    state = init_server_state(params, server_opt, algorithm=alg)
    # stateful algorithms (scaffold/fedep): per-client persistent state,
    # checkpointed alongside the server state. A burn regime may differ in
    # statefulness from the main regime (fedep burns in as stateless
    # fedavg) — same rule as FedSim/RoundEngine.
    burn_stateful = (alg.burn_algorithm().stateful
                     if alg.has_burn_regime and fed.burn_in_rounds
                     else alg.stateful)
    device_store = fed.client_state_placement == "device"
    if shard_pop and not device_store and (alg.stateful or burn_stateful):
        raise SystemExit("population sharding needs the device store for "
                         "stateful algorithms: add "
                         "--client-state-placement device")
    store = (make_client_store(fed.client_state_placement, args.num_clients,
                               mesh=pop_mesh if device_store else None)
             .ensure(alg.init_client_state(params))
             if alg.stateful or burn_stateful else None)
    # a sharded store never ships through the server checkpoint: each
    # host writes its own slice (checkpoint.save_store_sharded)
    sharded_store = store is not None and pop_mesh is not None

    def ckpt_tree(round_state):
        """Checkpoint pytree: bare server state, or {"server", "clients"}.

        ``store.state_dict()`` is the one place device-resident client
        state is pulled to the host."""
        if store is None or sharded_store:
            return round_state
        return {"server": round_state, "clients": store.state_dict()}

    state, start_round = restore_if_present(
        args, state, None if sharded_store else store, ckpt_tree)
    if sharded_store and start_round:
        restore_store_sharded(args.ckpt_dir, store, step=start_round)

    q_chunk = min(64, s_text)

    # faults + sampling + weights live in the cohort source; its draws key
    # off the ABSOLUTE round index, so a checkpoint restart replays the
    # same fault matrix
    round_batches = make_round_batches(args, cfg, fed, data, s_text)
    if pop_mesh is not None:
        # per-host cohort feeding: this process builds batches only for
        # the cohort rows its devices own; the global (C, ...) arrays are
        # assembled shard-locally — no batch bytes cross hosts
        lo, hi = local_row_range(pop_mesh, "data", fed.clients_per_round)
        base_batches = round_batches

        def round_batches(r, ids):  # noqa: F811 — sharded feeding wrapper
            local = base_batches(r, np.asarray(ids)[lo:hi])
            return globalize_cohort_batches(local, pop_mesh, "data",
                                            len(ids), lo)
    source = CohortSource(fed, args.num_clients,
                          lambda ids, r: round_batches(r, ids),
                          seed=args.seed)

    eval_fn = make_eval_fn(args, cfg, data, s_text, q_chunk)

    logf = open(args.log, "a") if args.log and is_main else None

    def emit(rec):
        if not is_main:
            return  # every process computes metrics; one reports
        print(json.dumps(rec), flush=True)
        if logf:
            logf.write(json.dumps(rec) + "\n")
            logf.flush()

    def maybe_checkpoint(round_state, r):
        if args.ckpt_dir and ((r + 1) % args.ckpt_every == 0
                              or r == args.rounds - 1):
            if is_main:
                save_checkpoint(args.ckpt_dir, ckpt_tree(round_state), r + 1,
                                {"arch": cfg.name,
                                 "algorithm": fed.algorithm})
            if sharded_store:
                # every process writes its own store slice
                save_store_sharded(args.ckpt_dir, store, r + 1,
                                   {"arch": cfg.name,
                                    "algorithm": fed.algorithm})

    state = run_rounds(args, cfg, fed, alg, state, store, burn_stateful,
                       start_round, source, eval_fn, emit, maybe_checkpoint,
                       q_chunk, pop_mesh=pop_mesh)
    if logf:
        logf.close()


def run_rounds(args, cfg, fed, alg, state, store, burn_stateful, start_round,
               source, eval_fn, emit, maybe_checkpoint, q_chunk,
               pop_mesh=None):
    """Drive the unified ``RoundEngine``; returns the final state.

    One loop for both modes: synchronous runs are the in-flight window of
    one (single-dispatch fused round — bitwise the historical sync loop);
    ``fed.async_rounds`` widens the window to ``max_staleness + 1`` so
    cohort t+1's client compute overlaps round t's server update, deltas
    discounted by ``staleness_discount**s``. The engine owns all jitting
    (including the device store's donation + pinned shardings); with
    ``pop_mesh`` the host-built operands are lifted to global arrays via
    ``lift_operand`` and the server state is made global up front."""
    if pop_mesh is not None:
        # every jit input must be a global array in a multi-process run;
        # after round one the server state is a round output and stays so
        state = replicate_global(state, pop_mesh)
    has_burn = alg.has_burn_regime and fed.burn_in_rounds > 0
    cohort_fn, server_fn = make_fed_round_split(
        cfg, fed, placement="parallel", q_chunk=q_chunk)
    burn_cohort_fn = burn_server_fn = None
    if has_burn:
        burn_cohort_fn, burn_server_fn = make_fed_round_split(
            cfg, fed, placement="parallel", q_chunk=q_chunk,
            use_sampling=False)
    rb = round_bytes(fed, state.params)
    burn_rb = (round_bytes(fed, state.params, use_sampling=False)
               if has_burn else rb)
    engine = RoundEngine(
        cohort_fn=cohort_fn,
        server_fn=server_fn,
        round_fn=make_fed_round(cfg, fed, placement="parallel",
                                q_chunk=q_chunk),
        burn_cohort_fn=burn_cohort_fn,
        burn_server_fn=burn_server_fn,
        burn_round_fn=(make_fed_round(cfg, fed, placement="parallel",
                                      q_chunk=q_chunk, use_sampling=False)
                       if has_burn else None),
        burn_in_rounds=max(0, fed.burn_in_rounds - start_round),
        max_staleness=fed.max_staleness if fed.async_rounds else 0,
        staleness_discount=fed.staleness_discount,
        # straggler lateness needs the apply-time discount exponent, which
        # only the split pipeline traces
        pipeline_only=fed.straggler_rate > 0,
        prefetch_rounds=fed.prefetch_rounds,
        prefetch_backend=fed.prefetch_backend,
        client_store=store,
        stateful=alg.stateful,
        burn_stateful=burn_stateful,
        record_faults=fed.fault_injection,
        round_bytes=rb,
        burn_round_bytes=burn_rb,
        lift_operand=(None if pop_mesh is None
                      else lambda x: replicate_global(x, pop_mesh)),
    )

    def build_cohort(i):
        # the engine orders by its own 0-based index; the draw (and its
        # faults) stays keyed to the absolute round
        return source.cohort(start_round + i)._replace(round_idx=i)

    last_t = time.time()

    def on_round(rec, round_state):
        # live per-round logging + periodic checkpoints; forcing the
        # metrics here costs one sync per round, but (async) the next
        # cohorts are already dispatched on device
        nonlocal last_t
        r = start_round + rec["round"]
        out = {"round": r,
               "eval_loss": (float(rec["eval"]["eval_loss"])
                             if "eval" in rec else None),
               "client_loss_last": float(rec["metrics"]["loss_last"]),
               "client_loss_first": float(rec["metrics"]["loss_first"]),
               "staleness": rec["staleness"],
               "phase": phase_name(fed, r),
               "sec": round(time.time() - last_t, 2)}
        for k in ("dropped", "straggled", "bytes_up", "bytes_down"):
            out[k] = rec[k]
        emit(out)
        last_t = time.time()
        maybe_checkpoint(round_state, r)

    state, _ = engine.run(
        state, build_cohort, args.rounds - start_round,
        eval_fn=lambda p: {"eval_loss": float(eval_fn(p))},
        on_round=on_round)
    return state


if __name__ == "__main__":
    main()
