"""Federated LM training driver (end-to-end example entry point).

Runs real federated rounds of the selected architecture on whatever devices
exist (CPU simulation here; the same code paths the dry-run lowers for the
production mesh). FedPA vs FedAvg is a flag; checkpoints + metrics logged.

  PYTHONPATH=src python -m repro.launch.train --arch fedlm-100m --smoke \
      --rounds 20 --algorithm fedpa
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import FedConfig
from repro.core.server import init_server_state
from repro.core.sharded_round import make_fed_round
from repro.data import SyntheticLMData
from repro.data.sampling import ClientSampler
from repro.models import init_params, lm_loss
from repro.optim import get_optimizer


def build_fed(args) -> FedConfig:
    return FedConfig(
        algorithm=args.algorithm,
        clients_per_round=args.clients,
        local_steps=args.local_steps,
        burn_in_steps=args.burn_in_steps,
        steps_per_sample=args.steps_per_sample,
        shrinkage_rho=args.rho,
        server_opt=args.server_opt, server_lr=args.server_lr,
        client_opt="sgdm", client_lr=args.client_lr,
        burn_in_rounds=args.burn_in_rounds,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedlm-100m",
                    choices=configs.ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--algorithm", default="fedpa",
                    choices=("fedavg", "fedpa"))
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--num-clients", type=int, default=64,
                    help="population size")
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--burn-in-steps", type=int, default=4)
    ap.add_argument("--steps-per-sample", type=int, default=2)
    ap.add_argument("--burn-in-rounds", type=int, default=5)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--server-opt", default="sgdm")
    ap.add_argument("--server-lr", type=float, default=0.5)
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log", default=None, help="JSONL metrics path")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    fed = build_fed(args)
    print(f"arch={cfg.name} params={configs.get_smoke(args.arch).param_count() if args.smoke else cfg.param_count():,} "
          f"algorithm={fed.algorithm} rounds={args.rounds}")

    data = SyntheticLMData(vocab_size=cfg.vocab_size,
                           num_clients=args.num_clients, seed=args.seed)
    sampler = ClientSampler(args.num_clients, args.clients, args.seed)
    s_text = args.seq_len - (cfg.frontend_tokens if cfg.frontend else 0)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state = init_server_state(params, server_opt)
    start_round = 0
    if args.ckpt_dir and os.path.isdir(args.ckpt_dir):
        try:
            state, start_round, _ = restore_checkpoint(args.ckpt_dir, state)
            print(f"restored checkpoint at round {start_round}")
        except FileNotFoundError:
            pass

    q_chunk = min(64, s_text)
    round_sample = jax.jit(make_fed_round(cfg, fed, placement="parallel",
                                          q_chunk=q_chunk))
    round_burn = jax.jit(make_fed_round(cfg, fed, placement="parallel",
                                        q_chunk=q_chunk, use_sampling=False))

    def round_batches(r):
        ids = sampler.sample(r)
        toks = data.round_batches(ids, fed.local_steps, args.batch, s_text,
                                  round_idx=r)
        batches = {"tokens": toks}
        if cfg.frontend:
            fe = np.stack([
                np.stack([
                    np.asarray(data.frontend_embeddings(
                        int(c), args.batch, cfg.frontend_tokens, cfg.d_model,
                        salt=r * 1000 + k))
                    for k in range(fed.local_steps)
                ]) for c in ids
            ])
            batches["frontend"] = jnp.asarray(fe, jnp.bfloat16)
        return batches

    # held-out eval batch from unseen client ids
    eval_batch = {
        "tokens": data.client_batches(args.num_clients + 1, 1, args.batch,
                                      s_text)[0]
    }
    if cfg.frontend:
        eval_batch["frontend"] = jnp.asarray(
            data.frontend_embeddings(args.num_clients + 1, args.batch,
                                     cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    eval_fn = jax.jit(lambda p: lm_loss(p, eval_batch, cfg,
                                        q_chunk=q_chunk)[0])

    logf = open(args.log, "a") if args.log else None
    for r in range(start_round, args.rounds):
        t0 = time.time()
        fn = round_burn if r < fed.burn_in_rounds else round_sample
        state, metrics = fn(state, round_batches(r))
        ev = float(eval_fn(state.params))
        rec = {"round": r, "eval_loss": ev,
               "client_loss_last": float(metrics["loss_last"]),
               "phase": "burn-in" if r < fed.burn_in_rounds else fed.algorithm,
               "sec": round(time.time() - t0, 2)}
        print(json.dumps(rec), flush=True)
        if logf:
            logf.write(json.dumps(rec) + "\n")
            logf.flush()
        if args.ckpt_dir and ((r + 1) % args.ckpt_every == 0
                              or r == args.rounds - 1):
            save_checkpoint(args.ckpt_dir, state, r + 1,
                            {"arch": cfg.name, "algorithm": fed.algorithm})
    if logf:
        logf.close()


if __name__ == "__main__":
    main()
