"""Federated LM training driver (end-to-end example entry point).

Runs real federated rounds of the selected architecture on whatever devices
exist (CPU simulation here; the same code paths the dry-run lowers for the
production mesh). FedPA vs FedAvg is a flag; checkpoints + metrics logged.

  PYTHONPATH=src python -m repro.launch.train --arch fedlm-100m --smoke \
      --rounds 20 --algorithm fedpa
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.algorithms import algorithm_names, get_algorithm, phase_name
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import FedConfig
from repro.core.async_engine import AsyncRoundEngine
from repro.core.client_state import jit_donating_store, make_client_store
from repro.core.server import init_server_state
from repro.core.sharded_round import make_fed_round, make_fed_round_split
from repro.data import SyntheticLMData
from repro.data.prefetch import Cohort
from repro.data.sampling import ClientSampler
from repro.models import init_params, lm_loss
from repro.optim import get_optimizer


def build_fed(args) -> FedConfig:
    """CLI flags -> the run's ``FedConfig``."""
    return FedConfig(
        algorithm=args.algorithm,
        clients_per_round=args.clients,
        local_steps=args.local_steps,
        burn_in_steps=args.burn_in_steps,
        steps_per_sample=args.steps_per_sample,
        shrinkage_rho=args.rho,
        server_opt=args.server_opt, server_lr=args.server_lr,
        client_opt=args.client_opt, client_lr=args.client_lr,
        burn_in_rounds=args.burn_in_rounds,
        async_rounds=args.async_rounds,
        max_staleness=args.max_staleness,
        staleness_discount=args.staleness_discount,
        prefetch_rounds=args.prefetch_rounds,
        client_state_placement=args.client_state_placement,
    )


def main():
    """Parse flags, build the round programs, drive the training loop."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedlm-100m",
                    choices=configs.ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--algorithm", default="fedpa",
                    choices=algorithm_names(),
                    help="registered federated algorithm "
                         f"(repro.algorithms): {', '.join(algorithm_names())}")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--num-clients", type=int, default=64,
                    help="population size")
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--burn-in-steps", type=int, default=4)
    ap.add_argument("--steps-per-sample", type=int, default=2)
    ap.add_argument("--burn-in-rounds", type=int, default=5)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--server-opt", default="sgdm")
    ap.add_argument("--server-lr", type=float, default=0.5)
    ap.add_argument("--client-opt", default="sgdm",
                    help="client optimizer (scaffold requires 'sgd')")
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--async-rounds", action="store_true",
                    help="double-buffered rounds: overlap cohort t+1's "
                         "client compute with round t's server update "
                         "(core/async_engine.py)")
    ap.add_argument("--max-staleness", type=int, default=1,
                    help="cohorts in flight beyond the one being applied; "
                         "0 matches the sync path numerically")
    ap.add_argument("--staleness-discount", type=float, default=0.9,
                    help="a staleness-s delta is scaled by discount**s")
    ap.add_argument("--prefetch-rounds", type=int, default=2,
                    help="cohort batches stacked ahead by a host thread "
                         "(0 = inline)")
    ap.add_argument("--client-state-placement", default="host",
                    choices=("host", "device"),
                    help="where stateful algorithms' per-client state "
                         "lives: host numpy store (one device sync per "
                         "stateful round at scatter time) or device "
                         "buffers threaded through the jitted round "
                         "(sync-free; pulled to host only at checkpoints)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log", default=None, help="JSONL metrics path")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    fed = build_fed(args)
    print(f"arch={cfg.name} params={configs.get_smoke(args.arch).param_count() if args.smoke else cfg.param_count():,} "
          f"algorithm={fed.algorithm} rounds={args.rounds}")

    data = SyntheticLMData(vocab_size=cfg.vocab_size,
                           num_clients=args.num_clients, seed=args.seed)
    sampler = ClientSampler(args.num_clients, args.clients, args.seed)
    s_text = args.seq_len - (cfg.frontend_tokens if cfg.frontend else 0)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    alg = get_algorithm(fed)
    state = init_server_state(params, server_opt, algorithm=alg)
    # stateful algorithms (scaffold/fedep): per-client persistent state,
    # checkpointed alongside the server state. A burn regime may differ in
    # statefulness from the main regime (fedep burns in as stateless
    # fedavg) — same rule as FedSim/AsyncRoundEngine.
    burn_stateful = (alg.burn_algorithm().stateful
                     if alg.has_burn_regime and fed.burn_in_rounds
                     else alg.stateful)
    device_store = fed.client_state_placement == "device"
    store = (make_client_store(fed.client_state_placement, args.num_clients)
             .ensure(alg.init_client_state(params))
             if alg.stateful or burn_stateful else None)

    def ckpt_tree(round_state):
        """Checkpoint pytree: bare server state, or {"server", "clients"}.

        ``store.state_dict()`` is the one place device-resident client
        state is pulled to the host."""
        if store is None:
            return round_state
        return {"server": round_state, "clients": store.state_dict()}

    start_round = 0
    if args.ckpt_dir and os.path.isdir(args.ckpt_dir):
        try:
            restored, start_round, _ = restore_checkpoint(args.ckpt_dir,
                                                          ckpt_tree(state))
            if store is None:
                state = restored
            else:
                state = restored["server"]
                store.load_state_dict(restored["clients"])
            print(f"restored checkpoint at round {start_round}")
        except FileNotFoundError:
            pass

    q_chunk = min(64, s_text)

    def jit_round(round_fn, stateful_regime):
        # device-stateful rounds take (state, batches, weights, store, ids)
        # — donate the store so its buffers update in place
        if device_store and stateful_regime:
            return jit_donating_store(round_fn, 3)
        return jax.jit(round_fn)

    round_sample = jit_round(make_fed_round(cfg, fed, placement="parallel",
                                            q_chunk=q_chunk), alg.stateful)
    round_burn = jit_round(make_fed_round(cfg, fed, placement="parallel",
                                          q_chunk=q_chunk,
                                          use_sampling=False), burn_stateful)

    def round_batches(r, ids):
        toks = data.round_batches(ids, fed.local_steps, args.batch, s_text,
                                  round_idx=r)
        batches = {"tokens": toks}
        if cfg.frontend:
            fe = np.stack([
                np.stack([
                    np.asarray(data.frontend_embeddings(
                        int(c), args.batch, cfg.frontend_tokens, cfg.d_model,
                        salt=r * 1000 + k))
                    for k in range(fed.local_steps)
                ]) for c in ids
            ])
            batches["frontend"] = jnp.asarray(fe, jnp.bfloat16)
        return batches

    # held-out eval batch from unseen client ids
    eval_batch = {
        "tokens": data.client_batches(args.num_clients + 1, 1, args.batch,
                                      s_text)[0]
    }
    if cfg.frontend:
        eval_batch["frontend"] = jnp.asarray(
            data.frontend_embeddings(args.num_clients + 1, args.batch,
                                     cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    eval_fn = jax.jit(lambda p: lm_loss(p, eval_batch, cfg,
                                        q_chunk=q_chunk)[0])

    logf = open(args.log, "a") if args.log else None

    def emit(rec):
        print(json.dumps(rec), flush=True)
        if logf:
            logf.write(json.dumps(rec) + "\n")
            logf.flush()

    def maybe_checkpoint(round_state, r):
        if args.ckpt_dir and ((r + 1) % args.ckpt_every == 0
                              or r == args.rounds - 1):
            save_checkpoint(args.ckpt_dir, ckpt_tree(round_state), r + 1,
                            {"arch": cfg.name, "algorithm": fed.algorithm})

    if fed.async_rounds:
        # double-buffered rounds: cohort t+1 is dispatched before round t's
        # server update lands; deltas discounted by staleness_discount**s
        cohort_fn, server_fn = make_fed_round_split(
            cfg, fed, placement="parallel", q_chunk=q_chunk)
        burn_cohort_fn = burn_server_fn = None
        if alg.has_burn_regime and fed.burn_in_rounds:
            burn_cohort_fn, burn_server_fn = make_fed_round_split(
                cfg, fed, placement="parallel", q_chunk=q_chunk,
                use_sampling=False)
        engine = AsyncRoundEngine(
            cohort_fn=cohort_fn,
            server_fn=server_fn,
            burn_cohort_fn=burn_cohort_fn,
            burn_server_fn=burn_server_fn,
            burn_in_rounds=max(0, fed.burn_in_rounds - start_round),
            max_staleness=fed.max_staleness,
            staleness_discount=fed.staleness_discount,
            prefetch_rounds=fed.prefetch_rounds,
            client_store=store,
            stateful=alg.stateful,
            burn_stateful=burn_stateful,
        )

        def build_cohort(i):
            r = start_round + i
            ids = sampler.sample(r)
            return Cohort(i, ids, round_batches(r, ids), None)

        last_t = time.time()

        def on_round(rec, round_state):
            # live per-round logging + periodic checkpoints, as in the sync
            # loop; forcing the metrics here costs one sync per round, but
            # the next cohorts are already dispatched on device
            nonlocal last_t
            r = start_round + rec["round"]
            emit({"round": r,
                  "eval_loss": (float(rec["eval"]["eval_loss"])
                                if "eval" in rec else None),
                  "client_loss_last": float(rec["metrics"]["loss_last"]),
                  "client_loss_first": float(rec["metrics"]["loss_first"]),
                  "staleness": rec["staleness"],
                  "phase": phase_name(fed, r),
                  "sec": round(time.time() - last_t, 2)})
            last_t = time.time()
            maybe_checkpoint(round_state, r)

        state, _ = engine.run(
            state, build_cohort, args.rounds - start_round,
            eval_fn=lambda p: {"eval_loss": float(eval_fn(p))},
            on_round=on_round)
    else:
        for r in range(start_round, args.rounds):
            t0 = time.time()
            is_burn = r < fed.burn_in_rounds
            fn = round_burn if is_burn else round_sample
            ids = sampler.sample(r)
            batches = round_batches(r, ids)
            stateful_round = (store is not None
                              and (burn_stateful if is_burn
                                   else alg.stateful))
            if stateful_round and device_store:
                state, metrics, new_ss = fn(state, batches, None,
                                            store.device_state(),
                                            store.prepare_ids(ids))
                store.set_device_state(new_ss)
            elif stateful_round:
                cstates, stamps = store.gather(ids)
                state, metrics, new_states = fn(state, batches, None,
                                                cstates)
                store.scatter(ids, new_states, stamps)
            else:
                state, metrics = fn(state, batches)
            ev = float(eval_fn(state.params))
            rec = {"round": r, "eval_loss": ev,
                   "client_loss_last": float(metrics["loss_last"]),
                   "client_loss_first": float(metrics["loss_first"]),
                   "phase": phase_name(fed, r),
                   "sec": round(time.time() - t0, 2)}
            emit(rec)
            maybe_checkpoint(state, r)
    if logf:
        logf.close()


if __name__ == "__main__":
    main()
