"""ShapeDtypeStruct input specs + shardings for every (arch x shape) combo.

``input_specs`` builds weak-type-correct, shardable stand-ins for every
model input — no device allocation — which is what the dry-run lowers.
The same functions produce the NamedShardings used as in/out_shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FedConfig, ModelConfig, ShapeConfig
from repro.core.client_state import PopulationLayout, population_layout
from repro.core.server import ServerState
from repro.models import abstract_decode_state, abstract_params
from repro.optim import get_optimizer
from repro.sharding import fsdp_shardings, param_shardings


def client_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The mesh axes that carry federated clients / batch."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _client_extent(mesh: Mesh) -> int:
    n = 1
    for a in client_axes(mesh):
        n *= mesh.shape[a]
    return n


def _model_extent(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _model_axis(mesh: Mesh, dim: int):
    """"model" when the mesh has it and ``dim`` shards evenly, else None."""
    me = _model_extent(mesh)
    return "model" if ("model" in mesh.axis_names and me > 1
                       and dim % me == 0) else None


# ---------------------------------------------------------------------------
# Train (the federated round)
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, fed: FedConfig,
                      mesh: Mesh, placement: str):
    """client_batches ShapeDtypeStructs: {"tokens": (C, K, B, S_text+1),
    ["frontend": (C, K, B, F, d)]} and their shardings."""
    if placement == "parallel":
        C = _client_extent(mesh)
        B_local = shape.global_batch // C
        if B_local == 0:
            raise ValueError(
                f"{shape.name}: global_batch {shape.global_batch} < client "
                f"extent {C} — parallel placement impossible"
            )
        lead_spec = P(client_axes(mesh))
    else:
        C = fed.clients_per_round
        B_local = shape.global_batch
        lead_spec = P()  # scan axis: not sharded
    s_text = shape.seq_len - (cfg.frontend_tokens if cfg.frontend else 0)
    K = fed.local_steps
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((C, K, B_local, s_text + 1), jnp.int32)
    }
    shardings: Dict[str, Any] = {
        "tokens": NamedSharding(mesh, P(*lead_spec, None, None, None))
        if placement == "parallel"
        else NamedSharding(mesh, P(None, None, client_axes(mesh), None)),
    }
    if cfg.frontend:
        F = cfg.frontend_tokens
        specs["frontend"] = jax.ShapeDtypeStruct(
            (C, K, B_local, F, cfg.d_model), jnp.bfloat16
        )
        ma = _model_axis(mesh, cfg.d_model)
        shardings["frontend"] = NamedSharding(
            mesh,
            P(*lead_spec, None, None, None, ma)
            if placement == "parallel"
            else P(None, None, client_axes(mesh), None, ma),
        )
    if fed.min_local_steps:
        # heterogeneous step budgets ride as a (C, K) 0/1 leaf the engine's
        # grad wrapper strips (data/cohort_source.py injects it)
        specs["_active"] = jax.ShapeDtypeStruct((C, K), jnp.float32)
        shardings["_active"] = NamedSharding(
            mesh, P(*lead_spec, None) if placement == "parallel"
            else P(None, None))
    return specs, shardings


def server_state_specs(cfg: ModelConfig, fed: FedConfig, mesh: Mesh,
                       placement: str, param_dtype=jnp.float32):
    """Abstract ServerState + shardings (tp for parallel, FSDP for seq).

    Includes the algorithm's persistent ``algo_state`` slot (SCAFFOLD's
    server control variate); its parameter-shaped leaves reuse the param
    sharding, everything else stays replicated.
    """
    from repro.algorithms import get_algorithm  # noqa: PLC0415 — cycle

    alg = get_algorithm(fed)
    params = abstract_params(cfg, param_dtype)
    server_opt = get_optimizer(fed.server_opt, fed.server_lr,
                               fed.server_momentum)
    state = jax.eval_shape(
        lambda p: ServerState(p, server_opt.init(p), jnp.zeros((), jnp.int32),
                              alg.init_algo_state(p)),
        params,
    )
    shard_fn = param_shardings if placement == "parallel" else fsdp_shardings
    p_sh = shard_fn(params, mesh)
    # optimizer moments are parameter-shaped: reuse the param sharding by
    # shape (scalars like step counters stay replicated)
    flat_params = {s.shape: sh for s, sh in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p_sh))}

    def match(leaf):
        return flat_params.get(leaf.shape, NamedSharding(mesh, P()))

    opt_sh = jax.tree_util.tree_map(match, state.opt_state)
    algo_sh = jax.tree_util.tree_map(match, state.algo_state)
    state_sh = ServerState(p_sh, opt_sh, NamedSharding(mesh, P()), algo_sh)
    return state, state_sh


def client_state_specs(cfg: ModelConfig, fed: FedConfig, mesh: Mesh,
                      placement: str, param_dtype=jnp.float32):
    """Abstract gathered cohort client-state slice + shardings.

    ``(None, None)`` for stateless algorithms. The leading cohort axis
    shards over the client axes under the parallel placement (one client
    per data slice, like the batches) and stays unsharded for the
    sequential scan.
    """
    from repro.algorithms import get_algorithm  # noqa: PLC0415 — cycle

    alg = get_algorithm(fed)
    if not alg.stateful:
        return None, None
    params = abstract_params(cfg, param_dtype)
    one = jax.eval_shape(alg.init_client_state, params)
    if placement == "parallel":
        C = _client_extent(mesh)
        lead = P(client_axes(mesh))
    else:
        C = fed.clients_per_round
        lead = P()
    specs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((C,) + tuple(x.shape), x.dtype), one)
    shardings = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P(*lead, *(None,) * len(x.shape))), one)
    return specs, shardings


def store_population_layout(mesh: Mesh, num_clients: int) -> PopulationLayout:
    """THE population layout of a device store on ``mesh``.

    The single source of truth consulted by ``device_store_specs``, the
    launch entry points (train/dryrun), and anything else that must agree
    with the store's on-device shapes: the leading ``N`` axis shards over
    the mesh's client axes (``client_axes``) and is padded up to the next
    multiple of their extent — never silently replicated. The padding rows
    are dead (masked ``-1`` stamps, unreachable ids).
    """
    return population_layout(mesh, num_clients)


def device_store_specs(cfg: ModelConfig, fed: FedConfig, mesh: Mesh,
                       placement: str, num_clients: int = 64,
                       param_dtype=jnp.float32):
    """Abstract device-resident client-state store + cohort-id specs.

    The ``client_state_placement="device"`` round signature appends
    ``(store_state, client_ids)``: the full population's dense
    ``{"buffers": (N_padded, ...), "stamps": (N_padded,)}`` store
    (``DeviceClientStateStore.device_state()``) and the traced ``(C,)``
    cohort id vector. Returns ``(store_spec, store_sharding, ids_spec,
    ids_sharding)``; ``(None,) * 4`` for stateless algorithms. The leading
    population axis follows :func:`store_population_layout`: sharded over
    the client axes with ``num_clients`` padded up to the next multiple of
    their extent (a non-divisible population used to fall back to full
    replication, silently); the in-program gather reshards the cohort
    slice, and ids are replicated.
    """
    from repro.algorithms import get_algorithm  # noqa: PLC0415 — cycle

    alg = get_algorithm(fed)
    if not alg.stateful:
        return None, None, None, None
    params = abstract_params(cfg, param_dtype)
    one = jax.eval_shape(alg.init_client_state, params)
    layout = store_population_layout(mesh, num_clients)
    n, lead = layout.padded_num_clients, layout.spec
    store_spec = {
        "buffers": jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct((n,) + tuple(x.shape),
                                           x.dtype), one),
        "stamps": jax.ShapeDtypeStruct((n,), jnp.int32),
    }
    store_sh = {
        "buffers": jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh,
                                    P(*lead, *(None,) * len(x.shape))), one),
        "stamps": NamedSharding(mesh, P(*lead)),
    }
    C = (_client_extent(mesh) if placement == "parallel"
         else fed.clients_per_round)
    ids_spec = jax.ShapeDtypeStruct((C,), jnp.int32)
    ids_sh = NamedSharding(mesh, P())
    return store_spec, store_sh, ids_spec, ids_sh


# ---------------------------------------------------------------------------
# Inference (prefill / decode)
# ---------------------------------------------------------------------------

def _greedy_sharding(leaf, mesh: Mesh) -> NamedSharding:
    """Assign ("pod","data") to the first divisible dim, then "model" to the
    last divisible remaining dim — memory-first layout for decode caches."""
    caxes = client_axes(mesh)
    ce = _client_extent(mesh)
    me = _model_extent(mesh)
    spec: list = [None] * leaf.ndim
    if leaf.ndim == 0 or leaf.size < 1024:
        return NamedSharding(mesh, P(*spec))
    for i, dim in enumerate(leaf.shape):
        if dim % ce == 0 and dim >= ce:
            spec[i] = caxes if len(caxes) > 1 else caxes[0]
            break
    if "model" in mesh.axis_names:
        for i in range(leaf.ndim - 1, -1, -1):
            if spec[i] is None and leaf.shape[i] % me == 0 and leaf.shape[i] >= me:
                spec[i] = "model"
                break
    return NamedSharding(mesh, P(*spec))


def _kv_cache_sharding(leaf, mesh: Mesh, mode: str) -> NamedSharding:
    """Sharding for AttnCache k/v leaves (B, L, KV, dh).

    ``greedy`` (baseline): model axis on the last divisible dim — usually
    head_dim. The dh-sharded contraction makes GSPMD all-gather the whole
    cache per layer (observed: 219 GB/device/step on qwen3-32b decode_32k).

    ``flash`` (optimized, §Perf): KV heads over model when divisible (fully
    independent heads — zero attention collectives); otherwise the sequence
    dim L over model — flash-decode parallelism where each shard computes
    partial scores/softmax stats and only tiny (B, KV, G) reductions cross
    chips.
    """
    if mode == "greedy":
        return _greedy_sharding(leaf, mesh)
    B, L, KV, dh = leaf.shape
    caxes = client_axes(mesh)
    ce = _client_extent(mesh)
    me = _model_extent(mesh)
    spec = [None, None, None, None]
    if B % ce == 0 and B >= ce:
        spec[0] = caxes if len(caxes) > 1 else caxes[0]
    if me > 1:
        if KV % me == 0 and KV >= me:
            spec[2] = "model"
        elif L % me == 0 and L >= me:
            spec[1] = "model"
        elif dh % me == 0 and dh >= me:
            spec[3] = "model"
    return NamedSharding(mesh, P(*spec))


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       cache_dtype=jnp.bfloat16, headroom: int = 0,
                       cache_shard: str = "greedy"):
    """Abstract decode state (KV caches, positions) + shardings."""
    B = shape.global_batch
    max_len = shape.seq_len + headroom
    state = abstract_decode_state(cfg, B, max_len, cache_dtype)

    def one(path, leaf):
        names = jax.tree_util.keystr(path)
        if leaf.ndim == 4 and (names.endswith(".k") or names.endswith(".v")):
            return _kv_cache_sharding(leaf, mesh, cache_shard)
        if leaf.ndim == 5 and (names.endswith(".k") or names.endswith(".v")):
            # stacked over repeats: same rule on the trailing 4 dims
            inner = _kv_cache_sharding(
                jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype), mesh,
                cache_shard)
            return NamedSharding(mesh, P(None, *inner.spec))
        return _greedy_sharding(leaf, mesh)

    shardings = jax.tree_util.tree_map_with_path(one, state)
    return state, shardings


def token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """One decode step's token-id batch spec + sharding."""
    B = shape.global_batch
    spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    ce = _client_extent(mesh)
    sh = NamedSharding(
        mesh, P(client_axes(mesh) if B % ce == 0 and B >= ce else None)
    )
    return spec, sh


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Prefill inputs (token batch, optional frontend) + shardings."""
    B = shape.global_batch
    s_text = shape.seq_len - (cfg.frontend_tokens if cfg.frontend else 0)
    ce = _client_extent(mesh)
    bspec = client_axes(mesh) if B % ce == 0 and B >= ce else None
    specs = {"tokens": jax.ShapeDtypeStruct((B, s_text), jnp.int32)}
    shardings = {"tokens": NamedSharding(mesh, P(bspec, None))}
    if cfg.frontend:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
        shardings["frontend"] = NamedSharding(
            mesh, P(bspec, None, _model_axis(mesh, cfg.d_model)))
    return specs, shardings


# ---------------------------------------------------------------------------
# The deliverable-facing aggregate
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, fed: FedConfig,
                mesh: Mesh, placement: Optional[str] = None,
                cache_shard: str = "greedy", num_clients: int = 64):
    """Every input the lowered step needs, as ShapeDtypeStructs, plus
    matching shardings: {"args": (...), "shardings": (...)} keyed by kind.
    ``num_clients`` sizes the device-resident client-state store's
    population axis for ``fed.client_state_placement="device"`` rounds.

    Train records also carry an explicit ``"stateful"`` flag ("device",
    "host", or None) — consumers key output shardings off it, never off
    positional arity (a fault-injecting config appends a (C,) survivor
    mask as the trailing round argument, so arity alone is ambiguous).
    """
    from repro.core.sharded_round import default_placement  # late: cycle-free

    placement = placement or default_placement(cfg)
    if shape.kind == "train":
        state, state_sh = server_state_specs(cfg, fed, mesh, placement)
        batches, batch_sh = train_batch_specs(cfg, shape, fed, mesh, placement)
        mask_args, mask_sh = (), ()
        if fed.fault_injection:
            # the (C,) survivor mask: O(C) scalars, replicated
            C = (_client_extent(mesh) if placement == "parallel"
                 else fed.clients_per_round)
            mask_args = (jax.ShapeDtypeStruct((C,), jnp.float32),)
            mask_sh = (NamedSharding(mesh, P()),)
        if fed.client_state_placement == "device":
            store, store_sh, ids, ids_sh = device_store_specs(
                cfg, fed, mesh, placement, num_clients)
            if store is not None:
                # device-stateful round:
                # fn(state, batches, weights=None, store_state, client_ids
                #    [, survivor_mask]) -> (state, losses, new_store_state)
                return {"kind": "train", "placement": placement,
                        "stateful": "device",
                        "args": (state, batches, None, store, ids)
                        + mask_args,
                        "shardings": (state_sh, batch_sh, None, store_sh,
                                      ids_sh) + mask_sh}
        cstates, cstate_sh = client_state_specs(cfg, fed, mesh, placement)
        if cstates is not None:
            # stateful round: fn(state, batches, weights=None, client_states
            #                    [, survivor_mask])
            return {"kind": "train", "placement": placement,
                    "stateful": "host",
                    "args": (state, batches, None, cstates) + mask_args,
                    "shardings": (state_sh, batch_sh, None, cstate_sh)
                    + mask_sh}
        if mask_args:
            return {"kind": "train", "placement": placement, "stateful": None,
                    "args": (state, batches, None) + mask_args,
                    "shardings": (state_sh, batch_sh, None) + mask_sh}
        return {"kind": "train", "placement": placement, "stateful": None,
                "args": (state, batches), "shardings": (state_sh, batch_sh)}
    params = abstract_params(cfg, jnp.bfloat16)
    params_sh = param_shardings(params, mesh)
    if shape.kind == "prefill":
        toks, toks_sh = prefill_specs(cfg, shape, mesh)
        return {"kind": "prefill", "args": (params, toks),
                "shardings": (params_sh, toks_sh)}
    tok, tok_sh = token_specs(cfg, shape, mesh)
    state, state_sh = decode_state_specs(cfg, shape, mesh,
                                         cache_shard=cache_shard)
    # the decode state arrives mid-stream: pos = seq_len - 1 tokens consumed
    return {"kind": "decode", "args": (params, tok, state),
            "shardings": (params_sh, tok_sh, state_sh)}
