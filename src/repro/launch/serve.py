"""Batched serving driver: prefill a batch of synthetic requests, then
decode tokens with the cached state — the decode_32k/long_500k code path at
CPU-friendly scale.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --smoke \
      --batch 4 --prompt-len 96 --gen 32 [--pallas]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import SyntheticLMData
from repro.models import init_params, prefill_step, serve_step


def main():
    """CLI: prefill a synthetic batch, then decode ``--gen`` tokens."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b", choices=configs.ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--pallas", action="store_true",
                    help="route decode attention through the Pallas "
                         "swa_decode kernel (interpret mode on CPU)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    data = SyntheticLMData(vocab_size=cfg.vocab_size, num_clients=args.batch,
                           seed=args.seed)

    s_text = args.prompt_len - (cfg.frontend_tokens if cfg.frontend else 0)
    prompts = jnp.stack([
        data.client_batches(i, 1, 1, s_text - 1)[0, 0] for i in range(args.batch)
    ])                                   # (B, s_text)
    frontend = None
    if cfg.frontend:
        frontend = jnp.stack([
            data.frontend_embeddings(i, 1, cfg.frontend_tokens,
                                     cfg.d_model)[0]
            for i in range(args.batch)
        ]).astype(jnp.bfloat16)

    max_len = args.prompt_len + args.gen
    q_chunk = min(32, s_text)
    t0 = time.time()
    pf = jax.jit(lambda p, t: prefill_step(p, t, cfg, max_len,
                                           frontend=frontend,
                                           q_chunk=q_chunk))
    logits, state = pf(params, prompts)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    step = jax.jit(lambda p, t, s, r: serve_step(
        p, t, s, cfg, sample=args.sample, rng=r,
        temperature=args.temperature, use_pallas=args.pallas))
    out = [np.asarray(tok)]
    rng = jax.random.PRNGKey(args.seed + 1)
    t1 = time.time()
    for i in range(args.gen - 1):
        rng, sub = jax.random.split(rng)
        tok, logits, state = step(params, tok, state, sub)
        out.append(np.asarray(tok))
    dt = time.time() - t1
    gen = np.stack(out, axis=1)
    print(f"decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"request {b}: {gen[b].tolist()}")
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN logits"
    print("ok")


if __name__ == "__main__":
    main()
