"""Re-derive roofline reports from saved compiled-HLO dumps — the §Perf
iteration loop's fast path: analyzer changes re-parse in seconds instead of
recompiling the 80-combo sweep.

  PYTHONPATH=src python -m repro.launch.reanalyze hlo_dumps/ --out rooflines.jsonl
"""
from __future__ import annotations

import argparse
import gzip
import json
import os

from repro import configs
from repro.configs.base import SHAPES
from repro.core.sharded_round import default_placement
from repro.launch.dryrun import default_fed_config
from repro.sharding.hlo_cost import analyze
from repro.sharding.roofline import derive, format_table


def reanalyze_file(path: str) -> dict:
    """Re-derive one roofline record from a saved ``*.hlo.gz`` dump
    (arch/shape/mesh parsed back out of the dump's file name)."""
    base = os.path.basename(path).replace(".hlo.gz", "")
    parts = base.split("__")
    arch, shape_name, mesh_name = parts[:3]
    variant = "__".join(parts[3:])
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    chips = 512 if mesh_name == "2x16x16" else 256
    with gzip.open(path, "rt") as f:
        hlo = f.read()
    res = analyze(hlo)
    fed = default_fed_config()
    eff_steps = 1
    if shape.kind == "train":
        eff_steps = fed.local_steps
        if default_placement(cfg) == "sequential":
            eff_steps *= fed.clients_per_round
    rep = derive(arch, shape, cfg, mesh_name, chips,
                 {"flops": res["flops"], "bytes accessed": res["bytes"]},
                 res["collectives"], local_steps=eff_steps)
    rec = rep.as_row()
    rec["hlo_file"] = path
    if variant:
        rec["variant"] = variant
    return rec


def main():
    """CLI: re-analyze every dump in a directory, print the table."""
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo_dir")
    ap.add_argument("--out", default=None)
    ap.add_argument("--variants", action="store_true",
                    help="include §Perf variant dumps, not just baselines")
    args = ap.parse_args()
    rows = []
    for fn in sorted(os.listdir(args.hlo_dir)):
        if not fn.endswith(".hlo.gz"):
            continue
        if not args.variants and len(fn.replace(".hlo.gz", "").split("__")) > 3:
            continue
        rec = reanalyze_file(os.path.join(args.hlo_dir, fn))
        rows.append(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(format_table(rows))


if __name__ == "__main__":
    main()
