"""Roofline term derivation from the compiled dry-run artifact.

Per (arch x shape x mesh):

    compute    = HLO_FLOPs      / (chips x 197 TFLOP/s bf16)
    memory     = HLO_bytes      / (chips x 819 GB/s HBM)
    collective = collective_bytes / (chips x 50 GB/s ICI per link)

plus MODEL_FLOPS = 6 * N_active * D (the "useful" compute) and the
MODEL/HLO ratio that exposes remat/dispatch overhead. This container is
CPU-only — v5e-class hardware constants are the TARGET, so these terms are
*derived*, not measured; EXPERIMENTS.md §Roofline reports them and §Perf
iterates the dominant one down.

Note on cost_analysis semantics: with SPMD partitioning XLA reports the
per-partition (per-device) module's flops/bytes. We therefore divide by
chips ONLY when normalizing analytic MODEL_FLOPS; the HLO terms use the
per-device numbers directly. This is asserted empirically in
tests/test_roofline.py by checking HLO_FLOPs against 6ND within a small
factor on a dense arch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class Hardware:
    """Peak per-chip numbers the roofline terms divide by."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link (~per chip per axis)


V5E = Hardware()


@dataclass
class RooflineReport:
    """Per-(arch, shape, mesh) roofline breakdown and derived time terms."""

    arch: str
    shape: str
    mesh: str
    chips: int
    # raw per-device quantities from the compiled module
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    # derived terms (seconds)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    tokens: int = 0
    collectives: Dict[str, dict] = field(default_factory=dict)
    notes: str = ""

    def as_row(self) -> dict:
        """Flatten to a plain dict for tables/JSON."""
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "notes": self.notes,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig,
                local_steps: int = 1, num_samples: int = 0) -> float:
    """6 * N_active * D analytic compute for the step the dry-run lowers.

    Training: 6ND per local step x local_steps (fwd 2ND + bwd 4ND).
    Prefill: 2ND. Decode: 2N per token x batch (D = batch tokens).
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens * max(local_steps, 1)
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per request
    return 2.0 * n * shape.global_batch


def step_tokens(shape: ShapeConfig, local_steps: int = 1) -> int:
    """Tokens processed by one step of this shape (decode: one per row)."""
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len * max(local_steps, 1)
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch


def derive(arch: str, shape_cfg: ShapeConfig, cfg: ModelConfig, mesh_name: str,
           chips: int, cost: dict, collectives: dict,
           local_steps: int = 1, hw: Hardware = V5E,
           per_device: bool = True, notes: str = "") -> RooflineReport:
    """Build the three-term report from cost_analysis + parsed collectives."""
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    coll = float(collectives.get("total_bytes", 0))
    if not per_device:  # numbers are whole-program: normalize
        flops /= chips
        bts /= chips
        coll /= chips
    mf = model_flops(cfg, shape_cfg, local_steps)
    rep = RooflineReport(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bts, collective_bytes=coll,
        compute_s=flops / hw.peak_flops,
        memory_s=bts / hw.hbm_bw,
        collective_s=coll / hw.ici_bw,
        model_flops=mf,
        tokens=step_tokens(shape_cfg, local_steps),
        collectives={k: v for k, v in collectives.items()
                     if isinstance(v, dict)},
        notes=notes,
    )
    terms = {"compute": rep.compute_s, "memory": rep.memory_s,
             "collective": rep.collective_s}
    rep.dominant = max(terms, key=terms.get)
    # useful_ratio compares per-chip shares of the analytic model flops
    rep.useful_ratio = (mf / chips) / flops if flops else 0.0
    return rep


def format_table(reports, keys=("arch", "shape", "mesh", "compute_s",
                                "memory_s", "collective_s", "dominant",
                                "useful_ratio")) -> str:
    """Render reports as an aligned fixed-width text table."""
    rows = [r.as_row() if isinstance(r, RooflineReport) else r
            for r in reports]
    widths = {k: max(len(k), *(len(_fmt(row.get(k))) for row in rows))
              for k in keys}
    line = " | ".join(k.ljust(widths[k]) for k in keys)
    sep = "-|-".join("-" * widths[k] for k in keys)
    body = "\n".join(
        " | ".join(_fmt(row.get(k)).ljust(widths[k]) for k in keys)
        for row in rows
    )
    return f"{line}\n{sep}\n{body}"


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.3e}" if (abs(v) < 1e-3 or abs(v) >= 1e4) and v else f"{v:.4f}"
    return str(v)
