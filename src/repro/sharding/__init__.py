"""Mesh axis rules, HLO cost analysis, and roofline estimates."""
from repro.sharding.rules import (  # noqa: F401
    axis_rules,
    constrain,
    current_mesh,
    fsdp_constrain,
    fsdp_shardings,
    logical_spec,
    make_mesh_compat,
    param_shardings,
    tp_constrain,
)
