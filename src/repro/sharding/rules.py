"""Logical-axis sharding rules.

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", None, "tp")``); a context-installed rule table maps
logical names to mesh axes, filtered to the axes the active mesh actually
has. With no active mesh every annotation is a no-op, so the same model code
runs in single-device tests and in the 512-chip dry-run unchanged.

Default mapping (DESIGN.md §3):
  clients/batch -> ("pod", "data")   federated clients = data parallelism
  tp            -> "model"           tensor parallel: heads / ffn / vocab / experts
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES = {
    "clients": ("pod", "data"),
    "batch": ("pod", "data"),
    "tp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
}

_STATE = threading.local()


def make_mesh_compat(axis_shapes, axis_names, *, devices=None,
                     explicit=False) -> Mesh:
    """``jax.make_mesh`` across jax versions.

    jax >= 0.5 grew an ``axis_types`` kwarg (``jax.sharding.AxisType``);
    0.4.x has neither the kwarg nor the enum. Tests and launch scripts call
    this instead of ``jax.make_mesh`` so both lines work. ``explicit=True``
    requests AxisType.Explicit axes where supported (Auto otherwise).
    """
    kw = {} if devices is None else {"devices": devices}
    try:
        from jax.sharding import AxisType  # noqa: PLC0415
    except ImportError:  # jax 0.4.x: auto axes are the only behavior
        return jax.make_mesh(axis_shapes, axis_names, **kw)
    kind = AxisType.Explicit if explicit else AxisType.Auto
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=tuple(kind for _ in axis_names), **kw)


def current_mesh() -> Optional[Mesh]:
    """The mesh installed by the innermost axis_rules (None outside one)."""
    return getattr(_STATE, "mesh", None)


def current_rules() -> dict:
    """The logical-axis rule map currently in effect."""
    return getattr(_STATE, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[dict] = None):
    """Install ``mesh`` (+ optional rule overrides) for model annotations."""
    prev_mesh, prev_rules = current_mesh(), current_rules()
    _STATE.mesh = mesh
    _STATE.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        yield
    finally:
        _STATE.mesh = prev_mesh
        _STATE.rules = prev_rules


def _resolve(name: Optional[str], mesh: Mesh, rules: dict):
    if name is None:
        return None
    axes = tuple(a for a in rules.get(name, ()) if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def logical_spec(names: Tuple[Optional[str], ...], mesh: Optional[Mesh] = None,
                 rules: Optional[dict] = None) -> P:
    """Map logical axis names to a PartitionSpec under the current rules."""
    mesh = mesh or current_mesh()
    rules = rules or current_rules()
    if mesh is None:
        return P()
    return P(*(_resolve(n, mesh, rules) for n in names))


def constrain(x, *names):
    """with_sharding_constraint against the active mesh (no-op without one)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_spec(names, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter shardings (megatron-style tensor parallelism by param name)
# ---------------------------------------------------------------------------

# (regex on the param keypath, logical axes for the *trailing* dims).
# Stacked pattern params carry extra leading axes (repeats) that are padded
# with None automatically, so one rule covers both pattern and tail layers.
_PARAM_RULES = (
    (r"embed$", ("vocab", None)),
    (r"unembed$", (None, "vocab")),
    # attention
    (r"(wq|wk|wv)$", (None, "tp")),
    (r"wo$", ("tp", None)),
    # MoE expert banks (E, d, f) / (E, f, d): expert-parallel over tp
    (r"moe.*w_(gate|up)$", ("experts", None, None)),
    (r"moe.*w_down$", ("experts", None, None)),
    (r"router$", (None, "tp")),
    (r"ws_(gate|up)$", (None, "tp")),
    (r"ws_down$", ("tp", None)),
    # dense ffn / xlstm / rglru projections
    (r"w_(gate|up|mlp_up)$", (None, "tp")),
    (r"(w_down|w_mlp_down)$", ("tp", None)),
    (r"conv$", (None, "tp")),
    (r"w_gates$", (None, None)),
    (r"\bw$", (None, "tp")),        # slstm input gates (d, 4d)
    (r"\br$", ("tp", None, None)),  # slstm recurrent blocks (H, dh, 4dh)
    (r"rg_(a|x)$", (None, None, None)),
)


def _normalize_path(path_str: str) -> str:
    """keystr emits "['pattern']['pos_0']['ffn']['moe']['w_gate']" — turn it
    into "pattern.pos_0.ffn.moe.w_gate" so $-anchored rules match."""
    return re.sub(r"[\[\]'\"]+", ".", path_str).strip(".")


def _axis_extent(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _sanitize(spec: P, shape, mesh: Mesh) -> P:
    """Drop axis assignments whose dimension isn't divisible by the axis
    extent (e.g. 4 sLSTM heads can't shard over model=16 — replicate)."""
    out = []
    for dim, s in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        e = _axis_extent(mesh, s)
        out.append(s if (e > 1 and dim % e == 0 and dim >= e) else None)
    return P(*out)


def _spec_for_path(path_str: str, ndim: int, mesh: Mesh, rules: dict,
                   shape=None) -> P:
    path_str = _normalize_path(path_str)
    for pattern, logical in _PARAM_RULES:
        if re.search(pattern, path_str):
            if len(logical) > ndim:
                logical = logical[-ndim:]
            pad = (None,) * (ndim - len(logical))
            spec = logical_spec(pad + tuple(logical), mesh, rules)
            return _sanitize(spec, shape, mesh) if shape is not None else spec
    return P()  # replicate anything unmatched (norms, biases, scalars)


def fsdp_shardings(params, mesh: Mesh, rules: Optional[dict] = None):
    """ZeRO/FSDP-style shardings: the tensor-parallel spec plus the first
    still-replicated, divisible dimension sharded over the client axes.

    This is what lets FedPA's O(l d) per-client state (posterior samples, DP
    history vectors, optimizer moments, fp32 masters) fit in HBM for the
    >=10B architectures under ``client_placement="sequential"``: every
    parameter-shaped vector shards over data x model = the full pod instead
    of model only. Leaves too small (or not divisible) stay replicated.
    """
    rules = {**DEFAULT_RULES, **(rules or {})}
    client_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    extent = 1
    for a in client_axes:
        extent *= mesh.shape[a]

    def one(path, leaf):
        path_str = jax.tree_util.keystr(path)
        spec = list(_spec_for_path(path_str, leaf.ndim, mesh, rules,
                                   shape=leaf.shape))
        spec += [None] * (leaf.ndim - len(spec))
        for i, (dim, s) in enumerate(zip(leaf.shape, spec)):
            if s is None and dim % extent == 0 and dim >= extent:
                spec[i] = client_axes if len(client_axes) > 1 else client_axes[0]
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params)


def fsdp_constrain(tree, like_params=None):
    """with_sharding_constraint a parameter-shaped pytree to FSDP shardings
    against the active mesh (no-op without one). ``like_params`` gives the
    path structure when ``tree`` is shaped like the params."""
    mesh = current_mesh()
    if mesh is None:
        return tree
    shardings = fsdp_shardings(like_params if like_params is not None else tree,
                               mesh, current_rules())
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, shardings
    )


def tp_constrain(tree):
    """Constrain a parameter pytree to the pure tensor-parallel shardings
    (replicated over client axes) — forces the FSDP all-gather boundary."""
    mesh = current_mesh()
    if mesh is None:
        return tree
    shardings = param_shardings(tree, mesh, current_rules())
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, shardings
    )


def param_shardings(params, mesh: Mesh, rules: Optional[dict] = None,
                    extra_leading: Tuple[Optional[str], ...] = ()):
    """NamedSharding pytree for a parameter pytree.

    ``extra_leading``: logical names for extra leading axes every leaf
    carries (e.g. ("clients",) for the per-client param copies inside a
    federated round).
    """
    rules = {**DEFAULT_RULES, **(rules or {})}
    lead = tuple(_resolve(n, mesh, rules) for n in extra_leading)

    def one(path, leaf):
        path_str = jax.tree_util.keystr(path)
        nd = leaf.ndim - len(lead)
        spec = _spec_for_path(path_str, nd, mesh, rules,
                              shape=leaf.shape[len(lead):])
        full = P(*(lead + tuple(spec)))
        return NamedSharding(mesh, full)

    return jax.tree_util.tree_map_with_path(one, params)
