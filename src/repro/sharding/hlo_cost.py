"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation exactly ONCE —
``while`` bodies are NOT multiplied by their trip counts (verified
empirically in EXPERIMENTS.md §Roofline/Methodology: a 7-iteration scanned
matmul reports 1x the matmul flops). Since the whole framework leans on
``lax.scan`` (over layers, local steps, clients, MoE chunks) precisely to
keep compile time depth-independent, the built-in numbers undercount by
orders of magnitude.

This module re-derives per-device cost from the compiled module text:

  1. parse computations and their instructions;
  2. build an execution-multiplier per computation by propagating
     ``while`` trip counts (recovered from counter-vs-constant conditions,
     the lax.scan pattern) and fusion/call/reduce edges through the call
     graph — nested loops multiply;
  3. FLOPs: 2 * numel(result) * contracted_size for every ``dot`` (+
     convolution treated via output x kernel numel), scaled by multiplier;
  4. bytes: sum of (result + operand) buffer bytes per materializing
     instruction, scaled — the post-fusion instruction granularity is a
     good proxy for HBM traffic;
  5. collective bytes per kind, with the same multipliers (superseding the
     single-level scaling in ``collectives.py``).

All numbers are per device: the post-partitioning module is the per-device
program.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, NamedTuple, Optional, Tuple


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions: 0.4.x returns a
    one-element list of per-device dicts, newer jax returns the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1, "f4e2m1fn": 1,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# header params may contain nested parens (tuple-typed params), so match
# loosely up to the arrow
_COMP_HDR = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
# tuple shapes may contain /*index=N*/ comments — match to the closing paren
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"([\w\-]+)\((.*)$")
_ARRAY = re.compile(r"(\w+)\[([\d,]*)\]")


class Instr(NamedTuple):
    """One parsed HLO instruction: name, result shape, opcode, operand text."""

    name: str
    shape: str
    op: str
    rest: str


def _shape_numel_bytes(shape_str: str) -> Tuple[int, int]:
    numel = 0
    total = 0
    for dtype, dims in _ARRAY.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel += n
        total += n * _DTYPE_BYTES[dtype]
    return numel, total


def parse_module(hlo: str) -> Dict[str, List[Instr]]:
    """Split HLO text into computations, each a list of parsed Instrs."""
    comps: Dict[str, List[Instr]] = {}
    name: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            name = m.group(1)
            comps[name] = []
            if line.lstrip().startswith("ENTRY"):
                entry = name
            continue
        if name is None:
            continue
        mi = _INSTR.match(line)
        if mi:
            comps[name].append(Instr(*mi.groups()))
    comps["__entry__"] = comps.get(entry, [])
    if entry:
        comps["__entry_name__"] = entry  # type: ignore[assignment]
    return comps


def _trip_count(cond_instrs: List[Instr]) -> int:
    """lax.scan conditions compare the counter against a constant."""
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant" and ins.shape.startswith(("s32[]", "s64[]",
                                                          "u32[]", "u64[]")):
            m = re.match(r"(\d+)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _callees(ins: Instr) -> List[str]:
    """Computations this instruction invokes (fusion/call/while/etc.)."""
    out = []
    for key in ("calls=", "to_apply=", "condition=", "body=", "branch_computations="):
        for m in re.finditer(re.escape(key) + r"\{?%?([\w.\-,% {}]+)", ins.rest):
            blob = m.group(1)
            for nm in re.split(r"[,\s{}%]+", blob):
                if nm:
                    out.append(nm)
            break
    return out


def fusion_called(comps: Dict[str, List[Instr]]) -> set:
    """Computations inlined into fusions / reducers: their internal
    intermediates live in registers/VMEM, not HBM — flops count, bytes
    don't."""
    out = set()
    for cname, instrs in comps.items():
        if cname.startswith("__"):
            continue
        for ins in instrs:
            if ins.op in ("fusion", "reduce", "reduce-window", "map", "sort",
                          "scatter", "select-and-scatter", "all-reduce",
                          "reduce-scatter", "custom-call"):
                out.update(_callees(ins))
    return out


def multipliers(comps: Dict[str, List[Instr]]) -> Dict[str, float]:
    """Execution count per computation (entry = 1; while bodies x trips)."""
    entry = comps.get("__entry_name__")
    mult: Dict[str, float] = defaultdict(float)
    if not entry:
        return mult
    mult[entry] = 1.0
    # topological-ish fixpoint (call graph is a DAG; few iterations suffice)
    for _ in range(50):
        new = defaultdict(float)
        new[entry] = 1.0
        for cname, instrs in comps.items():
            if cname.startswith("__"):
                continue
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in instrs:
                if ins.op == "while":
                    mcond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                    mbody = re.search(r"body=%?([\w.\-]+)", ins.rest)
                    if mcond and mbody:
                        trips = _trip_count(comps.get(mcond.group(1), []))
                        new[mbody.group(1)] += m * trips
                        new[mcond.group(1)] += m * (trips + 1)
                elif ins.op in ("fusion", "call", "conditional", "map",
                                "reduce", "reduce-window", "sort", "scatter",
                                "select-and-scatter", "all-reduce",
                                "reduce-scatter", "custom-call"):
                    for callee in _callees(ins):
                        if callee in comps:
                            new[callee] += m
        if dict(new) == dict(mult):
            break
        mult = new
    return mult


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_numel, _ = _shape_numel_bytes(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    operands = re.findall(r"%([\w.\-]+)", ins.rest.split(",  ")[0])
    contracted = 1
    if m and operands:
        lhs_shape = shapes.get(operands[0], "")
        arr = _ARRAY.search(lhs_shape)
        if arr:
            dims = [int(x) for x in arr.group(2).split(",") if x]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contracted *= dims[int(ci)]
    return 2.0 * out_numel * contracted


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota", "while", "conditional", "call"}


def _operand_names(ins: Instr) -> List[str]:
    """Operand instruction names: the %refs before the first unparenthesized
    option key (operand list ends at the matching close paren)."""
    head = ins.rest.split("), ")[0]
    return re.findall(r"%([\w.\-]+)", head)


def _param_instr_name(pidx: int, callee: List[Instr]) -> Optional[str]:
    """Name of the callee's ``pidx``-th parameter instruction, if present."""
    for ins in callee:
        if ins.op == "parameter" and ins.rest.startswith(f"{pidx})"):
            return ins.name
    return None


def _alias_chain(pname: str, callee: List[Instr]) -> set:
    """Names reachable from ``pname`` through same-size alias ops
    (bitcast/reshape/copy/convert/transpose): a scan body often bitcasts
    the stacked buffer before slicing it."""
    aliases = {pname}
    for _ in range(4):
        grew = False
        for ins in callee:
            if ins.op in ("bitcast", "reshape", "copy", "convert",
                          "transpose") and ins.name not in aliases:
                if aliases & set(_operand_names(ins)):
                    aliases.add(ins.name)
                    grew = True
        if not grew:
            break
    return aliases


def _dus_update_bytes(ops_: List[str], callee: List[Instr]) -> float:
    """Update-extent bytes of a dynamic-update-slice's second operand."""
    if len(ops_) >= 2:
        for cand in callee:
            if cand.name == ops_[1]:
                _, ub = _shape_numel_bytes(cand.shape)
                return ub
    return 0.0


def _param_read_bytes(pidx: int, full_bytes: float,
                      callee: List[Instr]) -> float:
    """Bytes a fused computation actually reads of its ``pidx``-th parameter.

    Scan bodies receive whole stacked arrays and dynamic-slice one step's
    worth inside the fusion; charging the full operand per iteration
    overcounted memory traffic ~1000x. If every use of the parameter is a
    slicing op, charge the slice sizes; otherwise the full buffer.
    """
    pname = _param_instr_name(pidx, callee)
    if pname is None:
        return full_bytes
    aliases = _alias_chain(pname, callee)
    read = 0.0
    for ins in callee:
        if ins.op == "parameter" or ins.name in aliases:
            continue
        ops_ = _operand_names(ins)
        if not (aliases & set(ops_)):
            continue
        if ins.op in ("dynamic-slice", "slice", "gather"):
            _, rb = _shape_numel_bytes(ins.shape)
            read += rb
        elif ins.op == "dynamic-update-slice" and ops_ and ops_[0] in aliases:
            # in-place update of the buffer: reads ~the update extent
            ub = _dus_update_bytes(ops_, callee)
            read += ub if ub else full_bytes
        else:
            return full_bytes
    return min(read, full_bytes)


def _fusion_result_bytes(ins: Instr, callee: List[Instr]) -> float:
    """Result-write bytes of a fusion: a dynamic-update-slice root writes
    only the update extent even though the result shape is the full buffer
    (XLA aliases it in place)."""
    _, rb = _shape_numel_bytes(ins.shape)
    if not callee:
        return rb
    root = callee[-1]
    if root.op == "dynamic-update-slice":
        ops_ = _operand_names(root)
        if len(ops_) >= 2:
            for cand in callee:
                if cand.name == ops_[1]:
                    _, ub = _shape_numel_bytes(cand.shape)
                    return min(2.0 * ub, rb)  # read-modify-write of the slice
    return rb


def _instr_bytes(ins: Instr, shapes: Dict[str, str],
                 comps: Optional[Dict[str, List[Instr]]] = None) -> float:
    """HBM traffic estimate for one instruction execution.

    Slicing ops read/write only the slice, never the backing buffer —
    charging full operands would bill a scan's stacked input once per
    iteration (1000x overcounts observed before this special-casing).
    """
    _, rb = _shape_numel_bytes(ins.shape)
    if ins.op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * rb
    if ins.op in ("dynamic-update-slice", "scatter"):
        ops_ = _operand_names(ins)
        ub = 0.0
        if len(ops_) >= 2 and ops_[1] in shapes:
            _, ub = _shape_numel_bytes(shapes[ops_[1]])
        return 3.0 * ub if ub else 2.0 * rb
    callee = None
    if ins.op == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
        if m:
            callee = comps.get(m.group(1))
    if callee is not None:
        rb = _fusion_result_bytes(ins, callee)
    ob = 0.0
    for i, opn in enumerate(_operand_names(ins)):
        if opn in shapes:
            _, b = _shape_numel_bytes(shapes[opn])
            if callee is not None:
                b = _param_read_bytes(i, b, callee)
            ob += b
    return rb + ob


def analyze(hlo: str) -> Dict[str, object]:
    """Returns {"flops", "bytes", "collectives": {...}, "dots": int}."""
    comps = parse_module(hlo)
    mult = multipliers(comps)
    fused = fusion_called(comps)
    flops = 0.0
    bts = 0.0
    ndots = 0
    coll = {k: {"bytes": 0.0, "count": 0.0} for k in COLLECTIVE_KINDS}

    for cname, instrs in comps.items():
        if cname.startswith("__"):
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        shapes = {i.name: i.shape for i in instrs}
        in_fusion = cname in fused
        for ins in instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, shapes)
                ndots += 1
            if ins.op == "convolution":
                out_numel, _ = _shape_numel_bytes(ins.shape)
                flops += m * 2.0 * out_numel  # lower bound; CNNs not on the hot path
            base_op = ins.op
            for kind in COLLECTIVE_KINDS:
                if base_op == kind or base_op == kind + "-start":
                    _, rb = _shape_numel_bytes(ins.shape)
                    coll[kind]["bytes"] += m * rb
                    coll[kind]["count"] += m
            if in_fusion or base_op in _SKIP_BYTES_OPS \
                    or base_op.endswith("-done"):
                continue
            bts += m * _instr_bytes(ins, shapes, comps)

    out = {
        "flops": flops,
        "bytes": bts,
        "dots": ndots,
        "collectives": {k: v for k, v in coll.items()},
    }
    out["collectives"]["total_bytes"] = sum(
        v["bytes"] for v in coll.values())
    return out
