"""Parse collective operations out of lowered/compiled HLO text.

``compiled.cost_analysis()`` reports FLOPs and bytes but not collective
traffic, so the roofline's collective term comes from scanning the (post-
SPMD-partitioning) HLO for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops and summing their result-shape bytes.
Convention: result bytes = bytes received per participating device per op
execution (all-gather's result is the gathered tensor, reduce-scatter's the
scattered shard — consistent with "bytes over the link" up to the usual
ring-algorithm factor (p-1)/p ~ 1, which we fold into the hardware constant).
Ops inside loop/scan bodies appear once in HLO but execute trip-count times;
we scale by the enclosing while-loop trip count when it is statically
recoverable from the HLO (the common case for lax.scan).
"""
from __future__ import annotations

import re
from typing import Dict

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

# one typed array inside an HLO shape, e.g. f32[16,1024]{1,0}
_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an HLO instruction: "%name = <shape> <opcode>(..."  (opcode may carry
# suffixes like all-gather-start)
_INSTR_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for dtype, dims in _ARRAY_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, dict]:
    """Sum collective result bytes per kind from HLO text.

    Returns {kind: {"bytes": int, "count": int}, ..., "total_bytes": int}.
    """
    # Build a map: computation name -> trip count, for while loops whose
    # condition compares an induction variable against a constant (lax.scan).
    trip_counts = _scan_trip_counts(hlo_text)

    out: Dict[str, dict] = {k: {"bytes": 0, "count": 0}
                            for k in COLLECTIVE_KINDS}
    current_comp = None
    comp_re = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
    for line in hlo_text.splitlines():
        mcomp = comp_re.match(line)
        if mcomp:
            current_comp = mcomp.group(1)
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # avoid double counting async start/done pairs
        shape_str, kind = m.group(1), m.group(2)
        mult = trip_counts.get(current_comp, 1)
        out[kind]["bytes"] += _shape_bytes(shape_str) * mult
        out[kind]["count"] += mult
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if k in COLLECTIVE_KINDS)
    return out


def _scan_trip_counts(hlo_text: str) -> Dict[str, int]:
    """Best-effort: map while-body computation names to static trip counts.

    XLA emits lax.scan as ``while(... condition=%cond body=%body)`` where the
    condition is ``lt(iv, constant)``. We find ``compare`` against integer
    constants inside condition computations and attach the constant to the
    corresponding body computation (named like region_X.Y / body fusion).
    """
    trips: Dict[str, int] = {}
    # while instructions referencing condition & body computation names
    # operands may carry typed, nested-paren annotations: skip to the keys
    while_re = re.compile(
        r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
    )
    # constants inside a computation: need per-computation parse
    comps: Dict[str, str] = {}
    name = None
    buf: list = []
    comp_re = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
    for line in hlo_text.splitlines():
        m = comp_re.match(line)
        if m:
            if name is not None:
                comps[name] = "\n".join(buf)
            name = m.group(1)
            buf = []
        elif name is not None:
            buf.append(line)
    if name is not None:
        comps[name] = "\n".join(buf)

    const_re = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
    for m in while_re.finditer(hlo_text):
        cond, body = m.group(1), m.group(2)
        consts = const_re.findall(comps.get(cond, ""))
        if consts:
            trips[body] = max(int(c) for c in consts)
    return trips
