"""Round-history assembly, shared by every round-loop frontend.

``RoundRecorder`` is the ONLY place per-round history records are
assembled (fedlint FL007 enforces this): ``core.engine.RoundEngine``
feeds it one call per applied round and converts to plain-Python JSON
in a single end-of-loop sync. Before the unified engine, the sync loop
(``core/round.py``) and the async engine (``core/async_engine.py``)
each hand-rolled their own records — and drifted: sync records lacked
the ``staleness`` / ``state_drops`` / ``straggled`` keys async stamped,
and JSON-breaking device arrays had to be fixed twice (PR 4, PR 5).

Every finalized record carries the same uniform schema:

=============  ============================================================
key            meaning (explicit default when the round has no signal)
=============  ============================================================
round          0-based applied-round index
staleness      server-version lag (+ straggler lateness) of the delta; 0
loss_first     cohort mean first-step client loss
loss_last      cohort mean last-step client loss
client_loss    alias of ``loss_last`` (legacy consumers)
bytes_up       per-round uplink bytes (``None`` without byte accounting)
bytes_down     per-round downlink bytes (``None`` without byte accounting)
dropped        clients dropped mid-round; 0
straggled      straggler lateness added to the staleness exponent; 0
state_drops    CAS-dropped client-state writes; 0
=============  ============================================================

plus any ``eval_fn`` metrics for rounds that evaluated, converted with
the losses in the same final sync.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


def json_scalar(v):
    """Device/NumPy metric -> plain Python (history must JSON-serialize).

    Scalars become Python numbers, arrays become lists — by rank, not
    size, so a length-1 vector metric keeps its list type. Reading a
    device array here blocks until its computation lands, so engines call
    this once per run (the end-of-loop sync), not once per round.
    """
    a = np.asarray(v)
    return a.item() if a.ndim == 0 else a.tolist()


class RoundRecorder:
    """Collects raw (possibly device-backed) round records; one sync at
    the end.

    ``record(...)`` is called once per applied round with whatever the
    engine measured; values it was not given are stamped with their
    explicit schema defaults, so both execution modes emit the same key
    set. ``history()`` converts everything to plain Python in one pass —
    the single blocking device sync of a whole run.
    """

    def __init__(self, *, round_bytes: Optional[dict] = None,
                 burn_round_bytes: Optional[dict] = None):
        #: ``compression.round_bytes`` dicts ({"bytes_up", "bytes_down"});
        #: burn rounds may communicate a different (dense) payload
        self.round_bytes = round_bytes
        self.burn_round_bytes = burn_round_bytes
        self._raw: List[dict] = []

    def record(self, *, round_idx: int, metrics: dict, is_burn: bool = False,
               staleness: int = 0, dropped: int = 0, straggled: int = 0,
               state_drops=0, eval_metrics: Optional[dict] = None) -> dict:
        """Assemble one round's raw record (uniform schema, explicit
        defaults) and append it; returns it for live ``on_round``
        consumers. ``metrics`` is the cohort program's loss dict and may
        still live on device — as may ``state_drops`` (the device store's
        CAS counter) and ``eval_metrics`` values."""
        bts = (self.burn_round_bytes if is_burn
               else self.round_bytes) or self.round_bytes
        rec = {"round": round_idx, "staleness": staleness,
               "metrics": metrics,
               "bytes_up": None if bts is None else bts["bytes_up"],
               "bytes_down": None if bts is None else bts["bytes_down"],
               "dropped": dropped, "straggled": straggled,
               "state_drops": state_drops}
        if eval_metrics is not None:
            rec["eval"] = eval_metrics
        self._raw.append(rec)
        return rec

    def history(self) -> List[dict]:
        """Finalize: one end-of-loop sync producing JSON-safe entries.

        Splicing raw device arrays into history broke JSON serialization
        and hid a blocking sync behind the first consumer access; forcing
        per round costs one sync per round — so everything converts here,
        once."""
        history = []
        for rec in self._raw:
            entry = {"round": rec["round"], "staleness": rec["staleness"],
                     "loss_first": float(rec["metrics"]["loss_first"]),
                     "loss_last": float(rec["metrics"]["loss_last"])}
            entry["client_loss"] = entry["loss_last"]
            entry["bytes_up"] = (None if rec["bytes_up"] is None
                                 else json_scalar(rec["bytes_up"]))
            entry["bytes_down"] = (None if rec["bytes_down"] is None
                                   else json_scalar(rec["bytes_down"]))
            entry["dropped"] = int(rec["dropped"])
            entry["straggled"] = int(rec["straggled"])
            entry["state_drops"] = int(json_scalar(rec["state_drops"]))
            entry.update({k: json_scalar(v)
                          for k, v in rec.get("eval", {}).items()})
            history.append(entry)
        return history
