"""Round-history helpers shared by the sync and async engines.

Both ``FedSim.run`` and ``AsyncRoundEngine.run`` return a per-round
``history`` list whose entries must be plain-Python JSON-serializable
dicts — splicing raw device arrays in breaks ``json.dumps(history)`` and
hides a blocking device sync behind the first consumer access.
"""
from __future__ import annotations

import numpy as np


def json_scalar(v):
    """Device/NumPy metric -> plain Python (history must JSON-serialize).

    Scalars become Python numbers, arrays become lists — by rank, not
    size, so a length-1 vector metric keeps its list type. Reading a
    device array here blocks until its computation lands, so engines call
    this once per run (the end-of-loop sync), not once per round.
    """
    a = np.asarray(v)
    return a.item() if a.ndim == 0 else a.tolist()
