"""Server side of generalized federated optimization (Algorithm 1).

The aggregated client delta is treated as a stochastic (pseudo-)gradient of
the surrogate quadratic Q(theta) (Proposition 2) and fed to any server
optimizer — SGD-M / Adam / Adagrad / Yogi, exactly the adaptive-FL framing
of Reddi et al. (2020) that the paper builds on.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.optim import Optimizer


class ServerState(NamedTuple):
    params: object
    opt_state: object
    round: jnp.ndarray   # i32 scalar


def init_server_state(params, server_opt: Optimizer) -> ServerState:
    return ServerState(params, server_opt.init(params),
                       jnp.zeros((), jnp.int32))


def aggregate_deltas(deltas, weights: Optional[jnp.ndarray] = None):
    """Weighted mean over the leading client axis of stacked deltas."""
    if weights is None:
        return tm.tmap(lambda d: jnp.mean(d, axis=0), deltas)
    w = weights / jnp.sum(weights)
    return tm.tmap(
        lambda d: jnp.tensordot(w.astype(d.dtype), d, axes=1), deltas
    )


def aggregate_deltas_list(deltas: Sequence, weights=None):
    """Same but for a Python list of per-client delta trees (simulation)."""
    n = len(deltas)
    if weights is None:
        weights = [1.0 / n] * n
    else:
        tot = sum(weights)
        weights = [w / tot for w in weights]
    acc = tm.tscale(weights[0], deltas[0])
    for w, d in zip(weights[1:], deltas[1:]):
        acc = tm.taxpy(w, d, acc)
    return acc


def server_update(state: ServerState, mean_delta,
                  server_opt: Optimizer) -> ServerState:
    """theta <- SERVEROPT(theta, Delta). Deltas point along +grad, so they
    plug directly into the (descent) optimizer update."""
    updates, opt_state = server_opt.update(mean_delta, state.opt_state,
                                           state.params)
    params = tm.tmap(lambda p, u: p + u.astype(p.dtype), state.params, updates)
    return ServerState(params, opt_state, state.round + 1)
