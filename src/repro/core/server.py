"""Server side of generalized federated optimization (Algorithm 1).

The aggregated client delta is treated as a stochastic (pseudo-)gradient of
the surrogate quadratic Q(theta) (Proposition 2) and fed to any server
optimizer — SGD-M / Adam / Adagrad / Yogi, exactly the adaptive-FL framing
of Reddi et al. (2020) that the paper builds on.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.optim import Optimizer


class ServerState(NamedTuple):
    """Everything the server carries across rounds.

    ``algo_state`` is the algorithm's persistent server-side statistic
    (``FedAlgorithm.init_algo_state``): an empty pytree for most algorithms,
    SCAFFOLD's server control variate. It defaults to ``()`` so positional
    3-field construction (params, opt_state, round) keeps working.
    """

    params: object
    opt_state: object
    round: jnp.ndarray   # i32 scalar
    algo_state: object = ()


def init_server_state(params, server_opt: Optimizer,
                      algorithm=None) -> ServerState:
    """Fresh server state; ``algorithm`` (a ``FedAlgorithm``) seeds its
    persistent ``algo_state`` — omitted, the slot is an empty pytree."""
    algo_state = () if algorithm is None else algorithm.init_algo_state(params)
    return ServerState(params, server_opt.init(params),
                       jnp.zeros((), jnp.int32), algo_state)


def check_weight_total(total: float, shape=None, context: str = "") -> None:
    """Shared host-side guard: raise on a non-positive cohort weight sum —
    loudly, before the NaN it would produce can poison the server state and
    only surface rounds later."""
    if not total > 0.0:
        raise ValueError(
            f"{context}cohort weights must sum to a positive total, got "
            f"sum={total}"
            + (f" for weights of shape {shape}" if shape is not None else ""))


def normalized_weights(client_weights, num_clients: int) -> jnp.ndarray:
    """Cohort weights -> fp32 simplex weights (None = uniform).

    Eager weights with a non-positive sum raise (``check_weight_total``).
    Traced weights (inside jit) degrade to an all-zero vector (a no-op
    round) instead of dividing by zero.
    """
    if client_weights is None:
        return jnp.full((num_clients,), 1.0 / num_clients, jnp.float32)
    w = jnp.asarray(client_weights, jnp.float32)
    total = jnp.sum(w)
    if not isinstance(total, jax.core.Tracer):
        check_weight_total(float(total), w.shape)
    return jnp.where(total > 0, w / jnp.where(total > 0, total, 1.0),
                     jnp.zeros_like(w))


def weighted_sum(stacked_deltas, weights, cast: bool = True):
    """sum_i w_i * delta_i over the leading client axis.

    The reduction runs in fp32 regardless of the delta dtype and the result
    is cast once at the end — casting the normalized weights down to e.g.
    bf16 first would round realistic example-count weights to ~2 decimal
    digits and bias the aggregate. ``cast=False`` keeps the fp32 sum (the
    algorithm accumulator space, where ``FedAlgorithm.finalize`` owns the
    single terminal cast).
    """
    return tm.tmap(
        lambda d: (jnp.tensordot(weights, d.astype(jnp.float32), axes=1)
                   .astype(d.dtype if cast else jnp.float32)),
        stacked_deltas,
    )


def aggregate_deltas(deltas, weights: Optional[jnp.ndarray] = None):
    """Weighted mean over the leading client axis of stacked deltas.

    Both paths reduce in fp32 and cast once to the delta dtype."""
    if weights is None:
        return tm.tmap(
            lambda d: jnp.mean(d.astype(jnp.float32), axis=0).astype(d.dtype),
            deltas)
    num = jax.tree_util.tree_leaves(deltas)[0].shape[0]
    return weighted_sum(deltas, normalized_weights(weights, num))


def aggregate_deltas_list(deltas: Sequence, weights=None):
    """Same but for a Python list of per-client delta trees (simulation)."""
    n = len(deltas)
    if weights is None:
        weights = [1.0 / n] * n
    else:
        tot = sum(weights)
        check_weight_total(float(tot))
        weights = [w / tot for w in weights]
    acc = tm.tscale(weights[0], deltas[0])
    for w, d in zip(weights[1:], deltas[1:]):
        acc = tm.taxpy(w, d, acc)
    return acc


def server_update(state: ServerState, mean_delta,
                  server_opt: Optimizer) -> ServerState:
    """theta <- SERVEROPT(theta, Delta). Deltas point along +grad, so they
    plug directly into the (descent) optimizer update. ``algo_state`` is
    carried through untouched (algorithms that update it do so in their
    ``server_update`` hook, after this step)."""
    updates, opt_state = server_opt.update(mean_delta, state.opt_state,
                                           state.params)
    params = tm.tmap(lambda p, u: p + u.astype(p.dtype), state.params, updates)
    return state._replace(params=params, opt_state=opt_state,
                          round=state.round + 1)
