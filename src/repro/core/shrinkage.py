"""Shrinkage covariance estimation (Theorem 3 / Appendix C.1).

The Ledoit-Wolf-style estimator

    Sigma_hat_l = rho_l * I + (1 - rho_l) * S_l,   rho_l = 1 / (1 + (l-1) rho)

is the unique shrinkage schedule for which the *unnormalized* matrix

    Sigma_tilde_t = I + rho (t-1) S_t

admits exact rank-1 updates

    Sigma_tilde_t = Sigma_tilde_{t-1} + gamma_t u_t u_t^T,
    u_t = x_t - xbar_{t-1},   gamma_t = (t-1) rho / t,

which is what makes the O(l^2 d) Sherman-Morrison dynamic program of
``dp_delta`` possible. This module holds the dense/closed-form pieces used by
the DP, the tests, and the (offline) near-optimal-rho estimators.
"""
from __future__ import annotations

import jax.numpy as jnp


def rho_l(ell, rho):
    """Shrinkage weight on the identity after ``ell`` samples."""
    return 1.0 / (1.0 + (ell - 1.0) * rho)


def gamma_t(t, rho):
    """Rank-1 update coefficient: Sigma_tilde_t - Sigma_tilde_{t-1} = gamma_t u u^T."""
    return (t - 1.0) * rho / t


def sample_mean_cov(samples: jnp.ndarray):
    """Sample mean and (unbiased, /(l-1)) sample covariance of (l, d) samples."""
    ell = samples.shape[0]
    mean = jnp.mean(samples, axis=0)
    centered = samples - mean
    denom = max(ell - 1, 1)
    cov = centered.T @ centered / denom
    return mean, cov


def shrinkage_cov(samples: jnp.ndarray, rho: float) -> jnp.ndarray:
    """Dense Sigma_hat_l = rho_l I + (1 - rho_l) S_l.  O(l d^2) — tests only."""
    ell, d = samples.shape
    _, cov = sample_mean_cov(samples)
    r = rho_l(ell, rho)
    return r * jnp.eye(d, dtype=cov.dtype) + (1.0 - r) * cov


def shrinkage_cov_unnormalized(samples: jnp.ndarray, rho: float) -> jnp.ndarray:
    """Dense Sigma_tilde_t = I + rho (t-1) S_t (the rank-1-recursive form)."""
    ell, d = samples.shape
    _, cov = sample_mean_cov(samples)
    return jnp.eye(d, dtype=cov.dtype) + rho * (ell - 1.0) * cov


def dense_delta(x0: jnp.ndarray, samples: jnp.ndarray, rho: float) -> jnp.ndarray:
    """O(d^3) oracle: Delta_hat_l = Sigma_hat_l^{-1} (x0 - xbar_l).

    This is the quantity Theorem 3 computes in O(l^2 d); the DP implementation
    is asserted allclose against this in tests and benchmarked against it in
    benchmarks/table1_client_cost.py.
    """
    mean = jnp.mean(samples, axis=0)
    sigma = shrinkage_cov(samples, rho)
    return jnp.linalg.solve(sigma, x0 - mean)


# ---------------------------------------------------------------------------
# Near-optimal shrinkage selection (Chen et al. 2010), offline alternative to
# committing to a fixed rho (Appendix C, "Optimal selection of rho").
# ---------------------------------------------------------------------------

def oas_rho(samples: jnp.ndarray) -> jnp.ndarray:
    """Oracle-Approximating Shrinkage weight rho_l* in [0, 1] (Chen et al. 2010).

    Returns the *normalized* shrinkage weight on the identity (i.e. the thing
    ``rho_l`` computes from the paper's rho); callers can invert the map
    rho = (1/rho_l - 1)/(l - 1) if they need the paper's parameterization.
    """
    ell, d = samples.shape
    mean = jnp.mean(samples, axis=0)
    c = samples - mean
    s = c.T @ c / max(ell - 1, 1)
    tr_s = jnp.trace(s)
    tr_s2 = jnp.sum(s * s)
    num = (1.0 - 2.0 / d) * tr_s2 + tr_s**2
    den = (ell + 1.0 - 2.0 / d) * (tr_s2 - tr_s**2 / d)
    return jnp.clip(num / jnp.maximum(den, 1e-30), 0.0, 1.0)
