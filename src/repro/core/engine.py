"""The staleness-general round engine: ONE loop for sync and async.

The paper's framing — FedAvg as the degenerate case of a generalized
posterior-inference round loop — applies to the loop itself: the
synchronous path is the async pipeline with an in-flight window of one.
``RoundEngine`` owns that single loop: cohort dispatch (up to
``max_staleness + 1`` cohorts in flight), delta application with the
``staleness_discount ** s`` down-weighting, client-state gather /
CAS-scatter routing for both store placements, burn-in regimes, eval
cadence, the prefetcher lifecycle, and history via the shared
``core.history.RoundRecorder``. ``FedSim`` (``core/round.py``),
``launch.train``, the deprecated ``AsyncRoundEngine`` alias
(``core/async_engine.py``), and the engine benchmarks are all thin
frontends over it.

Two program backends hide behind the one loop:

* **fused** (``round_fn`` from ``make_round_program``): the whole round
  — cohort, aggregation, server update — is one jitted XLA dispatch.
  Used when the window is 1 (``max_staleness=0``) and no straggler can
  add lateness to the staleness exponent (``pipeline_only=False``);
  bitwise-identical to the pre-engine synchronous loop.
* **split** (``cohort_fn`` + ``server_fn`` from ``make_cohort_program``
  / ``make_server_program``): cohort compute and server update are
  separate dispatches so cohort ``t+1`` can be in flight before round
  ``t``'s update lands, and so a delta computed at params version ``v``
  and applied at ``v + s`` can be discounted by
  ``staleness_discount ** s`` (straggler lateness rides the same
  exponent). Bitwise-identical to the pre-engine async engine.

The two backends agree to float rounding but are NOT bitwise-identical
in general (XLA fuses the round differently), which is why both exist;
each frontend keeps whichever bitwise contract it always had.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, NamedTuple, Optional, Tuple, Union

import jax

from repro.core.client_state import (ClientStateStore, DeviceClientStateStore,
                                     device_scatter, jit_donating_store)
from repro.core.history import RoundRecorder
from repro.core.server import ServerState
from repro.data.prefetch import Cohort, close_prefetcher, make_prefetcher

#: build_cohort(round_idx) -> Cohort (see data/prefetch.py)
BuildCohort = Callable[[int], Cohort]


class _InFlight(NamedTuple):
    """One dispatched-but-unapplied cohort in the split-backend pipeline.

    ``version`` is the params version the cohort saw when dispatched;
    ``client_ids`` / ``new_states`` / ``stamps`` carry the per-client
    state write-back (None for stateless regimes): the gather-time write
    stamps let the store drop a stale write from a cohort that overlapped
    an already-applied one on the same client. With the device store the
    three are device arrays (the traced id vector, the cohort program's
    stacked state output, the on-device stamp snapshot) and the write-back
    never touches the host. ``survivors`` / ``extra_staleness`` /
    ``dropped`` are the cohort's fault annotations (``data.cohort_source``):
    the survivors mask was already threaded through the dispatched cohort
    program and gates the state write-back; straggler lateness is added to
    the staleness exponent at apply time.
    """

    agg: object
    metrics: dict
    version: int
    round_idx: int
    is_burn: bool
    client_ids: object = None
    new_states: object = None
    stamps: object = None
    survivors: object = None
    extra_staleness: int = 0
    dropped: int = 0


class _Applied(NamedTuple):
    """What one applied round hands the recorder (either backend)."""

    state: ServerState
    metrics: dict
    is_burn: bool
    staleness: int
    dropped: int
    straggled: int
    state_drops: object   # int, or the device store's CAS drop counter


@dataclasses.dataclass
class RoundEngine:
    """Drives ``num_rounds`` staleness-aware rounds; window=1 ≡ sync.

    Pass raw program builders, not pre-jitted functions — the engine owns
    all jitting (including the device store's donation + pinned
    ``out_shardings``). Backends:

    * split stages: ``cohort_fn(state, batches, weights, survivors) ->
      (agg, metrics)`` + ``server_fn(state, agg, discount) -> state``
      (stateful signatures as in ``make_cohort_program``); required
      whenever ``max_staleness > 0`` or ``pipeline_only=True``.
    * fused round: ``round_fn(state, batches, weights[, store, ids],
      survivors) -> (state, metrics[, new_store])`` from
      ``make_round_program``; required for the single-dispatch window=1
      path and the one-shot ``round()`` API.

    ``burn_*`` variants (optional) are used for the first
    ``burn_in_rounds`` rounds — the burn regime of the config's algorithm
    (e.g. the FedAvg regime of a FedPA config, Section 5.2); the burn
    server stage exists because a burn regime may aggregate in a
    different payload space than the sampling regime (``fedpa_precision``
    burns in as fedavg).

    Stateful algorithms (``stateful=True`` + a ``client_store``): each
    dispatched cohort gathers its clients' persistent state from the store
    and the write-back happens at APPLY time, in round order, tagged with
    the gather-time stamps — so when two in-flight cohorts overlap on a
    client, the one applied second (which gathered before the first wrote)
    is dropped for that client instead of clobbering the fresher state.
    With the host ``ClientStateStore`` the write-back pulls ``new_states``
    to the host; with a ``DeviceClientStateStore`` the gather happens
    *inside* the dispatched program and the write-back is a small jitted
    ``device_scatter`` (store buffers donated, CAS drop count kept as a
    device counter until the end-of-loop history sync).

    ``pipeline_only=True`` forces the split backend even at window=1:
    straggler injection (``fed.straggler_rate > 0``) needs the apply-time
    ``staleness_discount ** extra_staleness`` path that the fused program
    does not trace. ``lift_operand`` (optional) lifts host-built operands
    (the survivors mask, prepared store ids) to global arrays for
    multi-process runs (``launch.train``'s ``replicate_global``).
    """

    cohort_fn: Optional[Callable] = None
    server_fn: Optional[Callable] = None
    max_staleness: int = 0
    staleness_discount: float = 1.0
    burn_cohort_fn: Optional[Callable] = None
    burn_server_fn: Optional[Callable] = None
    burn_in_rounds: int = 0
    prefetch_rounds: int = 0
    prefetch_backend: str = "thread"
    client_store: Optional[Union[ClientStateStore,
                                 DeviceClientStateStore]] = None
    stateful: bool = False
    burn_stateful: bool = False
    #: kept for frontend compat: the uniform history schema now stamps
    #: ``dropped`` / ``straggled`` on every record (0 defaults), so this
    #: no longer gates anything
    record_faults: bool = False
    #: Per-round communicated bytes (``compression.round_bytes`` dicts with
    #: ``bytes_up`` / ``bytes_down``), stamped on every history record;
    #: ``burn_round_bytes`` covers the burn regime's (dense) payloads.
    round_bytes: Optional[dict] = None
    burn_round_bytes: Optional[dict] = None
    round_fn: Optional[Callable] = None
    burn_round_fn: Optional[Callable] = None
    pipeline_only: bool = False
    lift_operand: Optional[Callable] = None

    def __post_init__(self):
        """Validate knobs, normalize the burn-regime flags, jit the
        backends."""
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if not 0.0 <= self.staleness_discount <= 1.0:
            raise ValueError("staleness_discount must be in [0, 1]")
        needs_split = (self.max_staleness > 0 or self.pipeline_only
                       or self.round_fn is None)
        if needs_split and (self.cohort_fn is None or self.server_fn is None):
            raise ValueError(
                "RoundEngine needs split stages (cohort_fn + server_fn) "
                "whenever the pipeline can run: max_staleness > 0, "
                "pipeline_only=True, or no fused round_fn was given")
        if self.burn_cohort_fn is None and self.burn_round_fn is None:
            # no dedicated burn stage: burn rounds run the main programs,
            # so they are stateful exactly when the main regime is
            self.burn_stateful = self.stateful
        if (self.stateful or self.burn_stateful) and self.client_store is None:
            raise ValueError(
                "stateful=True requires a client-state store (client_store)")
        self._device_store = isinstance(self.client_store,
                                        DeviceClientStateStore)
        # the split backend's device write-back stage: donate the store so
        # the (N, ...) buffers alias in place instead of doubling
        # per-client state; a population-sharded store additionally pins
        # the scatter's store output to its own placement so the alias is
        # shard-for-shard
        self._scatter = None
        if self._device_store:
            pop_sh = self.client_store.population_sharding
            self._scatter = jit_donating_store(
                device_scatter, 0,
                out_shardings=None if pop_sh is None else (pop_sh, None))
        self._cohort = (jax.jit(self.cohort_fn)
                        if self.cohort_fn is not None else None)
        self._burn = (jax.jit(self.burn_cohort_fn)
                      if self.burn_cohort_fn is not None else self._cohort)
        self._server = (jax.jit(self.server_fn)
                        if self.server_fn is not None else None)
        self._burn_server = (jax.jit(self.burn_server_fn)
                             if self.burn_server_fn is not None
                             else self._server)
        self._fused = self._jit_fused(self.round_fn, self.stateful)
        self._fused_burn = (self._jit_fused(self.burn_round_fn,
                                            self.burn_stateful)
                            if self.burn_round_fn is not None
                            else self._fused)
        #: window=1 with no straggler lateness runs the single-dispatch
        #: fused program — today's sync path, bitwise
        self._use_fused = (self._fused is not None
                           and self.max_staleness == 0
                           and not self.pipeline_only)

    def _jit_fused(self, round_fn, regime_stateful: bool):
        """Jit one fused round; a device-stateful regime donates the store
        argument so the (N, ...) buffers update in place, pinned to the
        store's own population sharding so the alias is shard-for-shard."""
        if round_fn is None:
            return None
        if regime_stateful and self._device_store:
            out_sh = None
            if self.client_store.population_sharding is not None:
                out_sh = (None, None,
                          self.client_store.population_sharding)
            return jit_donating_store(round_fn, 3, out_shardings=out_sh)
        return jax.jit(round_fn)

    def _lift(self, x):
        """Lift a host-built operand to a global array (multi-process)."""
        if x is None or self.lift_operand is None:
            return x
        return self.lift_operand(x)

    # -- split backend: dispatch now, apply (discounted) later ------------
    def _dispatch(self, state: ServerState, cohort: Cohort, t_next: int,
                  version: int) -> _InFlight:
        """Dispatch one cohort program and wrap its outputs as ``_InFlight``.

        Stateful regimes also carry the per-client state write-back: with
        the device store the gather happens inside the dispatched program
        against the store's current device buffers (the returned stamps
        snapshot tags the CAS); with the host store the gather is a host
        numpy slice."""
        is_burn = t_next < self.burn_in_rounds
        fn = self._burn if is_burn else self._cohort
        surv = self._lift(cohort.survivors)
        fault = (surv, cohort.extra_staleness, cohort.dropped)
        if not (self.burn_stateful if is_burn else self.stateful):
            agg, metrics = fn(state, cohort.batches, cohort.weights, surv)
            return _InFlight(agg, metrics, version, t_next, is_burn,
                             None, None, None, *fault)
        if self._device_store:
            ids = self._lift(self.client_store.prepare_ids(cohort.client_ids))
            agg, metrics, new_states, stamps = fn(
                state, cohort.batches, cohort.weights,
                self.client_store.device_state(), ids, surv)
            return _InFlight(agg, metrics, version, t_next, is_burn,
                             ids, new_states, stamps, *fault)
        cstates, stamps = self.client_store.gather(cohort.client_ids)
        agg, metrics, new_states = fn(state, cohort.batches, cohort.weights,
                                      cstates, surv)
        return _InFlight(agg, metrics, version, t_next, is_burn,
                         cohort.client_ids, new_states, stamps, *fault)

    def _apply_pipelined(self, state: ServerState, fl: _InFlight,
                         version: int) -> _Applied:
        """Apply one in-flight cohort: staleness-discounted server update,
        then the apply-order client-state write-back."""
        # a straggling cohort is applied at its slot but discounted as if
        # it were extra_staleness rounds later — the late delta rides the
        # existing staleness_discount**s path
        staleness = version - fl.version + fl.extra_staleness
        server = self._burn_server if fl.is_burn else self._server
        state = server(state, fl.agg, self.staleness_discount ** staleness)
        drops = self._write_back_states(fl)
        return _Applied(state, fl.metrics, fl.is_burn, staleness,
                        int(fl.dropped), int(fl.extra_staleness), drops)

    def _write_back_states(self, fl: _InFlight):
        """Apply-order client-state write-back, tagged with the gather-time
        stamps: a client already updated by an overlapping cohort keeps
        that fresher value (stale write dropped); a dropped client's
        half-finished state must not land. Returns the CAS drop count
        (a device scalar for the device store — no per-round host pull)."""
        if fl.new_states is None:
            return 0
        if self._device_store:
            new_store, drops = self._scatter(
                self.client_store.device_state(), fl.client_ids,
                fl.new_states, fl.stamps, fl.survivors)
            self.client_store.set_device_state(new_store)
            return drops
        return self.client_store.scatter(
            fl.client_ids, fl.new_states, fl.stamps,
            write_mask=fl.survivors)

    # -- fused backend: the whole round is one dispatch --------------------
    def _apply_fused(self, state: ServerState, cohort: Cohort,
                     t: int) -> _Applied:
        """One fused round; stateful algorithms additionally thread the
        cohort's client state through the jitted round — gathered and
        scattered at the host edges for the host store, or passed as the
        store's device buffers (+ the cohort ids) with the gather/CAS
        scatter fused into the program for the device store."""
        is_burn = t < self.burn_in_rounds
        fn = self._fused_burn if is_burn else self._fused
        stateful = self.burn_stateful if is_burn else self.stateful
        surv = self._lift(cohort.survivors)  # None = mask-free program
        drops = 0
        if stateful and self._device_store:
            ids = self._lift(self.client_store.prepare_ids(cohort.client_ids))
            state, metrics, new_store = fn(
                state, cohort.batches, cohort.weights,
                self.client_store.device_state(), ids, surv)
            self.client_store.set_device_state(new_store)
        elif stateful:
            cstates, stamps = self.client_store.gather(cohort.client_ids)
            state, metrics, new_states = fn(
                state, cohort.batches, cohort.weights, cstates, surv)
            # a dropped client's half-finished state must not land
            drops = self.client_store.scatter(cohort.client_ids, new_states,
                                              stamps, write_mask=surv)
        else:
            state, metrics = fn(state, cohort.batches, cohort.weights, surv)
        return _Applied(state, metrics, is_burn, 0,
                        int(cohort.dropped), int(cohort.extra_staleness),
                        drops)

    def round(self, state: ServerState, cohort: Cohort, round_idx: int
              ) -> Tuple[ServerState, dict]:
        """One synchronous round via the fused backend (requires
        ``round_fn``); returns ``(state, record)`` with the record already
        finalized to plain Python — the one-shot twin of ``run``."""
        if self._fused is None:
            raise ValueError(
                "RoundEngine.round needs a fused round_fn (the split "
                "pipeline has no single-round API)")
        recorder = RoundRecorder(round_bytes=self.round_bytes,
                                 burn_round_bytes=self.burn_round_bytes)
        out = self._apply_fused(state, cohort, round_idx)
        recorder.record(round_idx=round_idx, metrics=out.metrics,
                        is_burn=out.is_burn, staleness=out.staleness,
                        dropped=out.dropped, straggled=out.straggled,
                        state_drops=out.state_drops)
        return out.state, recorder.history()[0]

    def run(
        self,
        state: ServerState,
        build_cohort: BuildCohort,
        num_rounds: int,
        *,
        eval_fn: Optional[Callable] = None,
        eval_every: int = 1,
        on_round: Optional[Callable] = None,
    ) -> Tuple[ServerState, List[dict]]:
        """Returns ``(state, history)``; one uniform-schema history entry
        per applied round (``core.history.RoundRecorder``), every value
        JSON-serializable after the single end-of-loop sync. ``eval_fn``
        metrics ride the records of rounds where ``t % eval_every == 0``
        (plus the last round).

        ``on_round(record, state)`` fires after each server update with the
        raw (possibly still-on-device) metrics and the post-update state —
        for live logging/checkpointing. Forcing metrics there re-introduces
        a per-round sync, so log sparingly in throughput-sensitive loops.
        """
        if eval_fn is not None and eval_every < 1:
            raise ValueError(
                f"eval_every must be >= 1 when eval_fn is set, got "
                f"{eval_every} (evaluate every round with eval_every=1, or "
                f"pass eval_fn=None to disable evaluation)")
        recorder = RoundRecorder(round_bytes=self.round_bytes,
                                 burn_round_bytes=self.burn_round_bytes)
        source = (make_prefetcher(self.prefetch_backend, build_cohort, 0,
                                  num_rounds, depth=self.prefetch_rounds)
                  if self.prefetch_rounds > 0 else None)
        get = source.get if source is not None else build_cohort
        fused = self._use_fused
        pending: deque = deque()   # in dispatch (== apply) order
        version = 0                # server updates applied so far
        t_next = 0                 # next round to dispatch
        completed = False
        try:
            for t_apply in range(num_rounds):
                # keep up to max_staleness cohorts in flight beyond the one
                # being applied; each remembers the params version it saw.
                # The fused backend (window=1) has nothing in flight — its
                # "dispatch" is just the host-side cohort build.
                while (t_next < num_rounds
                       and len(pending) <= self.max_staleness):
                    cohort = get(t_next)
                    pending.append(cohort if fused else
                                   self._dispatch(state, cohort, t_next,
                                                  version))
                    t_next += 1

                item = pending.popleft()
                if fused:
                    out = self._apply_fused(state, item, t_apply)
                else:
                    assert item.round_idx == t_apply, (item.round_idx,
                                                       t_apply)
                    out = self._apply_pipelined(state, item, version)
                state = out.state
                version += 1
                ev = (eval_fn(state.params)
                      if eval_fn is not None and (t_apply % eval_every == 0
                                                  or t_apply == num_rounds - 1)
                      else None)
                rec = recorder.record(
                    round_idx=t_apply, metrics=out.metrics,
                    is_burn=out.is_burn, staleness=out.staleness,
                    dropped=out.dropped, straggled=out.straggled,
                    state_drops=out.state_drops, eval_metrics=ev)
                if on_round is not None:
                    on_round(rec, state)
            completed = True
        finally:
            if source is not None:
                # a hung prefetch worker stays loud on a clean exit but
                # must not mask an exception unwinding out of the loop
                close_prefetcher(source, unwinding=not completed)

        # one sync at the end instead of one per round — splicing raw
        # device arrays into history broke JSON serialization and hid a
        # sync on first access
        return state, recorder.history()
