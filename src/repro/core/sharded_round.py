"""The production multi-pod federated round: one jitted SPMD program.

The paper's communication pattern — broadcast theta, K isolated local steps
per client, one O(d) delta aggregation — maps onto the TPU mesh as
(DESIGN.md §3):

  * ``parallel`` placement: clients are slices of the ("pod", "data") axes.
    The client dimension is a ``vmap`` with ``spmd_axis_name`` set to the
    client axes, so every per-client tensor (params copy, optimizer moments,
    IASG samples, DP history) shards one-client-per-data-slice, and the only
    cross-client collective is the delta mean — a single all-reduce of d
    values per round, amortized over K local steps. This is the paper's
    O(d)-communication claim made structural.

  * ``sequential`` placement (>=10B archs): clients run one after another in
    a ``lax.scan``, each using the whole mesh; the client-local batch shards
    over ("pod", "data") and all parameter-shaped state (fp32 master, client
    moments, IASG samples, DP vectors) is FSDP-sharded over data x model via
    ``fsdp_constrain``, with a bf16 all-gather at each local step's compute
    boundary (``tp_constrain``). This trades one weight all-gather per local
    step for fitting O(l d) FedPA state in HBM.

  * ``chunked`` placement: scan-of-vmap middle ground — ``chunk`` clients
    vmapped at a time, chunks scanned, for cohorts too large to vmap whole.

Both the program structure (placement loops, weighted aggregation, server
update) and the client math live in ``round_program.make_round_program`` —
this module only contributes the LM grad_fn and the FSDP/TP sharding hooks,
so the simulation path (``round.FedSim``) and this path can never diverge.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import FedConfig, ModelConfig
from repro.core import tree_math as tm
from repro.core.round_program import (make_cohort_program,
                                      make_round_program,
                                      make_server_program)
from repro.models.steps import lm_grad_fn
from repro.sharding import fsdp_constrain, tp_constrain


def _program_pieces(
    cfg: ModelConfig,
    fed: FedConfig,
    placement: str,
    spmd_axes: Optional[Tuple[str, ...]],
    compute_dtype,
    q_chunk: int,
    remat: str,
    use_sampling: bool,
    chunk_size: Optional[int],
):
    """Shared wiring: (grad_fn, cohort_kwargs, server_kwargs) for a given
    placement — one source of truth for the fused and split builders."""
    from repro.algorithms import resolve_algorithm  # noqa: PLC0415

    grad_fn = lm_grad_fn(cfg, compute_dtype=compute_dtype, q_chunk=q_chunk,
                         remat=remat)

    if placement in ("parallel", "chunked"):
        cohort_kw = dict(placement=placement, chunk_size=chunk_size,
                         spmd_axes=spmd_axes, use_sampling=use_sampling)
        return grad_fn, cohort_kw, {"use_sampling": use_sampling}

    if placement != "sequential":
        raise ValueError(f"unknown placement {placement!r}")

    alg = resolve_algorithm(fed, use_sampling)

    def wrap_client(client_update):
        def fsdp_client_update(master_params, batches, *extra):
            """One client with FSDP-sharded state; compute on gathered bf16."""
            # the all-gather boundary: compute params are tensor-parallel only
            gathered = tp_constrain(tm.tcast(master_params, compute_dtype))
            res = client_update(gathered, batches, *extra)
            payload = alg.map_components(
                lambda t: fsdp_constrain(t, like_params=master_params),
                res.payload)
            # state_update (stateful algorithms) passes through unchanged:
            # its sharding is pinned by the gathered store slice it came from
            return res._replace(payload=payload)

        return fsdp_client_update

    cohort_kw = dict(
        placement="sequential", use_sampling=use_sampling,
        wrap_client=wrap_client,
        prepare_params=fsdp_constrain,
        constrain_accum=lambda zeros, master: fsdp_constrain(
            zeros, like_params=master),
    )
    server_kw = dict(use_sampling=use_sampling,
                     prepare_params=fsdp_constrain,
                     finalize_params=fsdp_constrain)
    return grad_fn, cohort_kw, server_kw


def make_fed_round(
    cfg: ModelConfig,
    fed: FedConfig,
    *,
    placement: str = "parallel",
    spmd_axes: Optional[Tuple[str, ...]] = None,
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 1024,
    remat: str = "full",
    use_sampling: bool = True,
    chunk_size: Optional[int] = None,
) -> Callable:
    """Build ``round_fn(server_state, client_batches) -> (state, metrics)``.

    client_batches: {"tokens": (C, K, B_local, S+1) int32,
                     ["frontend": (C, K, B_local, F, d)]}.
    ``use_sampling=False`` gives the burn-in-round variant (FedAvg regime)
    of the same FedPA config — used for the first ``burn_in_rounds`` rounds.

    Stateful algorithms follow ``fed.client_state_placement``: ``"host"``
    appends the gathered ``client_states`` slice to the signature,
    ``"device"`` appends ``(store_state, client_ids)`` with the
    gather/CAS-scatter fused into the program and the updated store
    returned (see ``round_program.make_round_program``); ``launch/specs.py``
    provides the matching abstract store specs for the dry-run.
    """
    grad_fn, cohort_kw, server_kw = _program_pieces(
        cfg, fed, placement, spmd_axes, compute_dtype, q_chunk, remat,
        use_sampling, chunk_size)
    # both stages share prepare_params; merge instead of passing it twice
    return make_round_program(grad_fn, fed, **{**cohort_kw, **server_kw})


def make_fed_round_split(
    cfg: ModelConfig,
    fed: FedConfig,
    *,
    placement: str = "parallel",
    spmd_axes: Optional[Tuple[str, ...]] = None,
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 1024,
    remat: str = "full",
    use_sampling: bool = True,
    chunk_size: Optional[int] = None,
) -> Tuple[Callable, Callable]:
    """Same wiring as ``make_fed_round`` but split into the two async-engine
    stages: ``(cohort_fn, server_fn)`` (see ``core.async_engine``)."""
    grad_fn, cohort_kw, server_kw = _program_pieces(
        cfg, fed, placement, spmd_axes, compute_dtype, q_chunk, remat,
        use_sampling, chunk_size)
    return (make_cohort_program(grad_fn, fed, **cohort_kw),
            make_server_program(fed, **server_kw))


def default_placement(cfg: ModelConfig, threshold: int = 10_000_000_000) -> str:
    """parallel for <10B-param archs (one client per data slice fits),
    sequential-FSDP otherwise."""
    return "parallel" if cfg.param_count() < threshold else "sequential"
