"""The production multi-pod federated round: one jitted SPMD program.

The paper's communication pattern — broadcast theta, K isolated local steps
per client, one O(d) delta aggregation — maps onto the TPU mesh as
(DESIGN.md §3):

  * ``parallel`` placement: clients are slices of the ("pod", "data") axes.
    The client dimension is a ``vmap`` with ``spmd_axis_name`` set to the
    client axes, so every per-client tensor (params copy, optimizer moments,
    IASG samples, DP history) shards one-client-per-data-slice, and the only
    cross-client collective is the delta mean — a single all-reduce of d
    values per round, amortized over K local steps. This is the paper's
    O(d)-communication claim made structural.

  * ``sequential`` placement (>=10B archs): clients run one after another in
    a ``lax.scan``, each using the whole mesh; the client-local batch shards
    over ("pod", "data") and all parameter-shaped state (fp32 master, client
    moments, IASG samples, DP vectors) is FSDP-sharded over data x model via
    ``fsdp_constrain``, with a bf16 all-gather at each local step's compute
    boundary (``tp_constrain``). This trades one weight all-gather per local
    step for fitting O(l d) FedPA state in HBM.

Both placements share the same client math (``make_client_update``); the
server update runs once per round on the aggregated delta.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, ModelConfig
from repro.core import tree_math as tm
from repro.core.client import make_client_update
from repro.core.server import ServerState, aggregate_deltas, server_update
from repro.models.steps import lm_grad_fn
from repro.optim import get_optimizer
from repro.sharding import fsdp_constrain, tp_constrain


def make_fed_round(
    cfg: ModelConfig,
    fed: FedConfig,
    *,
    placement: str = "parallel",
    spmd_axes: Optional[Tuple[str, ...]] = None,
    compute_dtype=jnp.bfloat16,
    q_chunk: int = 1024,
    remat: str = "full",
    use_sampling: bool = True,
) -> Callable:
    """Build ``round_fn(server_state, client_batches) -> (state, metrics)``.

    client_batches: {"tokens": (C, K, B_local, S+1) int32,
                     ["frontend": (C, K, B_local, F, d)]}.
    ``use_sampling=False`` gives the burn-in-round variant (FedAvg regime)
    of the same FedPA config — used for the first ``burn_in_rounds`` rounds.
    """
    eff_fed = fed
    if not use_sampling and fed.algorithm == "fedpa":
        eff_fed = dataclasses.replace(fed, algorithm="fedavg")

    grad_fn = lm_grad_fn(cfg, compute_dtype=compute_dtype, q_chunk=q_chunk,
                         remat=remat)
    client_opt = get_optimizer(eff_fed.client_opt, eff_fed.client_lr,
                               eff_fed.client_momentum)
    server_opt = get_optimizer(eff_fed.server_opt, eff_fed.server_lr,
                               eff_fed.server_momentum)
    client_update = make_client_update(grad_fn, eff_fed, client_opt)

    if placement == "parallel":

        def round_fn(state: ServerState, client_batches):
            vm = jax.vmap(client_update, in_axes=(None, 0),
                          spmd_axis_name=spmd_axes)
            deltas, metrics = vm(state.params, client_batches)
            mean_delta = aggregate_deltas(deltas)
            new_state = server_update(state, mean_delta, server_opt)
            return new_state, {
                "loss_first": jnp.mean(metrics["loss_first"]),
                "loss_last": jnp.mean(metrics["loss_last"]),
            }

        return round_fn

    if placement != "sequential":
        raise ValueError(f"unknown placement {placement!r}")

    def fsdp_client_update(master_params, batches):
        """One client with FSDP-sharded state; compute on gathered bf16."""
        # the all-gather boundary: compute params are tensor-parallel only
        gathered = tp_constrain(tm.tcast(master_params, compute_dtype))
        delta, metrics = client_update(gathered, batches)
        return fsdp_constrain(delta, like_params=master_params), metrics

    def round_fn(state: ServerState, client_batches):
        master = fsdp_constrain(state.params)

        def body(acc, batches):
            delta, metrics = fsdp_client_update(master, batches)
            acc = tm.tadd(acc, delta)
            return acc, metrics

        C = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
        zero = fsdp_constrain(
            tm.tzeros_like(state.params, jnp.dtype(eff_fed.delta_dtype)),
            like_params=state.params,
        )
        acc, metrics = jax.lax.scan(body, zero, client_batches)
        mean_delta = tm.tscale(1.0 / C, acc)
        new_state = server_update(state._replace(params=master), mean_delta,
                                  server_opt)
        new_state = new_state._replace(params=fsdp_constrain(new_state.params))
        return new_state, {
            "loss_first": jnp.mean(metrics["loss_first"]),
            "loss_last": jnp.mean(metrics["loss_last"]),
        }

    return round_fn


def default_placement(cfg: ModelConfig, threshold: int = 10_000_000_000) -> str:
    """parallel for <10B-param archs (one client per data slice fits),
    sequential-FSDP otherwise."""
    return "parallel" if cfg.param_count() < threshold else "sequential"
