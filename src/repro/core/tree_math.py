"""Back-compat shim: the pytree vector-space ops live at ``repro.tree_math``
(top level, import-cycle-free — repro.optim needs them without touching
repro.core's __init__)."""
from repro.tree_math import *          # noqa: F401,F403
from repro.tree_math import (          # noqa: F401
    tadd, taxpy, tcast, tdynamic_index, tdynamic_update, tindex, tmap,
    tnorm, tree_bytes, tree_size, tscale, tstack, tsub, tvdot, tzeros_like,
)
