"""Exact Gaussian posteriors for federated least squares (Section 3).

For quadratic client objectives f_i(theta) = 1/2 ||X_i theta - y_i||^2 the
local posterior is Gaussian with Sigma_i^{-1} = X_i^T X_i and
mu_i = (X_i^T X_i)^{-1} X_i^T y_i, and the global posterior mode has the
closed form of Eq. 3. These exact quantities are the oracles against which
FedPA's approximations (IASG sampling, shrinkage, DP) are validated in tests
and in the Fig. 1 / Fig. 3 benchmarks.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp


class QuadraticClient(NamedTuple):
    """One client's quadratic objective in natural-parameter form."""

    sigma_inv: jnp.ndarray   # (d, d) = X^T X  (precision)
    mu: jnp.ndarray          # (d,)   local optimum / posterior mean
    weight: jnp.ndarray      # scalar q_i

    def loss(self, theta):
        """0.5 (theta - mu)^T Sigma^{-1} (theta - mu)."""
        r = theta - self.mu
        return 0.5 * r @ self.sigma_inv @ r

    def grad(self, theta):
        """Sigma^{-1} (theta - mu) — the exact local gradient."""
        return self.sigma_inv @ (theta - self.mu)

    def exact_delta(self, theta):
        """The unbiased FedPA client update Delta_i = Sigma_i^{-1}(theta - mu_i)."""
        return self.grad(theta)


def client_from_data(X: jnp.ndarray, y: jnp.ndarray, weight=1.0,
                     ridge: float = 1e-6) -> QuadraticClient:
    """Local Gaussian posterior of a least-squares client (Eq. 2)."""
    d = X.shape[1]
    sigma_inv = X.T @ X + ridge * jnp.eye(d, dtype=X.dtype)
    mu = jnp.linalg.solve(sigma_inv, X.T @ y)
    return QuadraticClient(sigma_inv=sigma_inv, mu=mu,
                           weight=jnp.asarray(weight, X.dtype))


def global_posterior_mode(clients: Sequence[QuadraticClient]) -> jnp.ndarray:
    """Eq. 3: mu = (sum q_i Sigma_i^{-1})^{-1} (sum q_i Sigma_i^{-1} mu_i)."""
    A = sum(c.weight * c.sigma_inv for c in clients)
    b = sum(c.weight * (c.sigma_inv @ c.mu) for c in clients)
    return jnp.linalg.solve(A, b)


def global_quadratic(clients: Sequence[QuadraticClient]):
    """Proposition 2's surrogate Q(theta) = 1/2 theta^T A theta - b^T theta."""
    A = sum(c.weight * c.sigma_inv for c in clients)
    b = sum(c.weight * (c.sigma_inv @ c.mu) for c in clients)

    def Q(theta):
        return 0.5 * theta @ A @ theta - b @ theta

    def grad_Q(theta):
        return A @ theta - b

    return Q, grad_Q


def global_objective(clients: Sequence[QuadraticClient]):
    """The federated objective F(theta) = sum q_i f_i(theta) (Eq. 1)."""
    def F(theta):
        return sum(c.weight * c.loss(theta) for c in clients)
    return F


def fedavg_fixed_point(clients: Sequence[QuadraticClient],
                       local_steps: int, client_lr: float) -> jnp.ndarray:
    """Analytic fixed point of FedAvg-with-K-local-GD-steps on quadratics.

    After K local gradient steps from theta on client i, the delta is
    (I - (I - lr Sigma_i^{-1})^K)(theta - mu_i). Setting the q-weighted sum to
    zero gives the (generally suboptimal) stagnation point the paper's Fig. 1
    illustrates; tests assert FedAvg converges here and that it differs from
    ``global_posterior_mode`` while FedPA's bias vanishes.
    """
    d = clients[0].mu.shape[0]
    eye = jnp.eye(d, dtype=clients[0].mu.dtype)
    A = jnp.zeros((d, d), clients[0].mu.dtype)
    b = jnp.zeros((d,), clients[0].mu.dtype)
    for c in clients:
        m = eye - jnp.linalg.matrix_power(eye - client_lr * c.sigma_inv,
                                          local_steps)
        A = A + c.weight * m
        b = b + c.weight * (m @ c.mu)
    return jnp.linalg.solve(A, b)
