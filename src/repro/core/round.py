"""Single-process federated simulation (the paper's experimental regime).

Drives Algorithm 1 with a Python loop over rounds and jitted client updates;
used by the convergence tests, the Fig. 1 / Table 3 benchmarks, and the
small examples. The production multi-pod path is ``sharded_round.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.core.client import make_client_update
from repro.core.server import (ServerState, aggregate_deltas_list,
                               init_server_state, server_update)
from repro.data.sampling import ClientSampler
from repro.optim import get_optimizer


@dataclasses.dataclass
class FedSim:
    """Generic federated simulation.

    batch_fn(client_id, round_idx, num_steps) -> batches pytree with leading
    step axis; grad_fn(params, batch) -> (loss, grads).
    """

    fed: FedConfig
    grad_fn: Callable
    batch_fn: Callable
    num_clients: int
    client_weights: Optional[np.ndarray] = None
    seed: int = 0

    def __post_init__(self):
        self.sampler = ClientSampler(self.num_clients,
                                     self.fed.clients_per_round, self.seed)
        self.server_opt = get_optimizer(self.fed.server_opt,
                                        self.fed.server_lr,
                                        self.fed.server_momentum)
        client_opt = get_optimizer(self.fed.client_opt, self.fed.client_lr,
                                   self.fed.client_momentum)
        self._update = jax.jit(
            make_client_update(self.grad_fn, self.fed, client_opt)
        )
        # burn-in rounds run the FedAvg-regime update (Section 5.2)
        if self.fed.algorithm == "fedpa" and self.fed.burn_in_rounds > 0:
            avg = dataclasses.replace(self.fed, algorithm="fedavg")
            self._burn_update = jax.jit(
                make_client_update(self.grad_fn, avg, client_opt)
            )
        else:
            self._burn_update = self._update

    def init(self, params) -> ServerState:
        return init_server_state(params, self.server_opt)

    def _server_momentum(self, state: ServerState):
        """Frozen server statistics shipped to MIME clients."""
        opt = state.opt_state
        if isinstance(opt, dict) and "m" in opt:
            return opt["m"]
        import repro.tree_math as tm
        return tm.tzeros_like(state.params)

    def round(self, state: ServerState, round_idx: int):
        client_ids = self.sampler.sample(round_idx)
        update = (self._burn_update if round_idx < self.fed.burn_in_rounds
                  else self._update)
        extra = ((self._server_momentum(state),)
                 if self.fed.algorithm == "mime" else ())
        deltas, losses = [], []
        for cid in client_ids:
            batches = self.batch_fn(int(cid), round_idx, self.fed.local_steps)
            delta, m = update(state.params, batches, *extra)
            deltas.append(delta)
            losses.append(float(m["loss_last"]))
        weights = (None if self.client_weights is None
                   else [self.client_weights[int(c)] for c in client_ids])
        mean_delta = aggregate_deltas_list(deltas, weights)
        state = server_update(state, mean_delta, self.server_opt)
        return state, {"client_loss": float(np.mean(losses))}

    def run(self, params, num_rounds: int,
            eval_fn: Optional[Callable] = None, eval_every: int = 1):
        state = self.init(params)
        history: List[dict] = []
        for r in range(num_rounds):
            state, metrics = self.round(state, r)
            if eval_fn is not None and (r % eval_every == 0
                                        or r == num_rounds - 1):
                metrics = {**metrics, **eval_fn(state.params)}
            metrics["round"] = r
            history.append(metrics)
        return state, history
