"""Single-process federated simulation (the paper's experimental regime).

Drives Algorithm 1 on top of the unified compiled round engine
(``round_program.make_round_program``): the host loop only samples client
ids and stacks their batches — the whole round (cohort of client updates,
weighted aggregation, server step) is ONE jitted XLA program per round
configuration, not one dispatch per client. The production multi-pod path
(``sharded_round.py``) builds on the same engine.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.core.round_program import make_round_program
from repro.core.server import ServerState, init_server_state
from repro.core.tree_math import tstack
from repro.data.sampling import ClientSampler
from repro.optim import get_optimizer


@dataclasses.dataclass
class FedSim:
    """Generic federated simulation.

    batch_fn(client_id, round_idx, num_steps) -> batches pytree with leading
    step axis; grad_fn(params, batch) -> (loss, grads).

    ``placement`` overrides ``fed.round_placement`` ("parallel" |
    "sequential" | "chunked") — the round math is identical across all
    three (tests/test_round_engine.py); only the compiled layout differs.
    """

    fed: FedConfig
    grad_fn: Callable
    batch_fn: Callable
    num_clients: int
    client_weights: Optional[np.ndarray] = None
    seed: int = 0
    placement: Optional[str] = None

    def __post_init__(self):
        self.sampler = ClientSampler(self.num_clients,
                                     self.fed.clients_per_round, self.seed)
        self.server_opt = get_optimizer(self.fed.server_opt,
                                        self.fed.server_lr,
                                        self.fed.server_momentum)

        def build(use_sampling: bool):
            return jax.jit(make_round_program(
                self.grad_fn, self.fed, placement=self.placement,
                server_opt=self.server_opt, use_sampling=use_sampling,
            ))

        self._round = build(use_sampling=True)
        # burn-in rounds run the FedAvg-regime update (Section 5.2)
        if self.fed.algorithm == "fedpa" and self.fed.burn_in_rounds > 0:
            self._burn_round = build(use_sampling=False)
        else:
            self._burn_round = self._round

    def init(self, params) -> ServerState:
        return init_server_state(params, self.server_opt)

    def stack_cohort(self, client_ids, round_idx: int):
        """Materialize the cohort's batches with a leading client axis."""
        return tstack([
            self.batch_fn(int(cid), round_idx, self.fed.local_steps)
            for cid in client_ids
        ])

    def round(self, state: ServerState, round_idx: int):
        client_ids = self.sampler.sample(round_idx)
        round_fn = (self._burn_round if round_idx < self.fed.burn_in_rounds
                    else self._round)
        batches = self.stack_cohort(client_ids, round_idx)
        weights = (None if self.client_weights is None
                   else np.asarray([self.client_weights[int(c)]
                                    for c in client_ids], np.float32))
        state, metrics = round_fn(state, batches, weights)
        return state, {"client_loss": float(metrics["loss_last"])}

    def run(self, params, num_rounds: int,
            eval_fn: Optional[Callable] = None, eval_every: int = 1):
        state = self.init(params)
        history: List[dict] = []
        for r in range(num_rounds):
            state, metrics = self.round(state, r)
            if eval_fn is not None and (r % eval_every == 0
                                        or r == num_rounds - 1):
                metrics = {**metrics, **eval_fn(state.params)}
            metrics["round"] = r
            history.append(metrics)
        return state, history
