"""Single-process federated simulation (the paper's experimental regime).

``FedSim`` is a thin frontend over the unified staleness-general
``core.engine.RoundEngine``: it resolves the config into programs (the
fused ``make_round_program`` round plus the split
``make_cohort_program`` / ``make_server_program`` stages), builds the
client-state store and the fault-injecting ``CohortSource``, and hands
everything to the one round loop. The host side only samples client ids
and stacks their batches — the whole round (cohort of client updates,
weighted aggregation, server step) is jitted XLA, not one dispatch per
client. Execution modes (both driven by the same engine loop):

  * synchronous (default): in-flight window of 1, single-dispatch fused
    round, with the cohort optionally stacked one round ahead on a
    background thread (``fed.prefetch_rounds > 0``);
  * async (``fed.async_rounds=True``): up to ``fed.max_staleness``
    cohorts in flight beyond the one being applied — cohort t+1's client
    compute overlaps round t's server update, deltas down-weighted by
    ``staleness_discount**staleness``; ``max_staleness=0`` reproduces
    the sync path (bitwise when no stragglers are configured — straggler
    lateness forces the split pipeline for the discount exponent).

The production multi-pod path (``sharded_round.py``) builds on the same
programs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.configs.base import FedConfig
from repro.core.client_state import make_client_store
from repro.core.engine import RoundEngine
from repro.core.round_program import (make_cohort_program,
                                      make_round_program,
                                      make_server_program)
from repro.core.server import ServerState, init_server_state
from repro.data.cohort_source import CohortSource
from repro.data.prefetch import Cohort, stack_host
from repro.optim import get_optimizer


@dataclasses.dataclass
class FedSim:
    """Generic federated simulation.

    batch_fn(client_id, round_idx, num_steps) -> batches pytree with leading
    step axis; grad_fn(params, batch) -> (loss, grads).

    ``placement`` overrides ``fed.round_placement`` ("parallel" |
    "sequential" | "chunked") — the round math is identical across all
    three (tests/test_round_engine.py); only the compiled layout differs.

    ``mesh`` (optional) makes the population axis a sharded dimension: the
    device client-state store is ``NamedSharding``-placed over the mesh's
    client axes (``population_layout``; padded, never replicated) and the
    engine pins the round's store output to that placement so the donated
    update aliases shard-for-shard. ``spmd_axes`` additionally names the
    mesh axes the parallel/chunked placements vmap over
    (``spmd_axis_name``), mapping each chunk to a mesh slice. Neither
    changes the round math — sharded vs replicated rounds are bitwise
    identical (tests/test_population_sharding.py).
    """

    fed: FedConfig
    grad_fn: Callable
    batch_fn: Callable
    num_clients: int
    client_weights: Optional[np.ndarray] = None
    seed: int = 0
    placement: Optional[str] = None
    mesh: Optional[object] = None
    spmd_axes: Optional[tuple] = None

    def __post_init__(self):
        """Resolve the config: round programs, store, cohort source."""
        self.source = CohortSource(self.fed, self.num_clients,
                                   self.stack_cohort, self.client_weights,
                                   self.seed)
        # ClientSampler API parity (the source delegates to the same
        # stream, so zero-fault cohorts are bitwise ClientSampler's)
        self.sampler = self.source.sampler
        self.server_opt = get_optimizer(self.fed.server_opt,
                                        self.fed.server_lr,
                                        self.fed.server_momentum)

        from repro.algorithms import get_algorithm  # noqa: PLC0415 — cycle

        self._state_placement = self.fed.client_state_placement
        # per-client persistent state (SCAFFOLD/FedEP): host or device
        # store per fed.client_state_placement; host gathers/scatters at
        # the round edges, device threads its buffers through the jit —
        # population-sharded over self.mesh when one is given
        self._alg = get_algorithm(self.fed)
        # burn-in rounds run the algorithm's burn regime, e.g. FedPA's
        # FedAvg regime (Section 5.2)
        self._has_burn_regime = (self._alg.has_burn_regime
                                 and self.fed.burn_in_rounds > 0)
        self._stateful = self._alg.stateful
        self._burn_stateful = (self._alg.burn_algorithm().stateful
                               if self._has_burn_regime else self._stateful)
        self.client_store = (
            make_client_store(self._state_placement, self.num_clients,
                              mesh=(self.mesh
                                    if self._state_placement == "device"
                                    else None))
            if self._stateful or self._burn_stateful else None)
        self._engine: Optional[RoundEngine] = None
        # per-round communicated bytes, computed once a params template is
        # seen (init); stamped on every history record by the engine
        self._round_bytes: Optional[dict] = None
        self._burn_round_bytes: Optional[dict] = None

    def init(self, params) -> ServerState:
        """Fresh server state (and, for stateful algorithms, a freshly
        zeroed client-state store — each ``run`` starts from scratch)."""
        if self.client_store is not None:
            self.client_store.ensure(
                self._alg.init_client_state(params)).reset()
        from repro.compression import round_bytes  # noqa: PLC0415 — cycle
        self._round_bytes = round_bytes(self.fed, params, use_sampling=True)
        self._burn_round_bytes = (
            round_bytes(self.fed, params, use_sampling=False)
            if self._has_burn_regime else self._round_bytes)
        return init_server_state(params, self.server_opt,
                                 algorithm=self._alg)

    def stack_cohort(self, client_ids, round_idx: int):
        """Materialize the cohort's batches with a leading client axis.

        Stacks on the host (numpy) so the work can run on the prefetch
        thread without contending for the device dispatch stream; the
        stacked cohort transfers once, when the round program consumes it.
        """
        return stack_host([
            self.batch_fn(int(cid), round_idx, self.fed.local_steps)
            for cid in client_ids
        ])

    def cohort(self, round_idx: int) -> Cohort:
        """Sample and materialize one round's inputs (the host-side work the
        prefetcher runs ahead of the round loop) — delegated to the
        fault-injecting ``CohortSource`` (fault-free configs reproduce the
        old sampler's cohorts bitwise)."""
        return self.source.cohort(round_idx)

    def round(self, state: ServerState, round_idx: int,
              cohort: Optional[Cohort] = None):
        """One synchronous round via the engine's fused one-shot API;
        returns ``(state, record)`` with the uniform-schema record already
        converted to plain Python."""
        cohort = cohort if cohort is not None else self.cohort(round_idx)
        return self.engine.round(state, cohort, round_idx)

    def run(self, params, num_rounds: int,
            eval_fn: Optional[Callable] = None, eval_every: int = 1):
        """Drive ``num_rounds`` rounds from fresh state; returns
        ``(final_state, history)`` (sync or async per ``fed.async_rounds``
        — one engine loop either way)."""
        state = self.init(params)
        return self.engine.run(state, self.cohort, num_rounds,
                               eval_fn=eval_fn, eval_every=eval_every)

    @property
    def engine(self) -> RoundEngine:
        """Built once (lazily, after ``init`` has seen a params template
        for the byte accounting) so the engine's jit caches survive
        repeated ``run()``s."""
        if self._engine is None:
            self._engine = self._build_engine()
        return self._engine

    def _build_engine(self) -> RoundEngine:
        def fused(use_sampling: bool):
            return make_round_program(
                self.grad_fn, self.fed, placement=self.placement,
                spmd_axes=self.spmd_axes,
                server_opt=self.server_opt, use_sampling=use_sampling)

        def split(use_sampling: bool):
            return (make_cohort_program(
                        self.grad_fn, self.fed, placement=self.placement,
                        spmd_axes=self.spmd_axes,
                        server_opt=self.server_opt,
                        use_sampling=use_sampling),
                    # a burn regime may aggregate in a different payload
                    # space (fedpa_precision burns in as fedavg), so it
                    # gets its own server stage too
                    make_server_program(self.fed, server_opt=self.server_opt,
                                        use_sampling=use_sampling))

        cohort_fn, server_fn = split(use_sampling=True)
        burn_cohort_fn = burn_server_fn = None
        if self._has_burn_regime:
            burn_cohort_fn, burn_server_fn = split(use_sampling=False)
        return RoundEngine(
            cohort_fn=cohort_fn,
            server_fn=server_fn,
            round_fn=fused(use_sampling=True),
            burn_cohort_fn=burn_cohort_fn,
            burn_server_fn=burn_server_fn,
            burn_round_fn=(fused(use_sampling=False)
                           if self._has_burn_regime else None),
            burn_in_rounds=self.fed.burn_in_rounds,
            max_staleness=(self.fed.max_staleness if self.fed.async_rounds
                           else 0),
            staleness_discount=self.fed.staleness_discount,
            # straggler lateness needs the apply-time discount exponent,
            # which only the split pipeline traces
            pipeline_only=self.fed.straggler_rate > 0,
            prefetch_rounds=self.fed.prefetch_rounds,
            prefetch_backend=self.fed.prefetch_backend,
            client_store=self.client_store,
            stateful=self._stateful,
            burn_stateful=self._burn_stateful,
            record_faults=self.fed.fault_injection,
            round_bytes=self._round_bytes,
            burn_round_bytes=self._burn_round_bytes,
        )
