"""Single-process federated simulation (the paper's experimental regime).

Drives Algorithm 1 on top of the unified compiled round engine
(``round_program``): the host loop only samples client ids and stacks their
batches — the whole round (cohort of client updates, weighted aggregation,
server step) is ONE jitted XLA program per round configuration, not one
dispatch per client. Two execution modes:

  * synchronous (default): the fused ``make_round_program`` round, with the
    cohort optionally stacked one round ahead on a background thread
    (``fed.prefetch_rounds > 0``);
  * async (``fed.async_rounds=True``): the double-buffered
    ``core.async_engine`` pipeline — cohort t+1's client compute overlaps
    round t's server update, deltas down-weighted by
    ``staleness_discount**staleness``; ``max_staleness=0`` reproduces the
    sync path numerically.

The production multi-pod path (``sharded_round.py``) builds on the same
engine.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.core.async_engine import AsyncRoundEngine
from repro.core.client_state import jit_donating_store, make_client_store
from repro.core.history import json_scalar
from repro.core.round_program import (make_cohort_program,
                                      make_round_program,
                                      make_server_program)
from repro.core.server import ServerState, init_server_state
from repro.data.cohort_source import CohortSource
from repro.data.prefetch import (Cohort, close_prefetcher, make_prefetcher,
                                 stack_host)
from repro.optim import get_optimizer


@dataclasses.dataclass
class FedSim:
    """Generic federated simulation.

    batch_fn(client_id, round_idx, num_steps) -> batches pytree with leading
    step axis; grad_fn(params, batch) -> (loss, grads).

    ``placement`` overrides ``fed.round_placement`` ("parallel" |
    "sequential" | "chunked") — the round math is identical across all
    three (tests/test_round_engine.py); only the compiled layout differs.

    ``mesh`` (optional) makes the population axis a sharded dimension: the
    device client-state store is ``NamedSharding``-placed over the mesh's
    client axes (``population_layout``; padded, never replicated) and both
    engines pin the round's store output to that placement so the donated
    update aliases shard-for-shard. ``spmd_axes`` additionally names the
    mesh axes the parallel/chunked placements vmap over
    (``spmd_axis_name``), mapping each chunk to a mesh slice. Neither
    changes the round math — sharded vs replicated rounds are bitwise
    identical (tests/test_population_sharding.py).
    """

    fed: FedConfig
    grad_fn: Callable
    batch_fn: Callable
    num_clients: int
    client_weights: Optional[np.ndarray] = None
    seed: int = 0
    placement: Optional[str] = None
    mesh: Optional[object] = None
    spmd_axes: Optional[tuple] = None

    def __post_init__(self):
        """Build (and jit) the round programs and the client-state store."""
        self.source = CohortSource(self.fed, self.num_clients,
                                   self.stack_cohort, self.client_weights,
                                   self.seed)
        # ClientSampler API parity (the source delegates to the same
        # stream, so zero-fault cohorts are bitwise ClientSampler's)
        self.sampler = self.source.sampler
        self.server_opt = get_optimizer(self.fed.server_opt,
                                        self.fed.server_lr,
                                        self.fed.server_momentum)

        from repro.algorithms import (get_algorithm,  # noqa: PLC0415 — cycle
                                      resolve_algorithm)

        self._state_placement = self.fed.client_state_placement
        # per-client persistent state (SCAFFOLD/FedEP): host or device
        # store per fed.client_state_placement; host gathers/scatters at
        # the round edges, device threads its buffers through the jit —
        # population-sharded over self.mesh when one is given
        alg = get_algorithm(self.fed)
        stateful = alg.stateful or (alg.has_burn_regime
                                    and self.fed.burn_in_rounds > 0
                                    and alg.burn_algorithm().stateful)
        self.client_store = (
            make_client_store(self._state_placement, self.num_clients,
                              mesh=(self.mesh
                                    if self._state_placement == "device"
                                    else None))
            if stateful else None)

        def build(use_sampling: bool):
            round_fn = make_round_program(
                self.grad_fn, self.fed, placement=self.placement,
                spmd_axes=self.spmd_axes,
                server_opt=self.server_opt, use_sampling=use_sampling,
            )
            if (resolve_algorithm(self.fed, use_sampling).stateful
                    and self._state_placement == "device"):
                # round_fn(state, batches, weights, store_state, ids):
                # donate the store so the (N, ...) buffers update in
                # place, pinned to the store's own population sharding so
                # the alias is shard-for-shard
                out_sh = None
                if self.client_store.population_sharding is not None:
                    out_sh = (None, None,
                              self.client_store.population_sharding)
                return jit_donating_store(round_fn, 3, out_shardings=out_sh)
            return jax.jit(round_fn)

        self._alg = get_algorithm(self.fed)
        self._round = build(use_sampling=True)
        # burn-in rounds run the algorithm's burn regime, e.g. FedPA's
        # FedAvg regime (Section 5.2)
        self._has_burn_regime = (self._alg.has_burn_regime
                                 and self.fed.burn_in_rounds > 0)
        if self._has_burn_regime:
            self._burn_round = build(use_sampling=False)
        else:
            self._burn_round = self._round
        self._stateful = self._alg.stateful
        self._burn_stateful = (self._alg.burn_algorithm().stateful
                               if self._has_burn_regime else self._stateful)
        self._engine: Optional[AsyncRoundEngine] = None
        # per-round communicated bytes, computed once a params template is
        # seen (init); stamped on every history record by both engines
        self._round_bytes: Optional[dict] = None
        self._burn_round_bytes: Optional[dict] = None

    def init(self, params) -> ServerState:
        """Fresh server state (and, for stateful algorithms, a freshly
        zeroed client-state store — each ``run`` starts from scratch)."""
        if self.client_store is not None:
            self.client_store.ensure(
                self._alg.init_client_state(params)).reset()
        from repro.compression import round_bytes  # noqa: PLC0415 — cycle
        self._round_bytes = round_bytes(self.fed, params, use_sampling=True)
        self._burn_round_bytes = (
            round_bytes(self.fed, params, use_sampling=False)
            if self._has_burn_regime else self._round_bytes)
        return init_server_state(params, self.server_opt,
                                 algorithm=self._alg)

    def stack_cohort(self, client_ids, round_idx: int):
        """Materialize the cohort's batches with a leading client axis.

        Stacks on the host (numpy) so the work can run on the prefetch
        thread without contending for the device dispatch stream; the
        stacked cohort transfers once, when the round program consumes it.
        """
        return stack_host([
            self.batch_fn(int(cid), round_idx, self.fed.local_steps)
            for cid in client_ids
        ])

    def cohort(self, round_idx: int) -> Cohort:
        """Sample and materialize one round's inputs (the host-side work the
        prefetcher runs ahead of the round loop) — delegated to the
        fault-injecting ``CohortSource`` (fault-free configs reproduce the
        old sampler's cohorts bitwise)."""
        return self.source.cohort(round_idx)

    def round(self, state: ServerState, round_idx: int,
              cohort: Optional[Cohort] = None):
        """One synchronous round; stateful algorithms additionally thread
        the cohort's client state through the jitted round — gathered and
        scattered at the host edges for the host store, or passed as the
        store's device buffers (+ the cohort ids) with the gather/CAS
        scatter fused into the program for the device store."""
        cohort = cohort if cohort is not None else self.cohort(round_idx)
        is_burn = round_idx < self.fed.burn_in_rounds
        round_fn = self._burn_round if is_burn else self._round
        stateful = (self._burn_stateful
                    if is_burn and self._has_burn_regime else self._stateful)
        survivors = cohort.survivors   # None traces the mask-free program
        if stateful and self._state_placement == "device":
            ids = self.client_store.prepare_ids(cohort.client_ids)
            state, metrics, new_store = round_fn(
                state, cohort.batches, cohort.weights,
                self.client_store.device_state(), ids, survivors)
            self.client_store.set_device_state(new_store)
        elif stateful:
            cstates, stamps = self.client_store.gather(cohort.client_ids)
            state, metrics, new_states = round_fn(
                state, cohort.batches, cohort.weights, cstates, survivors)
            # a dropped client's half-finished state must not land
            self.client_store.scatter(cohort.client_ids, new_states, stamps,
                                      write_mask=survivors)
        else:
            state, metrics = round_fn(state, cohort.batches, cohort.weights,
                                      survivors)
        loss_first = float(metrics["loss_first"])
        loss_last = float(metrics["loss_last"])
        record = {"client_loss": loss_last, "loss_first": loss_first,
                  "loss_last": loss_last}
        bts = (self._burn_round_bytes if is_burn and self._has_burn_regime
               else self._round_bytes)
        if bts is not None:
            record["bytes_up"] = json_scalar(bts["bytes_up"])
            record["bytes_down"] = json_scalar(bts["bytes_down"])
        if survivors is not None:
            record["dropped"] = int(cohort.dropped)
        return state, record

    def run(self, params, num_rounds: int,
            eval_fn: Optional[Callable] = None, eval_every: int = 1):
        """Drive ``num_rounds`` rounds from fresh state; returns
        ``(final_state, history)`` (sync or async per ``fed.async_rounds``)."""
        if eval_fn is not None and eval_every < 1:
            raise ValueError(
                f"eval_every must be >= 1 when eval_fn is set, got "
                f"{eval_every} (evaluate every round with eval_every=1, or "
                f"pass eval_fn=None to disable evaluation)")
        state = self.init(params)
        if self.fed.async_rounds:
            return self._run_async(state, num_rounds, eval_fn, eval_every)

        prefetch = (make_prefetcher(self.fed.prefetch_backend, self.cohort,
                                    0, num_rounds,
                                    depth=self.fed.prefetch_rounds)
                    if self.fed.prefetch_rounds > 0 else None)
        history: List[dict] = []
        completed = False
        try:
            for r in range(num_rounds):
                cohort = prefetch.get(r) if prefetch is not None else None
                state, metrics = self.round(state, r, cohort)
                if eval_fn is not None and (r % eval_every == 0
                                            or r == num_rounds - 1):
                    # eval metrics may be device arrays: convert here so
                    # history stays JSON-serializable (the sync path's twin
                    # of the async engine's end-of-loop conversion)
                    metrics = {**metrics,
                               **{k: json_scalar(v)
                                  for k, v in eval_fn(state.params).items()}}
                metrics["round"] = r
                history.append(metrics)
            completed = True
        finally:
            if prefetch is not None:
                # loud on a clean exit, a warning when the round loop is
                # already propagating its own exception
                close_prefetcher(prefetch, unwinding=not completed)
        return state, history

    def _run_async(self, state: ServerState, num_rounds: int,
                   eval_fn: Optional[Callable], eval_every: int):
        engine = self._async_engine
        return engine.run(state, self.cohort, num_rounds,
                          eval_fn=eval_fn, eval_every=eval_every)

    @property
    def _async_engine(self) -> AsyncRoundEngine:
        """Built once so the engine's jit caches survive repeated run()s."""
        if self._engine is None:
            self._engine = self._build_async_engine()
        return self._engine

    def _build_async_engine(self) -> AsyncRoundEngine:
        return AsyncRoundEngine(
            cohort_fn=make_cohort_program(
                self.grad_fn, self.fed, placement=self.placement,
                spmd_axes=self.spmd_axes,
                server_opt=self.server_opt, use_sampling=True),
            server_fn=make_server_program(self.fed,
                                          server_opt=self.server_opt),
            burn_cohort_fn=(make_cohort_program(
                self.grad_fn, self.fed, placement=self.placement,
                spmd_axes=self.spmd_axes,
                server_opt=self.server_opt, use_sampling=False)
                if self._has_burn_regime else None),
            # the burn regime may aggregate in a different payload space
            # (fedpa_precision burns in as fedavg), so it gets its own
            # server stage too
            burn_server_fn=(make_server_program(
                self.fed, server_opt=self.server_opt, use_sampling=False)
                if self._has_burn_regime else None),
            burn_in_rounds=self.fed.burn_in_rounds,
            max_staleness=self.fed.max_staleness,
            staleness_discount=self.fed.staleness_discount,
            prefetch_rounds=self.fed.prefetch_rounds,
            prefetch_backend=self.fed.prefetch_backend,
            client_store=self.client_store,
            stateful=self._stateful,
            burn_stateful=self._burn_stateful,
            record_faults=self.fed.fault_injection,
            round_bytes=self._round_bytes,
            burn_round_bytes=self._burn_round_bytes,
        )
