"""Client updates (Algorithms 2 and 3) — back-compat frontend.

The client math now lives in the ``repro.algorithms`` strategy API (one
registered ``FedAlgorithm`` per algorithm, including the streaming-DP FedPA
variant and MIME); this module keeps the historical
``make_client_update(grad_fn, fed, client_opt)`` entry point that tests and
benchmarks drive directly. The returned update is a pure function suitable
for ``vmap`` (parallel clients) or ``scan`` (sequential clients) inside one
jitted federated round — clients are stateless across rounds, as the
cross-device setting requires.
"""
from __future__ import annotations

from typing import Callable

from repro.configs.base import FedConfig
from repro.optim import Optimizer


def make_client_update(grad_fn: Callable, fed: FedConfig,
                       client_opt: Optimizer) -> Callable:
    """Returns ``update(params, batches, *extras) -> ClientResult``.

    ``batches``: pytree with leading axis ``fed.local_steps``. The result
    is a ``(payload, metrics, state_update)`` NamedTuple — read it by
    attribute (``res.payload``, ``res.metrics``); the third field exists
    for stateful algorithms, so the historical 2-tuple unpacking no longer
    works. For the mean-delta algorithms the payload is the delta pytree —
    a *pseudo-gradient*: the server optimizer treats it exactly like a
    stochastic gradient of the global objective (Proposition 2).
    """
    from repro.algorithms import get_algorithm  # noqa: PLC0415 — cycle

    return get_algorithm(fed).make_client_update(grad_fn, client_opt)
