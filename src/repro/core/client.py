"""Client updates (Algorithms 2 and 3).

FedAvg: K local SGD steps, delta = theta_0 - theta_K (identity covariance —
the biased special case). FedPA: IASG posterior sampling + shrinkage-DP
delta. Both return (delta, diagnostics) and are pure functions suitable for
``vmap`` (parallel clients) or ``scan`` (sequential clients) inside one
jitted federated round — clients are stateless across rounds, as the
cross-device setting requires.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import tree_math as tm
from repro.core.dp_delta import (dp_delta, fedavg_delta, online_dp_delta,
                                 online_dp_init, online_dp_update)
from repro.core.iasg import iasg_sample, sgd_steps
from repro.optim import Optimizer


def make_client_update(grad_fn: Callable, fed: FedConfig,
                       client_opt: Optimizer) -> Callable:
    """Returns ``update(params, batches) -> (delta, metrics)``.

    ``batches``: pytree with leading axis ``fed.local_steps``.
    The delta is a *pseudo-gradient*: the server optimizer treats it exactly
    like a stochastic gradient of the global objective (Proposition 2).
    """
    delta_dtype = jnp.dtype(fed.delta_dtype)

    if fed.algorithm == "fedavg":

        def update(params, batches):
            opt_state = client_opt.init(params)
            final, _, losses = sgd_steps(params, client_opt, opt_state,
                                         grad_fn, batches)
            delta = tm.tcast(fedavg_delta(params, final), delta_dtype)
            return delta, {"loss_first": losses[0], "loss_last": losses[-1]}

        return update

    if fed.algorithm == "mime":
        return make_mime_client_update(grad_fn, fed, client_opt,
                                       delta_dtype=delta_dtype)

    if fed.streaming_dp:
        return _make_streaming_fedpa_update(grad_fn, fed, client_opt,
                                            delta_dtype)

    def update(params, batches):
        opt_state = client_opt.init(params)
        res = iasg_sample(
            params, client_opt, opt_state, grad_fn, batches,
            burn_in_steps=fed.burn_in_steps,
            steps_per_sample=fed.steps_per_sample,
            num_samples=fed.num_samples,
            sample_dtype=delta_dtype,
        )
        # dp_delta's fp32 scalar coefficients promote bf16 leaves to fp32
        # (jnp weak-typing); pin the configured dtype so scan carries match
        delta = tm.tcast(
            dp_delta(tm.tcast(params, delta_dtype), res.samples,
                     fed.shrinkage_rho),
            delta_dtype,
        )
        first = res.burn_in_losses[0] if fed.burn_in_steps else \
            res.sample_losses[0, 0]
        return delta, {"loss_first": first,
                       "loss_last": res.sample_losses[-1, -1]}

    return update


def _make_streaming_fedpa_update(grad_fn, fed: FedConfig,
                                 client_opt: Optimizer, delta_dtype):
    """FedPA with the online/any-time DP (Appendix C): each IASG sample is
    absorbed into the Sherman-Morrison state as soon as its window closes —
    the l x d stacked-sample buffer never exists. Numerically identical to
    the batch DP (tests/test_streaming_client.py)."""
    ell = fed.num_samples
    rho = fed.shrinkage_rho
    K_s = fed.steps_per_sample

    def update(params, batches):
        opt_state = client_opt.init(params)
        split = lambda tree, a, b: tm.tmap(lambda x: x[a:b], tree)
        p, s = params, opt_state
        loss_first = None
        if fed.burn_in_steps:
            p, s, burn = sgd_steps(p, client_opt, s, grad_fn,
                                   split(batches, 0, fed.burn_in_steps))
            loss_first = burn[0]
        windows = tm.tmap(
            lambda x: x[fed.burn_in_steps:].reshape(
                (ell, K_s) + x.shape[1:]),
            batches,
        )
        dp0 = online_dp_init(tm.tcast(params, delta_dtype), ell,
                             dtype=delta_dtype)

        def window(carry, wb):
            p, s, dp = carry

            def step(inner, batch):
                p, s, acc = inner
                loss, grads = grad_fn(p, batch)
                upd, s = client_opt.update(grads, s, p)
                p = tm.tmap(lambda pi, u: pi + u.astype(pi.dtype), p, upd)
                acc = tm.tmap(lambda a, pi: a + pi.astype(delta_dtype),
                              acc, p)
                return (p, s, acc), loss

            acc0 = tm.tzeros_like(p, delta_dtype)
            (p, s, acc), losses = jax.lax.scan(step, (p, s, acc0), wb)
            sample = tm.tscale(1.0 / K_s, acc)
            dp = online_dp_update(dp, sample, rho)
            return (p, s, dp), losses

        (p, s, dp), losses = jax.lax.scan(window, (p, s, dp0), windows)
        delta = tm.tcast(online_dp_delta(dp, rho), delta_dtype)
        first = loss_first if loss_first is not None else losses[0, 0]
        return delta, {"loss_first": first, "loss_last": losses[-1, -1]}

    return update


def make_mime_client_update(grad_fn, fed: FedConfig,
                            client_opt: Optimizer,
                            delta_dtype=jnp.float32):
    """MIME-lite (Karimireddy et al. 2020) — the paper's strongest stateless
    baseline: clients mix a FROZEN server momentum estimate into every local
    step (theta <- theta - lr[(1-beta) g + beta m_server]) plus the SVRG-style
    control variate g(theta_k) - g(theta_0) + g_full(theta_0), where the
    full-batch gradient at theta_0 is estimated from the round's batches.

    Returns ``update(params, batches, server_m) -> (delta, metrics)`` —
    note the extra server-statistics argument (MIME's defining feature).
    """
    beta = fed.mime_beta
    lr = fed.client_lr

    def update(params, batches, server_m):
        # control-variate anchor: mean gradient at theta_0 over the round
        def accum(carry, batch):
            _, g = grad_fn(params, batch)
            return tm.tadd(carry, g), None

        K = jax.tree_util.tree_leaves(batches)[0].shape[0]
        gsum, _ = jax.lax.scan(accum, tm.tzeros_like(params), batches)
        g_anchor = tm.tscale(1.0 / K, gsum)

        def step(carry, batch):
            p = carry
            loss, g = grad_fn(p, batch)
            _, g0 = grad_fn(params, batch)   # same minibatch at theta_0
            g_corr = tm.tmap(lambda a, b, c: a - b + c, g, g0, g_anchor)
            d = tm.tmap(lambda gi, mi: (1.0 - beta) * gi + beta * mi,
                        g_corr, server_m)
            p = tm.tmap(lambda pi, di: pi - lr * di.astype(pi.dtype), p, d)
            return p, loss

        p, losses = jax.lax.scan(step, params, batches)
        delta = tm.tcast(fedavg_delta(params, p), delta_dtype)
        return delta, {"loss_first": losses[0], "loss_last": losses[-1]}

    return update
