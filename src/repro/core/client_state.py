"""Per-client persistent state for stateful federated algorithms.

The paper's template assumes stateless clients, but its stateful cousins —
SCAFFOLD-style control variates and the per-client site parameters of
EP-based posterior inference (Guo et al. 2023) — need a statistic that
persists *on the server, per client, across rounds*. Two interchangeable
stores give that statistic a home (``FedConfig.client_state_placement``),
both subclasses of :class:`BaseClientStateStore` (shared population
validation, lazy ``ensure`` allocation, write-stamp CAS contract):

  * :class:`ClientStateStore` (``"host"``, the default) — dense numpy
    buffers with a leading ``num_clients`` axis, mirroring one per-client
    state pytree (``FedAlgorithm.init_client_state``), lazily allocated the
    first time a template is available. ``gather(client_ids)`` slices one
    cohort's states (and records a per-client write stamp) for the jitted
    round program to consume; ``scatter(client_ids, updates, stamps)``
    writes the cohort's ``ClientResult.state_update`` back with
    compare-and-swap semantics: a write is applied only if the client's
    state was not updated since the matching gather. Under the async
    engine two in-flight cohorts can overlap on a client; the cohort
    applied second gathered *before* the first one wrote, so its stale
    write is dropped — an applied update is never silently clobbered by a
    writer that did not see it. The scatter pulls the stacked updates to
    the host: the one blocking device sync a stateful round pays that a
    stateless one does not.

  * :class:`DeviceClientStateStore` (``"device"``) — the same dense
    ``(num_clients, ...)`` buffers and write stamps as device arrays, with
    the gather (``buffers[ids]``) and CAS scatter (``jnp.where``-masked
    ``.at[ids].set``, stamps compared and bumped on device) traced *inside*
    the jitted round programs via :func:`device_gather` /
    :func:`device_scatter`: the cohort's ``client_ids`` become a traced
    argument, state traffic never leaves the accelerator, and the store's
    buffers are donated to the round (:func:`jit_donating_store`) so the
    update happens in place. The per-round host sync is gone; data only
    crosses to the host in :meth:`DeviceClientStateStore.state_dict`
    (checkpointing).

Population sharding: the device store optionally takes a ``mesh`` and a
population :class:`~jax.sharding.PartitionSpec` (see
:func:`population_layout`). Its buffers and stamps are then
``NamedSharding``-placed with the leading ``N`` axis sharded over the
client mesh axes, padded up to the next multiple of the axis extent
(padding rows carry a ``-1`` stamp and are unreachable — ids are
range-checked against the *logical* population). Under GSPMD the same
traced :func:`device_gather` / :func:`device_scatter` become
collective-aware: the gather pulls a cohort's rows from whichever shard
owns them, and the CAS scatter's masked writes land only on the owning
shard — nothing about the round program changes. ``shardings()`` exposes
the store's placement so engines can pin ``out_shardings`` and keep
donation aliasing exact. On a multi-process (multi-host) mesh the store
additionally checkpoints shard-locally: :meth:`local_state_dict` /
:meth:`load_local_state_dict` move only the rows this host owns.

Both stores share the write-stamp CAS contract, refuse duplicate client
ids in one cohort (numpy's buffered fancy indexing and XLA's scatter would
both silently make an arbitrary write win), and expose the same
``state_dict()`` / ``load_state_dict()`` pytree so checkpoints written
from one placement restore into the other through ``checkpoint/io.py``.
"""
from __future__ import annotations

import abc
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

#: Mesh axis names that may carry the population/client dimension, in
#: precedence order (mirrors sharding.rules.DEFAULT_RULES["clients"]).
CLIENT_AXES = ("pod", "data")


def _require_unique_ids(client_ids: np.ndarray, op: str) -> None:
    """Raise if a cohort names the same client twice.

    Duplicate ids in one scatter are ill-defined in both stores: numpy's
    buffered fancy indexing makes the *last* write win (and bumps the
    stamp once), XLA's scatter picks an arbitrary winner — either way one
    client's update is silently discarded. The engine's sampler draws
    without replacement, but the stores are public API, so this is
    enforced loudly at the edge.
    """
    ids, counts = np.unique(client_ids, return_counts=True)
    if ids.shape[0] != np.asarray(client_ids).shape[0]:
        dups = ids[counts > 1]
        raise ValueError(
            f"{op} got duplicate client ids {dups.tolist()}: a cohort may "
            f"name each client at most once (duplicate writes would "
            f"silently drop all but one update)")


class PopulationLayout(NamedTuple):
    """How a population of ``num_clients`` lays out over a mesh.

    ``padded_num_clients`` is ``num_clients`` rounded up to the next
    multiple of ``extent`` (the product of the sharded axis sizes) so the
    leading axis always divides evenly — the padding rows are dead weight
    (masked ``-1`` stamps, unreachable by range-checked ids) instead of
    the silent full replication a non-divisible spec used to cause.
    """

    num_clients: int
    padded_num_clients: int
    spec: P          # PartitionSpec for the leading population axis
    extent: int      # product of the sharded mesh axis sizes (1 = unsharded)

    @property
    def padding(self) -> int:
        """Number of dead tail rows added to make N divisible."""
        return self.padded_num_clients - self.num_clients


def _spec_axes(population_spec) -> tuple:
    """Flatten a leading-axis PartitionSpec entry into mesh axis names."""
    if population_spec is None:
        return ()
    parts = tuple(population_spec)
    if not parts or parts[0] is None:
        return ()
    head = parts[0]
    return tuple(head) if isinstance(head, (tuple, list)) else (head,)


def population_layout(mesh, num_clients: int,
                      population_spec: Optional[P] = None) -> PopulationLayout:
    """The padded population layout for ``num_clients`` over ``mesh``.

    With ``population_spec=None`` the leading axis shards over whichever of
    the canonical client axes (``("pod", "data")``) the mesh has; pass an
    explicit spec (e.g. ``P("data")``) to override. ``mesh`` may be a real
    ``Mesh``, an ``AbstractMesh``, or anything exposing ``shape`` /
    ``axis_names`` — only the axis sizes are consulted here, so layout
    arithmetic is testable without devices. ``mesh=None`` (or no matching
    axes) yields the unsharded identity layout.
    """
    if num_clients <= 0:
        raise ValueError(f"num_clients must be >= 1, got {num_clients}")
    if mesh is None:
        return PopulationLayout(num_clients, num_clients, P(), 1)
    if population_spec is None:
        axes = tuple(a for a in CLIENT_AXES if a in mesh.axis_names)
    else:
        axes = _spec_axes(population_spec)
        missing = [a for a in axes if a not in mesh.axis_names]
        if missing:
            raise ValueError(
                f"population_spec names mesh axes {missing} not in mesh "
                f"{tuple(mesh.axis_names)}")
    extent = 1
    for a in axes:
        extent *= mesh.shape[a]
    if extent <= 1:
        return PopulationLayout(num_clients, num_clients, P(), 1)
    padded = -(-num_clients // extent) * extent
    spec = P(axes if len(axes) > 1 else axes[0])
    return PopulationLayout(num_clients, padded, spec, extent)


# ---------------------------------------------------------------------------
# Shared store contract
# ---------------------------------------------------------------------------

class BaseClientStateStore(abc.ABC):
    """Shared contract of the host and device per-client state stores.

    Owns everything placement-independent: population validation, lazy
    ``ensure`` allocation from a single client's state template, the
    ``initialized`` guard, and the checkpoint population check. Subclasses
    provide ``_allocate`` (where the dense ``(N, ...)`` buffers live) plus
    the placement-specific gather/scatter/reset/state-dict operations; all
    of them honor the write-stamp CAS contract documented on the module.
    """

    #: Whether the subclass accepts mesh/population_spec sharding kwargs.
    shardable = False

    def __init__(self, num_clients: int):
        """Create an empty store for a population of ``num_clients``."""
        if num_clients <= 0:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.num_clients = num_clients
        self._buffers = None              # pytree of (N, ...) arrays

    @property
    def initialized(self) -> bool:
        """Whether the dense buffers have been allocated."""
        return self._buffers is not None

    def ensure(self, template):
        """Allocate the ``(num_clients, ...)`` buffers from one client's
        state template (idempotent; zeros, matching leaf dtypes)."""
        if self._buffers is None:
            self._buffers = self._allocate(template)
        return self

    def _require_initialized(self):
        if self._buffers is None:
            raise RuntimeError(
                f"{type(self).__name__} is uninitialized; call "
                f"ensure(template) with one client's state pytree first")

    def _check_restore_stamps(self, state) -> np.ndarray:
        """Validate a ``state_dict`` payload's population size; returns the
        stamps as int64 (both placements checkpoint stamps at int64)."""
        stamps = np.asarray(state["stamps"], np.int64)
        if stamps.shape != (self.num_clients,):
            raise ValueError(
                f"stamps shape {stamps.shape} != ({self.num_clients},) — "
                f"checkpoint was written for a different population size")
        return stamps

    @abc.abstractmethod
    def _allocate(self, template):
        """Allocate and return the zeroed ``(N, ...)`` buffer pytree."""

    @abc.abstractmethod
    def reset(self):
        """Zero every client's state and write stamp (keeps the buffers)."""

    @abc.abstractmethod
    def gather(self, client_ids):
        """One cohort's state slice: ``(stacked_states, stamps)``."""

    @abc.abstractmethod
    def scatter(self, client_ids, updates, stamps=None):
        """CAS write-back of a cohort's updates; returns #clients dropped."""

    @abc.abstractmethod
    def state_dict(self):
        """Checkpointable pytree: the dense buffers + write stamps."""

    @abc.abstractmethod
    def load_state_dict(self, state):
        """Restore from :meth:`state_dict` output."""


class ClientStateStore(BaseClientStateStore):
    """Per-client persistent state: dense host buffers + write stamps."""

    def __init__(self, num_clients: int):
        """Create an empty host store for ``num_clients`` clients."""
        super().__init__(num_clients)
        self._stamps = np.zeros(num_clients, np.int64)

    def _allocate(self, template):
        n = self.num_clients
        return jax.tree_util.tree_map(
            lambda x: np.zeros((n,) + tuple(np.shape(x)),
                               np.asarray(x).dtype),
            template)

    def reset(self) -> "ClientStateStore":
        """Zero every client's state and write stamp (keeps the buffers)."""
        if self._buffers is not None:
            jax.tree_util.tree_map(lambda b: b.fill(0), self._buffers)
        self._stamps[:] = 0
        return self

    def gather(self, client_ids):
        """One cohort's state slice: ``(stacked_states, stamps)``.

        ``stacked_states`` leaves have shape ``(C, ...)`` and feed the
        jitted round program; ``stamps`` snapshots each client's write
        counter and must be handed back to :meth:`scatter` so overlapping
        in-flight cohorts cannot clobber each other's applied updates.
        """
        self._require_initialized()
        ids = np.asarray(client_ids, np.int64)
        states = jax.tree_util.tree_map(lambda b: b[ids], self._buffers)
        return states, self._stamps[ids].copy()

    def scatter(self, client_ids, updates,
                stamps: Optional[np.ndarray] = None,
                write_mask: Optional[np.ndarray] = None) -> int:
        """Write a cohort's state updates back; returns #clients dropped.

        ``updates`` is the stacked ``ClientResult.state_update`` pytree
        (leading cohort axis; device arrays are pulled to the host here —
        the one blocking sync of a stateful round). With ``stamps`` (from
        the matching :meth:`gather`), a client whose state was updated
        since that gather keeps its newer value and this cohort's stale
        write is dropped; ``stamps=None`` writes unconditionally.
        ``write_mask`` (optional (C,) bool/0-1) suppresses the writes *and*
        stamp bumps of masked-out clients (fault injection's mid-round
        dropouts: their half-finished state must not land); masked-out
        clients do not count as CAS drops.
        """
        self._require_initialized()
        ids = np.asarray(client_ids, np.int64)
        _require_unique_ids(ids, "ClientStateStore.scatter")
        updates = jax.tree_util.tree_map(np.asarray, updates)
        if stamps is None:
            write = np.ones(ids.shape[0], bool)
        else:
            write = self._stamps[ids] == np.asarray(stamps)
        if write_mask is None:
            wanted = ids.shape[0]
        else:
            wm = np.asarray(write_mask).astype(bool)
            write &= wm
            wanted = int(wm.sum())
        rows = ids[write]
        jax.tree_util.tree_map(
            lambda b, u: b.__setitem__(rows, u[write]), self._buffers, updates)
        self._stamps[rows] += 1
        return int(wanted - rows.shape[0])

    # -- checkpointing -------------------------------------------------------
    def state_dict(self):
        """Checkpointable pytree: the dense buffers + write stamps."""
        self._require_initialized()
        return {"buffers": self._buffers, "stamps": self._stamps}

    def load_state_dict(self, state) -> "ClientStateStore":
        """Restore from :meth:`state_dict` output (leaf-count checked by
        ``checkpoint.restore_checkpoint`` when loading from disk)."""
        stamps = self._check_restore_stamps(state)
        self._buffers = jax.tree_util.tree_map(np.asarray, state["buffers"])
        self._stamps = stamps.copy()
        return self


# ---------------------------------------------------------------------------
# Device-resident store: gather/scatter traced inside the jitted round
# ---------------------------------------------------------------------------

def device_gather(store_state, client_ids):
    """Traced cohort gather: ``(stacked_states, stamps_snapshot)``.

    ``store_state`` is :meth:`DeviceClientStateStore.device_state` (the
    dense ``(N, ...)`` buffers + ``(N,)`` write stamps) and ``client_ids``
    a traced ``(C,)`` int vector; the slice happens on device, inside
    whatever jitted program calls this. When the store is population-
    sharded, GSPMD lowers this gather collectively — each cohort row is
    pulled from the shard that owns it. The stamps snapshot must be handed
    back to :func:`device_scatter` for the CAS check.
    """
    states = jax.tree_util.tree_map(lambda b: b[client_ids],
                                    store_state["buffers"])
    return states, store_state["stamps"][client_ids]


def device_scatter(store_state, client_ids, updates, stamps=None,
                   write_mask=None):
    """Traced CAS write-back: ``(new_store_state, drops)``.

    The device twin of :meth:`ClientStateStore.scatter`: a client whose
    stamp moved since the matching :func:`device_gather` keeps its newer
    value (``jnp.where``-masked ``.at[ids].set``, so the stale row writes
    back the value it would have overwritten), applied stamps are bumped
    on device, and ``drops`` (the number of dropped writes) stays a device
    scalar — the caller decides when, if ever, to sync it to the host.
    When the store is population-sharded, GSPMD masks each write to the
    shard that owns the row — the update never materializes a replicated
    ``(N, ...)`` copy. ``stamps=None`` writes unconditionally.
    ``write_mask`` (optional traced (C,) 0/1 vector) additionally
    suppresses masked-out clients' writes and stamp bumps without counting
    them as CAS drops — the fault-injection path's mid-round dropouts.
    Duplicate ``client_ids`` must be rejected host-side before tracing
    (``prepare_ids``): XLA's scatter would pick an arbitrary winner
    silently.
    """
    buffers, all_stamps = store_state["buffers"], store_state["stamps"]
    if stamps is None:
        ok = jnp.ones(client_ids.shape[0], bool)
    else:
        ok = all_stamps[client_ids] == stamps
    if write_mask is None:
        wanted = jnp.asarray(client_ids.shape[0], jnp.int32)
    else:
        wm = jnp.asarray(write_mask) > 0
        ok = ok & wm
        wanted = jnp.sum(wm.astype(jnp.int32))

    def write(b, u):
        mask = ok.reshape((-1,) + (1,) * (u.ndim - 1))
        return b.at[client_ids].set(
            jnp.where(mask, u.astype(b.dtype), b[client_ids]))

    new_buffers = jax.tree_util.tree_map(write, buffers, updates)
    new_stamps = all_stamps.at[client_ids].add(ok.astype(all_stamps.dtype))
    drops = wanted - jnp.sum(ok.astype(jnp.int32))
    return {"buffers": new_buffers, "stamps": new_stamps}, drops


def jit_donating_store(fn: Callable, store_argnum: int,
                       out_shardings=None) -> Callable:
    """``jax.jit(fn)`` with the store-state argument donated when possible.

    Donation lets XLA alias the store's ``(N, ...)`` input buffers to the
    returned updated store, so the round updates the state in place
    instead of holding two copies of ``N x`` per-client state in HBM. The
    CPU backend does not implement donation (it would warn on every
    compile), so this degrades to a plain ``jit`` there — purely a memory
    optimization either way; numerics are identical. ``out_shardings``
    (optional; a pytree prefix matching ``fn``'s outputs, ``None`` leaves
    = compiler's choice) pins the returned store to the store's own
    placement so donation aliases shard-for-shard on a sharded store.
    """
    kw = {} if out_shardings is None else {"out_shardings": out_shardings}
    if jax.default_backend() == "cpu":
        return jax.jit(fn, **kw)
    return jax.jit(fn, donate_argnums=(store_argnum,), **kw)


class DeviceClientStateStore(BaseClientStateStore):
    """Per-client persistent state as device-resident buffers.

    Same population/``ensure``/``reset``/``state_dict`` API and CAS
    write-stamp contract as the host :class:`ClientStateStore`, but the
    dense ``(num_clients, ...)`` buffers and the stamps are jax device
    arrays, and the engines trace :func:`device_gather` /
    :func:`device_scatter` against :meth:`device_state` *inside* their
    jitted round programs (the cohort's ``client_ids`` are a traced
    argument, prepared by :meth:`prepare_ids`) and hand the returned store
    pytree back to :meth:`set_device_state` — no host sync anywhere in the
    round loop. ``gather``/``scatter`` remain as host-callable conveniences
    with the host store's exact semantics (``scatter`` returns the drop
    count, which forces one sync) for tests and interactive use; the
    engines never call them.

    With a ``mesh`` the population axis is a first-class sharded dimension:
    buffers and stamps are ``NamedSharding``-placed with the leading ``N``
    axis split per ``population_spec`` (default: the mesh's client axes,
    via :func:`population_layout`), padded up to the axis extent — so a
    1M-client store on 8 devices holds ~1/8 of the rows per device instead
    of 8 full replicas. ``shardings()`` mirrors :meth:`device_state` for
    pinning ``out_shardings``. On a multi-process mesh use
    :meth:`local_state_dict` / :meth:`load_local_state_dict` to checkpoint
    shard-locally (each host moves only the rows it owns).

    Stamps are int32 on device (jax default-int under disabled x64);
    :meth:`state_dict` widens them to the host store's int64 so checkpoints
    are interchangeable between placements. Padding rows carry a ``-1``
    stamp and are invisible to every public method — ids are range-checked
    against the logical ``num_clients`` and checkpoints slice the padding
    off, so checkpoints are layout-independent.
    """

    shardable = True

    def __init__(self, num_clients: int, *, mesh=None, population_spec=None):
        """Create an empty device store for ``num_clients`` clients,
        optionally population-sharded over ``mesh`` per ``population_spec``
        (default: the mesh's client axes)."""
        super().__init__(num_clients)
        if mesh is None and population_spec is not None:
            raise ValueError("population_spec requires a mesh")
        self.mesh = mesh
        self.layout = population_layout(mesh, num_clients, population_spec)
        self._stamps = self._fresh_stamps()

    @property
    def padded_num_clients(self) -> int:
        """The on-device leading-axis extent (num_clients + padding)."""
        return self.layout.padded_num_clients

    def _sharding(self, tail_ndim: int) -> Optional[NamedSharding]:
        """NamedSharding for a ``(N_padded, *tail)`` leaf (None = no mesh)."""
        if self.mesh is None:
            return None
        return NamedSharding(
            self.mesh, P(*self.layout.spec, *(None,) * tail_ndim))

    def _device_zeros(self, shape, dtype):
        """Sharded zeros built inside a jit — no host-side materialization
        and, on a multi-process mesh, no cross-host transfer."""
        sh = self._sharding(len(shape) - 1)
        make = lambda: jnp.zeros(shape, dtype)  # noqa: E731
        if sh is None:
            return make()
        return jax.jit(make, out_shardings=sh)()

    def _fresh_stamps(self):
        n, live = self.layout.padded_num_clients, self.num_clients
        sh = self._sharding(0)

        def make():
            idx = jnp.arange(n, dtype=jnp.int32)
            return jnp.where(idx < live, jnp.int32(0), jnp.int32(-1))

        if sh is None:
            return make()
        return jax.jit(make, out_shardings=sh)()

    def _allocate(self, template):
        n = self.layout.padded_num_clients
        return jax.tree_util.tree_map(
            lambda x: self._device_zeros((n,) + tuple(np.shape(x)),
                                         jnp.asarray(x).dtype),
            template)

    def reset(self) -> "DeviceClientStateStore":
        """Zero every client's state and write stamp (keeps the shapes)."""
        if self._buffers is not None:
            self._buffers = jax.tree_util.tree_map(
                lambda b: self._device_zeros(b.shape, b.dtype), self._buffers)
        self._stamps = self._fresh_stamps()
        return self

    # -- the engine-facing traced-state handshake ---------------------------
    def _check_range(self, ids: np.ndarray) -> np.ndarray:
        if ids.size and (ids.min() < 0 or ids.max() >= self.num_clients):
            raise ValueError(
                f"client ids {ids.tolist()} out of range for population "
                f"{self.num_clients}")
        return ids

    def prepare_ids(self, client_ids) -> jnp.ndarray:
        """Cohort ids -> the traced ``(C,)`` int32 argument of the round.

        Checks duplicates and range host-side, while the ids are still
        concrete (inside the jit XLA clamps out-of-range indices and the
        scatter cannot raise). Range is checked against the *logical*
        population, so padding rows are unreachable.
        """
        ids = np.asarray(client_ids, np.int64)
        _require_unique_ids(ids, "DeviceClientStateStore")
        return jnp.asarray(self._check_range(ids), jnp.int32)

    def device_state(self):
        """The store as a traced-argument pytree: ``{"buffers", "stamps"}``.

        Hand this to the jitted round (or :func:`device_gather` /
        :func:`device_scatter`) and give the returned updated pytree back
        to :meth:`set_device_state`; with :func:`jit_donating_store` the
        round aliases the update in place.
        """
        self._require_initialized()
        return {"buffers": self._buffers, "stamps": self._stamps}

    @property
    def population_sharding(self) -> Optional[NamedSharding]:
        """One NamedSharding usable as a pytree *prefix* for any
        store-shaped subtree (every leaf has the population as its leading
        axis; trailing dims pad to None) — the handle engines pin a jitted
        round's store ``out_shardings`` with, available before ``ensure``.
        None when the store is unsharded."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.layout.spec)

    def shardings(self):
        """NamedSharding pytree mirroring :meth:`device_state` (or None
        when the store is unsharded) — pass as the store slot of a jitted
        round's ``out_shardings`` so the donated update aliases
        shard-for-shard instead of letting the compiler re-layout it."""
        if self.mesh is None:
            return None
        self._require_initialized()
        return {
            "buffers": jax.tree_util.tree_map(
                lambda b: self._sharding(b.ndim - 1), self._buffers),
            "stamps": self._sharding(0),
        }

    def set_device_state(self, store_state) -> "DeviceClientStateStore":
        """Adopt the updated ``{"buffers", "stamps"}`` a round returned.

        Pure reference rebinding: nothing syncs, the arrays may still be
        futures of an in-flight dispatch.
        """
        self._buffers = store_state["buffers"]
        self._stamps = store_state["stamps"]
        return self

    # -- host-callable conveniences (host-store API parity) -----------------
    def gather(self, client_ids):
        """One cohort's state slice ``(stacked_states, stamps)`` (device
        arrays), with the host store's contract — incl. rejecting
        out-of-range ids, which XLA's gather would silently clamp; for
        tests/interactive use — the engines gather inside their jitted
        rounds instead."""
        self._require_initialized()
        ids = self._check_range(np.asarray(client_ids, np.int64))
        return device_gather(self.device_state(), jnp.asarray(ids, jnp.int32))

    def scatter(self, client_ids, updates,
                stamps: Optional[jnp.ndarray] = None) -> int:
        """CAS write-back; returns #clients dropped (blocks on the count).

        Host-store API parity for tests/interactive use: the engines trace
        :func:`device_scatter` inside their round programs and fold the
        drop counter into their end-of-loop sync instead of blocking here.
        """
        ids = self.prepare_ids(client_ids)
        updates = jax.tree_util.tree_map(jnp.asarray, updates)
        new_state, drops = device_scatter(self.device_state(), ids, updates,
                                          stamps)
        self.set_device_state(new_state)
        return int(drops)

    # -- checkpointing -------------------------------------------------------
    def state_dict(self):
        """Checkpointable pytree — the ONE place device state crosses to
        the host (stamps widened to the host store's int64 and padding rows
        sliced off, so checkpoints restore into either placement and any
        layout). On a multi-process mesh the full population is not
        addressable from one host — use :meth:`local_state_dict` there."""
        self._require_initialized()
        if self.mesh is not None and jax.process_count() > 1:
            raise RuntimeError(
                "state_dict() needs every row addressable; on a "
                "multi-process mesh checkpoint shard-locally with "
                "local_state_dict() instead")
        live = self.num_clients
        return {
            "buffers": jax.tree_util.tree_map(
                lambda b: np.asarray(b[:live]), self._buffers),
            "stamps": np.asarray(self._stamps[:live], np.int64),
        }

    def load_state_dict(self, state) -> "DeviceClientStateStore":
        """Restore from either store's :meth:`state_dict` output (pushed
        to device; population size checked; re-padded and re-sharded to
        this store's layout — the replicated-read path: every process
        supplies the full array and keeps only the rows it owns)."""
        stamps = self._check_restore_stamps(state)
        self._buffers = jax.tree_util.tree_map(
            lambda b: self._globalize(np.asarray(b), 0), state["buffers"])
        self._stamps = self._globalize(stamps.astype(np.int32), -1)
        return self

    def _globalize(self, full_rows: np.ndarray, fill):
        """(num_clients, ...) host rows -> padded, sharded device array."""
        pad = self.layout.padding
        if pad:
            tail = np.full((pad,) + full_rows.shape[1:], fill,
                           full_rows.dtype)
            full_rows = np.concatenate([full_rows, tail], axis=0)
        sh = self._sharding(full_rows.ndim - 1)
        if sh is None:
            return jnp.asarray(full_rows)
        return jax.make_array_from_callback(
            full_rows.shape, sh, lambda idx: full_rows[idx])

    # -- shard-local checkpointing (multi-host) ------------------------------
    def _local_rows(self, arr) -> tuple:
        """This process's contiguous leading-axis slice of ``arr`` as
        ``(rows, start)`` (replica copies deduped, padding clipped)."""
        by_start = {}
        for s in arr.addressable_shards:
            lead = s.index[0] if s.index else slice(0, arr.shape[0])
            start = 0 if lead.start is None else lead.start
            by_start.setdefault(start, s.data)
        starts = sorted(by_start)
        chunks = [np.asarray(by_start[s]) for s in starts]
        lo = starts[0] if starts else 0
        hi = lo + sum(c.shape[0] for c in chunks)
        expect = lo
        for s, c in zip(starts, chunks):
            if s != expect:
                raise RuntimeError(
                    "store shards are not contiguous on this host — "
                    "shard-local checkpointing needs a row-major mesh")
            expect += c.shape[0]
        rows = (np.concatenate(chunks, axis=0) if chunks
                else np.zeros((0,) + arr.shape[1:], arr.dtype))
        hi = min(hi, self.num_clients)       # clip dead padding rows
        lo = min(lo, hi)
        return rows[:hi - lo], lo

    def local_state_dict(self):
        """This host's slice of :meth:`state_dict`: ``(state, row_offset)``.

        ``state`` holds only the contiguous live rows whose shards are
        addressable from this process (padding clipped, stamps widened to
        int64); ``row_offset`` is the slice's position in the global
        population. Feed both to ``checkpoint.save_checkpoint_shard`` and
        restore with :meth:`load_local_state_dict`.
        """
        self._require_initialized()
        stamp_rows, offset = self._local_rows(self._stamps)
        state = {
            "buffers": jax.tree_util.tree_map(
                lambda b: self._local_rows(b)[0], self._buffers),
            "stamps": stamp_rows.astype(np.int64),
        }
        return state, offset

    def load_local_state_dict(self, state, row_offset: int
                              ) -> "DeviceClientStateStore":
        """Shard-local restore: this process supplies only its own rows.

        ``state``/``row_offset`` are one host's :meth:`local_state_dict`
        output (or one shard file of a sharded checkpoint). Every process
        must call this with its own slice; rows outside ``[row_offset,
        row_offset + rows)`` that this process happens to address (the
        dead padding tail) are re-synthesized, not read.
        """
        stamps = np.asarray(state["stamps"], np.int64)
        rows = stamps.shape[0]
        if row_offset < 0 or row_offset + rows > self.num_clients:
            raise ValueError(
                f"shard rows [{row_offset}, {row_offset + rows}) out of "
                f"range for population {self.num_clients}")
        self._buffers = jax.tree_util.tree_map(
            lambda b: self._localize(np.asarray(b), row_offset, 0),
            state["buffers"])
        self._stamps = self._localize(stamps.astype(np.int32), row_offset, -1)
        return self

    def _localize(self, local_rows: np.ndarray, offset: int, fill):
        """Local ``(rows, ...)`` slice -> global padded sharded array."""
        n = self.layout.padded_num_clients
        gshape = (n,) + local_rows.shape[1:]
        sh = self._sharding(local_rows.ndim - 1)
        if sh is None:
            if offset != 0 or local_rows.shape[0] != self.num_clients:
                raise ValueError(
                    "unsharded store restore needs the full population "
                    "(offset 0); got a partial shard")
            return self._globalize(local_rows, fill)

        def cb(idx):
            lead = idx[0]
            lo = 0 if lead.start is None else lead.start
            hi = n if lead.stop is None else lead.stop
            out = np.full((hi - lo,) + gshape[1:], fill, local_rows.dtype)
            s = max(lo, offset)
            e = min(hi, offset + local_rows.shape[0])
            if e > s:
                out[s - lo:e - lo] = local_rows[s - offset:e - offset]
            return out[(slice(None),) + tuple(idx[1:])]

        return jax.make_array_from_callback(gshape, sh, cb)


#: Store classes by ``FedConfig.client_state_placement`` value. Populated
#: via :func:`register_store`; config validation treats it as the source
#: of truth for valid placements.
STORES = {}


def register_store(name: str, cls, *, override: bool = False):
    """Register a client-state store class under a placement ``name``.

    Re-registering an existing name raises — a silent swap would reroute
    every config's per-client state through a different store — unless
    ``override=True`` is passed explicitly. Returns ``cls`` so it can be
    used as a registration helper in downstream code.
    """
    if not issubclass(cls, BaseClientStateStore):
        raise TypeError(f"{cls!r} must subclass BaseClientStateStore")
    if not override and name in STORES and STORES[name] is not cls:
        raise ValueError(
            f"client-state store {name!r} is already registered to "
            f"{STORES[name]!r}; pass override=True to replace it")
    STORES[name] = cls
    return cls


register_store("host", ClientStateStore)
register_store("device", DeviceClientStateStore)


def make_client_store(placement: str, num_clients: int, *, mesh=None,
                      population_spec=None) -> BaseClientStateStore:
    """Instantiate the store for a ``client_state_placement`` value.

    ``mesh``/``population_spec`` request a population-sharded store; only
    placements whose store class advertises ``shardable`` accept them
    (today: ``"device"``).
    """
    try:
        cls = STORES[placement]
    except KeyError:
        raise ValueError(
            f"unknown client_state_placement {placement!r}; "
            f"known: {tuple(STORES)}") from None
    if not issubclass(cls, BaseClientStateStore):
        raise TypeError(
            f"STORES[{placement!r}] = {cls!r} is not a BaseClientStateStore")
    if mesh is not None:
        if not cls.shardable:
            raise ValueError(
                f"client_state_placement={placement!r} does not support "
                f"population sharding (mesh given); use \"device\"")
        return cls(num_clients, mesh=mesh, population_spec=population_spec)
    return cls(num_clients)
