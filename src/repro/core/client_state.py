"""Host-side per-client persistent state for stateful federated algorithms.

The paper's template assumes stateless clients, but its stateful cousins —
SCAFFOLD-style control variates and the per-client site parameters of
EP-based posterior inference (Guo et al. 2023) — need a statistic that
persists *on the server, per client, across rounds*. ``ClientStateStore``
is that statistic's home:

  * dense numpy buffers with a leading ``num_clients`` axis, mirroring one
    per-client state pytree (``FedAlgorithm.init_client_state``), lazily
    allocated the first time a template is available;
  * ``gather(client_ids)`` slices one cohort's states (and records a
    per-client write stamp) for the jitted round program to consume;
  * ``scatter(client_ids, updates, stamps)`` writes the cohort's
    ``ClientResult.state_update`` back with compare-and-swap semantics:
    a write is applied only if the client's state was not updated since
    the matching gather. Under the async engine two in-flight cohorts can
    overlap on a client; the cohort applied second gathered *before* the
    first one wrote, so its stale write is dropped — an applied update is
    never silently clobbered by a writer that did not see it;
  * ``state_dict()`` / ``load_state_dict()`` expose a plain pytree so the
    store checkpoints through ``checkpoint/io.py`` alongside ``ServerState``.

Everything here is host-side (numpy): the stacked cohort slice transfers
to the device once per round, with the batches, and the state traffic
inside the round stays inside the single jitted program.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


class ClientStateStore:
    """Per-client persistent state: dense host buffers + write stamps."""

    def __init__(self, num_clients: int):
        """Create an empty store for a population of ``num_clients``."""
        if num_clients <= 0:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.num_clients = num_clients
        self._buffers = None                  # pytree of (N, ...) np arrays
        self._stamps = np.zeros(num_clients, np.int64)

    @property
    def initialized(self) -> bool:
        """Whether the dense buffers have been allocated."""
        return self._buffers is not None

    def ensure(self, template) -> "ClientStateStore":
        """Allocate the ``(num_clients, ...)`` buffers from one client's
        state template (idempotent; zeros, matching leaf dtypes)."""
        if self._buffers is None:
            n = self.num_clients
            self._buffers = jax.tree_util.tree_map(
                lambda x: np.zeros((n,) + tuple(np.shape(x)),
                                   np.asarray(x).dtype),
                template)
        return self

    def reset(self) -> "ClientStateStore":
        """Zero every client's state and write stamp (keeps the buffers)."""
        if self._buffers is not None:
            jax.tree_util.tree_map(lambda b: b.fill(0), self._buffers)
        self._stamps[:] = 0
        return self

    def _require_initialized(self):
        if self._buffers is None:
            raise RuntimeError(
                "ClientStateStore is uninitialized; call ensure(template) "
                "with one client's state pytree first")

    def gather(self, client_ids):
        """One cohort's state slice: ``(stacked_states, stamps)``.

        ``stacked_states`` leaves have shape ``(C, ...)`` and feed the
        jitted round program; ``stamps`` snapshots each client's write
        counter and must be handed back to :meth:`scatter` so overlapping
        in-flight cohorts cannot clobber each other's applied updates.
        """
        self._require_initialized()
        ids = np.asarray(client_ids, np.int64)
        states = jax.tree_util.tree_map(lambda b: b[ids], self._buffers)
        return states, self._stamps[ids].copy()

    def scatter(self, client_ids, updates,
                stamps: Optional[np.ndarray] = None) -> int:
        """Write a cohort's state updates back; returns #clients dropped.

        ``updates`` is the stacked ``ClientResult.state_update`` pytree
        (leading cohort axis; device arrays are pulled to the host here —
        the one blocking sync of a stateful round). With ``stamps`` (from
        the matching :meth:`gather`), a client whose state was updated
        since that gather keeps its newer value and this cohort's stale
        write is dropped; ``stamps=None`` writes unconditionally.
        """
        self._require_initialized()
        ids = np.asarray(client_ids, np.int64)
        updates = jax.tree_util.tree_map(np.asarray, updates)
        if stamps is None:
            write = np.ones(ids.shape[0], bool)
        else:
            write = self._stamps[ids] == np.asarray(stamps)
        rows = ids[write]
        jax.tree_util.tree_map(
            lambda b, u: b.__setitem__(rows, u[write]), self._buffers, updates)
        self._stamps[rows] += 1
        return int(ids.shape[0] - rows.shape[0])

    # -- checkpointing -------------------------------------------------------
    def state_dict(self):
        """Checkpointable pytree: the dense buffers + write stamps."""
        self._require_initialized()
        return {"buffers": self._buffers, "stamps": self._stamps}

    def load_state_dict(self, state) -> "ClientStateStore":
        """Restore from :meth:`state_dict` output (leaf-count checked by
        ``checkpoint.restore_checkpoint`` when loading from disk)."""
        stamps = np.asarray(state["stamps"], np.int64)
        if stamps.shape != (self.num_clients,):
            raise ValueError(
                f"stamps shape {stamps.shape} != ({self.num_clients},) — "
                f"checkpoint was written for a different population size")
        self._buffers = jax.tree_util.tree_map(np.asarray, state["buffers"])
        self._stamps = stamps.copy()
        return self
