"""Per-client persistent state for stateful federated algorithms.

The paper's template assumes stateless clients, but its stateful cousins —
SCAFFOLD-style control variates and the per-client site parameters of
EP-based posterior inference (Guo et al. 2023) — need a statistic that
persists *on the server, per client, across rounds*. Two interchangeable
stores give that statistic a home (``FedConfig.client_state_placement``):

  * :class:`ClientStateStore` (``"host"``, the default) — dense numpy
    buffers with a leading ``num_clients`` axis, mirroring one per-client
    state pytree (``FedAlgorithm.init_client_state``), lazily allocated the
    first time a template is available. ``gather(client_ids)`` slices one
    cohort's states (and records a per-client write stamp) for the jitted
    round program to consume; ``scatter(client_ids, updates, stamps)``
    writes the cohort's ``ClientResult.state_update`` back with
    compare-and-swap semantics: a write is applied only if the client's
    state was not updated since the matching gather. Under the async
    engine two in-flight cohorts can overlap on a client; the cohort
    applied second gathered *before* the first one wrote, so its stale
    write is dropped — an applied update is never silently clobbered by a
    writer that did not see it. The scatter pulls the stacked updates to
    the host: the one blocking device sync a stateful round pays that a
    stateless one does not.

  * :class:`DeviceClientStateStore` (``"device"``) — the same dense
    ``(num_clients, ...)`` buffers and write stamps as device arrays, with
    the gather (``buffers[ids]``) and CAS scatter (``jnp.where``-masked
    ``.at[ids].set``, stamps compared and bumped on device) traced *inside*
    the jitted round programs via :func:`device_gather` /
    :func:`device_scatter`: the cohort's ``client_ids`` become a traced
    argument, state traffic never leaves the accelerator, and the store's
    buffers are donated to the round (:func:`jit_donating_store`) so the
    update happens in place. The per-round host sync is gone; data only
    crosses to the host in :meth:`DeviceClientStateStore.state_dict`
    (checkpointing).

Both stores share the write-stamp CAS contract, refuse duplicate client
ids in one cohort (numpy's buffered fancy indexing and XLA's scatter would
both silently make an arbitrary write win), and expose the same
``state_dict()`` / ``load_state_dict()`` pytree so checkpoints written
from one placement restore into the other through ``checkpoint/io.py``.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _require_unique_ids(client_ids: np.ndarray, op: str) -> None:
    """Raise if a cohort names the same client twice.

    Duplicate ids in one scatter are ill-defined in both stores: numpy's
    buffered fancy indexing makes the *last* write win (and bumps the
    stamp once), XLA's scatter picks an arbitrary winner — either way one
    client's update is silently discarded. The engine's sampler draws
    without replacement, but the stores are public API, so this is
    enforced loudly at the edge.
    """
    ids, counts = np.unique(client_ids, return_counts=True)
    if ids.shape[0] != np.asarray(client_ids).shape[0]:
        dups = ids[counts > 1]
        raise ValueError(
            f"{op} got duplicate client ids {dups.tolist()}: a cohort may "
            f"name each client at most once (duplicate writes would "
            f"silently drop all but one update)")


class ClientStateStore:
    """Per-client persistent state: dense host buffers + write stamps."""

    def __init__(self, num_clients: int):
        """Create an empty store for a population of ``num_clients``."""
        if num_clients <= 0:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.num_clients = num_clients
        self._buffers = None                  # pytree of (N, ...) np arrays
        self._stamps = np.zeros(num_clients, np.int64)

    @property
    def initialized(self) -> bool:
        """Whether the dense buffers have been allocated."""
        return self._buffers is not None

    def ensure(self, template) -> "ClientStateStore":
        """Allocate the ``(num_clients, ...)`` buffers from one client's
        state template (idempotent; zeros, matching leaf dtypes)."""
        if self._buffers is None:
            n = self.num_clients
            self._buffers = jax.tree_util.tree_map(
                lambda x: np.zeros((n,) + tuple(np.shape(x)),
                                   np.asarray(x).dtype),
                template)
        return self

    def reset(self) -> "ClientStateStore":
        """Zero every client's state and write stamp (keeps the buffers)."""
        if self._buffers is not None:
            jax.tree_util.tree_map(lambda b: b.fill(0), self._buffers)
        self._stamps[:] = 0
        return self

    def _require_initialized(self):
        if self._buffers is None:
            raise RuntimeError(
                "ClientStateStore is uninitialized; call ensure(template) "
                "with one client's state pytree first")

    def gather(self, client_ids):
        """One cohort's state slice: ``(stacked_states, stamps)``.

        ``stacked_states`` leaves have shape ``(C, ...)`` and feed the
        jitted round program; ``stamps`` snapshots each client's write
        counter and must be handed back to :meth:`scatter` so overlapping
        in-flight cohorts cannot clobber each other's applied updates.
        """
        self._require_initialized()
        ids = np.asarray(client_ids, np.int64)
        states = jax.tree_util.tree_map(lambda b: b[ids], self._buffers)
        return states, self._stamps[ids].copy()

    def scatter(self, client_ids, updates,
                stamps: Optional[np.ndarray] = None,
                write_mask: Optional[np.ndarray] = None) -> int:
        """Write a cohort's state updates back; returns #clients dropped.

        ``updates`` is the stacked ``ClientResult.state_update`` pytree
        (leading cohort axis; device arrays are pulled to the host here —
        the one blocking sync of a stateful round). With ``stamps`` (from
        the matching :meth:`gather`), a client whose state was updated
        since that gather keeps its newer value and this cohort's stale
        write is dropped; ``stamps=None`` writes unconditionally.
        ``write_mask`` (optional (C,) bool/0-1) suppresses the writes *and*
        stamp bumps of masked-out clients (fault injection's mid-round
        dropouts: their half-finished state must not land); masked-out
        clients do not count as CAS drops.
        """
        self._require_initialized()
        ids = np.asarray(client_ids, np.int64)
        _require_unique_ids(ids, "ClientStateStore.scatter")
        updates = jax.tree_util.tree_map(np.asarray, updates)
        if stamps is None:
            write = np.ones(ids.shape[0], bool)
        else:
            write = self._stamps[ids] == np.asarray(stamps)
        if write_mask is None:
            wanted = ids.shape[0]
        else:
            wm = np.asarray(write_mask).astype(bool)
            write &= wm
            wanted = int(wm.sum())
        rows = ids[write]
        jax.tree_util.tree_map(
            lambda b, u: b.__setitem__(rows, u[write]), self._buffers, updates)
        self._stamps[rows] += 1
        return int(wanted - rows.shape[0])

    # -- checkpointing -------------------------------------------------------
    def state_dict(self):
        """Checkpointable pytree: the dense buffers + write stamps."""
        self._require_initialized()
        return {"buffers": self._buffers, "stamps": self._stamps}

    def load_state_dict(self, state) -> "ClientStateStore":
        """Restore from :meth:`state_dict` output (leaf-count checked by
        ``checkpoint.restore_checkpoint`` when loading from disk)."""
        stamps = np.asarray(state["stamps"], np.int64)
        if stamps.shape != (self.num_clients,):
            raise ValueError(
                f"stamps shape {stamps.shape} != ({self.num_clients},) — "
                f"checkpoint was written for a different population size")
        self._buffers = jax.tree_util.tree_map(np.asarray, state["buffers"])
        self._stamps = stamps.copy()
        return self


# ---------------------------------------------------------------------------
# Device-resident store: gather/scatter traced inside the jitted round
# ---------------------------------------------------------------------------

def device_gather(store_state, client_ids):
    """Traced cohort gather: ``(stacked_states, stamps_snapshot)``.

    ``store_state`` is :meth:`DeviceClientStateStore.device_state` (the
    dense ``(N, ...)`` buffers + ``(N,)`` write stamps) and ``client_ids``
    a traced ``(C,)`` int vector; the slice happens on device, inside
    whatever jitted program calls this. The stamps snapshot must be handed
    back to :func:`device_scatter` for the CAS check.
    """
    states = jax.tree_util.tree_map(lambda b: b[client_ids],
                                    store_state["buffers"])
    return states, store_state["stamps"][client_ids]


def device_scatter(store_state, client_ids, updates, stamps=None,
                   write_mask=None):
    """Traced CAS write-back: ``(new_store_state, drops)``.

    The device twin of :meth:`ClientStateStore.scatter`: a client whose
    stamp moved since the matching :func:`device_gather` keeps its newer
    value (``jnp.where``-masked ``.at[ids].set``, so the stale row writes
    back the value it would have overwritten), applied stamps are bumped
    on device, and ``drops`` (the number of dropped writes) stays a device
    scalar — the caller decides when, if ever, to sync it to the host.
    ``stamps=None`` writes unconditionally. ``write_mask`` (optional traced
    (C,) 0/1 vector) additionally suppresses masked-out clients' writes and
    stamp bumps without counting them as CAS drops — the fault-injection
    path's mid-round dropouts. Duplicate ``client_ids`` must be rejected
    host-side before tracing (``prepare_ids``): XLA's scatter would pick an
    arbitrary winner silently.
    """
    buffers, all_stamps = store_state["buffers"], store_state["stamps"]
    if stamps is None:
        ok = jnp.ones(client_ids.shape[0], bool)
    else:
        ok = all_stamps[client_ids] == stamps
    if write_mask is None:
        wanted = jnp.asarray(client_ids.shape[0], jnp.int32)
    else:
        wm = jnp.asarray(write_mask) > 0
        ok = ok & wm
        wanted = jnp.sum(wm.astype(jnp.int32))

    def write(b, u):
        mask = ok.reshape((-1,) + (1,) * (u.ndim - 1))
        return b.at[client_ids].set(
            jnp.where(mask, u.astype(b.dtype), b[client_ids]))

    new_buffers = jax.tree_util.tree_map(write, buffers, updates)
    new_stamps = all_stamps.at[client_ids].add(ok.astype(all_stamps.dtype))
    drops = wanted - jnp.sum(ok.astype(jnp.int32))
    return {"buffers": new_buffers, "stamps": new_stamps}, drops


def jit_donating_store(fn: Callable, store_argnum: int) -> Callable:
    """``jax.jit(fn)`` with the store-state argument donated when possible.

    Donation lets XLA alias the store's ``(N, ...)`` input buffers to the
    returned updated store, so the round updates the state in place
    instead of holding two copies of ``N x`` per-client state in HBM. The
    CPU backend does not implement donation (it would warn on every
    compile), so this degrades to a plain ``jit`` there — purely a memory
    optimization either way; numerics are identical.
    """
    if jax.default_backend() == "cpu":
        return jax.jit(fn)
    return jax.jit(fn, donate_argnums=(store_argnum,))


class DeviceClientStateStore:
    """Per-client persistent state as device-resident buffers.

    Same population/``ensure``/``reset``/``state_dict`` API and CAS
    write-stamp contract as the host :class:`ClientStateStore`, but the
    dense ``(num_clients, ...)`` buffers and the stamps are jax device
    arrays, and the engines trace :func:`device_gather` /
    :func:`device_scatter` against :meth:`device_state` *inside* their
    jitted round programs (the cohort's ``client_ids`` are a traced
    argument, prepared by :meth:`prepare_ids`) and hand the returned store
    pytree back to :meth:`set_device_state` — no host sync anywhere in the
    round loop. ``gather``/``scatter`` remain as host-callable conveniences
    with the host store's exact semantics (``scatter`` returns the drop
    count, which forces one sync) for tests and interactive use; the
    engines never call them.

    Stamps are int32 on device (jax default-int under disabled x64);
    :meth:`state_dict` widens them to the host store's int64 so checkpoints
    are interchangeable between placements.
    """

    def __init__(self, num_clients: int):
        """Create an empty device store for ``num_clients`` clients."""
        if num_clients <= 0:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.num_clients = num_clients
        self._buffers = None                  # pytree of (N, ...) jnp arrays
        self._stamps = jnp.zeros(num_clients, jnp.int32)

    @property
    def initialized(self) -> bool:
        """Whether the dense device buffers have been allocated."""
        return self._buffers is not None

    def ensure(self, template) -> "DeviceClientStateStore":
        """Allocate the ``(num_clients, ...)`` device buffers from one
        client's state template (idempotent; zeros, matching leaf dtypes)."""
        if self._buffers is None:
            n = self.num_clients
            self._buffers = jax.tree_util.tree_map(
                lambda x: jnp.zeros((n,) + tuple(np.shape(x)),
                                    jnp.asarray(x).dtype),
                template)
        return self

    def reset(self) -> "DeviceClientStateStore":
        """Zero every client's state and write stamp (keeps the shapes)."""
        if self._buffers is not None:
            self._buffers = jax.tree_util.tree_map(
                lambda b: jnp.zeros_like(b), self._buffers)
        self._stamps = jnp.zeros(self.num_clients, jnp.int32)
        return self

    def _require_initialized(self):
        if self._buffers is None:
            raise RuntimeError(
                "DeviceClientStateStore is uninitialized; call "
                "ensure(template) with one client's state pytree first")

    # -- the engine-facing traced-state handshake ---------------------------
    def _check_range(self, ids: np.ndarray) -> np.ndarray:
        if ids.size and (ids.min() < 0 or ids.max() >= self.num_clients):
            raise ValueError(
                f"client ids {ids.tolist()} out of range for population "
                f"{self.num_clients}")
        return ids

    def prepare_ids(self, client_ids) -> jnp.ndarray:
        """Cohort ids -> the traced ``(C,)`` int32 argument of the round.

        Checks duplicates and range host-side, while the ids are still
        concrete (inside the jit XLA clamps out-of-range indices and the
        scatter cannot raise).
        """
        ids = np.asarray(client_ids, np.int64)
        _require_unique_ids(ids, "DeviceClientStateStore")
        return jnp.asarray(self._check_range(ids), jnp.int32)

    def device_state(self):
        """The store as a traced-argument pytree: ``{"buffers", "stamps"}``.

        Hand this to the jitted round (or :func:`device_gather` /
        :func:`device_scatter`) and give the returned updated pytree back
        to :meth:`set_device_state`; with :func:`jit_donating_store` the
        round aliases the update in place.
        """
        self._require_initialized()
        return {"buffers": self._buffers, "stamps": self._stamps}

    def set_device_state(self, store_state) -> "DeviceClientStateStore":
        """Adopt the updated ``{"buffers", "stamps"}`` a round returned.

        Pure reference rebinding: nothing syncs, the arrays may still be
        futures of an in-flight dispatch.
        """
        self._buffers = store_state["buffers"]
        self._stamps = store_state["stamps"]
        return self

    # -- host-callable conveniences (host-store API parity) -----------------
    def gather(self, client_ids):
        """One cohort's state slice ``(stacked_states, stamps)`` (device
        arrays), with the host store's contract — incl. rejecting
        out-of-range ids, which XLA's gather would silently clamp; for
        tests/interactive use — the engines gather inside their jitted
        rounds instead."""
        self._require_initialized()
        ids = self._check_range(np.asarray(client_ids, np.int64))
        return device_gather(self.device_state(), jnp.asarray(ids, jnp.int32))

    def scatter(self, client_ids, updates,
                stamps: Optional[jnp.ndarray] = None) -> int:
        """CAS write-back; returns #clients dropped (blocks on the count).

        Host-store API parity for tests/interactive use: the engines trace
        :func:`device_scatter` inside their round programs and fold the
        drop counter into their end-of-loop sync instead of blocking here.
        """
        ids = self.prepare_ids(client_ids)
        updates = jax.tree_util.tree_map(jnp.asarray, updates)
        new_state, drops = device_scatter(self.device_state(), ids, updates,
                                          stamps)
        self.set_device_state(new_state)
        return int(drops)

    # -- checkpointing -------------------------------------------------------
    def state_dict(self):
        """Checkpointable pytree — the ONE place device state crosses to
        the host (stamps widened to the host store's int64, so checkpoints
        restore into either placement)."""
        self._require_initialized()
        return {
            "buffers": jax.tree_util.tree_map(np.asarray, self._buffers),
            "stamps": np.asarray(self._stamps, np.int64),
        }

    def load_state_dict(self, state) -> "DeviceClientStateStore":
        """Restore from either store's :meth:`state_dict` output (pushed
        to device; population size checked)."""
        stamps = np.asarray(state["stamps"], np.int64)
        if stamps.shape != (self.num_clients,):
            raise ValueError(
                f"stamps shape {stamps.shape} != ({self.num_clients},) — "
                f"checkpoint was written for a different population size")
        self._buffers = jax.tree_util.tree_map(jnp.asarray, state["buffers"])
        self._stamps = jnp.asarray(stamps, jnp.int32)
        return self


#: Store classes by ``FedConfig.client_state_placement`` value.
STORES = {"host": ClientStateStore, "device": DeviceClientStateStore}


def make_client_store(placement: str, num_clients: int):
    """Instantiate the store for a ``client_state_placement`` value."""
    try:
        cls = STORES[placement]
    except KeyError:
        raise ValueError(
            f"unknown client_state_placement {placement!r}; "
            f"known: {tuple(STORES)}") from None
    return cls(num_clients)
