"""Sherman-Morrison dynamic program for FedPA client deltas (Appendix C).

Computes

    Delta_hat_l = Sigma_hat_l^{-1} (x0 - xbar_l)

in O(l^2 d) time and O(l d) memory, never materializing a d x d matrix,
where Sigma_hat_l is the shrinkage covariance of the l posterior samples
(see ``repro.core.shrinkage``). Works on arbitrary parameter pytrees; the
history {u_k}, {v_k} is kept with a stacked leading sample axis per leaf so
each leaf stays in its own (sharded) layout.

Recurrences implemented (paper eqs. 21-28), with
u_t = x_t - xbar_{t-1}, gamma_t = (t-1) rho / t, v_t = Sigma_tilde_{t-1}^{-1} u_t:

    v_t     = u_t - sum_{k=2}^{t-1} c_k (v_k . u_t) v_k,   c_k = gamma_k / (1 + gamma_k a_k)
    a_t     = u_t . v_t
    Delta~_t = Delta~_{t-1} - [1 + gamma_t (t b_t - a_t) / (1 + gamma_t a_t)] v_t / t,
               b_t = u_t . Delta~_{t-1}
    Delta^_t = Delta~_t / rho_t

One implementation of the recurrence (``online_dp_update``, vectorized
history dots + masked rank-1 combine) serves both entry points:
  * ``dp_delta``      — samples known up front (stacked trees): a
                        ``lax.scan`` of the online update, so trace size and
                        HLO stay O(l) even for large sample counts; used
                        inside the jitted federated round.
  * ``OnlineDP``      — streaming any-time state (init/update), used by the
                        serving-style example and mirrored by the Pallas
                        kernel in ``repro.kernels.fedpa_dp``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm


def fedavg_delta(x0, x_final):
    """FedAvg's (biased) client delta: Delta = I (theta_0 - theta_K).

    This is exactly ``dp_delta`` with a single sample (or rho -> 0): FedPA
    with identity covariance — the paper's Section 4 special-case claim,
    asserted in tests/test_dp_delta.py.
    """
    return tm.tsub(x0, x_final)


def dp_delta(x0, samples, rho, return_mean=False):
    """Delta_hat_l from stacked posterior samples.

    Args:
      x0: parameter pytree (the server state broadcast this round).
      samples: pytree with leading sample axis ``l`` on every leaf.
      rho: shrinkage parameter in [0, inf); rho=0 reduces to FedAvg-on-mean.
      return_mean: also return the sample mean xbar_l.

    Returns Delta_hat_l as a pytree shaped like x0 (and optionally xbar_l).
    """
    ell = jax.tree_util.tree_leaves(samples)[0].shape[0]
    # DP in >= fp32 (bf16 deltas are re-cast by the caller, see client.py)
    dtype = jnp.promote_types(
        jax.tree_util.tree_leaves(samples)[0].dtype, jnp.float32)
    state0 = online_dp_init(x0, ell, dtype=dtype)

    def body(state, x_t):
        return online_dp_update(state, x_t, rho), None

    state, _ = jax.lax.scan(body, state0, tm.tcast(samples, dtype))
    delta = online_dp_delta(state, rho)
    if return_mean:
        return delta, state.xbar
    return delta


# ---------------------------------------------------------------------------
# Streaming / any-time version
# ---------------------------------------------------------------------------

class DPState(NamedTuple):
    """Any-time DP state after ``t`` samples (paper: the O(l d) DP tuple)."""

    t: jnp.ndarray          # i32 scalar, number of samples absorbed
    xbar: object            # running sample mean (tree)
    delta_tilde: object     # Delta~_t (tree)
    v_hist: object          # tree, leading axis ell_max: v_2..v_t in slots 0..t-2
    c_hist: jnp.ndarray     # (ell_max,) combine coefficients c_k
    x0: object              # broadcast server state (tree)

    @property
    def delta(self):
        """Delta_hat_t — best any-time estimate given samples so far."""
        raise AttributeError("use online_dp_delta(state, rho)")


def online_dp_init(x0, ell_max: int, dtype=jnp.float32) -> DPState:
    """Pre-sample state (t=0). ``ell_max`` bounds the history buffers so the
    update is usable as a ``lax.scan`` body with static shapes."""
    zeros = tm.tzeros_like(x0, dtype)
    v_hist = tm.tmap(
        lambda z: jnp.zeros((max(ell_max - 1, 1),) + z.shape, dtype), zeros
    )
    return DPState(
        t=jnp.zeros((), jnp.int32),
        xbar=zeros,
        delta_tilde=zeros,
        v_hist=v_hist,
        c_hist=jnp.zeros((max(ell_max - 1, 1),), dtype),
        x0=tm.tcast(x0, dtype),
    )


def online_dp_update(state: DPState, x_t, rho) -> DPState:
    """Absorb one posterior sample. Traceable (lax.cond over the t=1 case)."""
    x_t = tm.tcast(x_t, state.c_hist.dtype)
    t_new = state.t + 1

    def first(st: DPState) -> DPState:
        return st._replace(
            t=t_new, xbar=x_t, delta_tilde=tm.tsub(st.x0, x_t)
        )

    def rest(st: DPState) -> DPState:
        tf = t_new.astype(st.c_hist.dtype)
        u = tm.tsub(x_t, st.xbar)
        # dots_k = v_k . u for the whole history at once, masked to k <= t
        dots = _hist_dots(st.v_hist, u)
        n_hist = st.c_hist.shape[0]
        mask = jnp.arange(n_hist) < (st.t - 1)
        coefs = jnp.where(mask, st.c_hist * dots, 0.0)
        v = _hist_combine(u, st.v_hist, coefs)
        g = (tf - 1.0) * rho / tf
        a = tm.tvdot(u, v)
        b = tm.tvdot(u, st.delta_tilde)
        scale = (1.0 + g * (tf * b - a) / (1.0 + g * a)) / tf
        delta_tilde = tm.taxpy(-scale, v, st.delta_tilde)
        xbar = tm.taxpy(1.0 / tf, u, st.xbar)
        v_hist = tm.tdynamic_update(st.v_hist, v, st.t - 1)
        c_hist = jax.lax.dynamic_update_index_in_dim(
            st.c_hist, g / (1.0 + g * a), st.t - 1, axis=0
        )
        return st._replace(
            t=t_new, xbar=xbar, delta_tilde=delta_tilde, v_hist=v_hist,
            c_hist=c_hist,
        )

    return jax.lax.cond(state.t == 0, first, rest, state)


def online_dp_delta(state: DPState, rho):
    """Delta_hat_t = Delta~_t / rho_t — the any-time estimate.

    With t=0 this returns zeros; with t=1 it returns x0 - x1 == the FedAvg
    delta (the paper's any-time property).
    """
    tf = jnp.maximum(state.t, 1).astype(state.c_hist.dtype)
    r = 1.0 / (1.0 + (tf - 1.0) * rho)
    return tm.tscale(1.0 / r, state.delta_tilde)


def _hist_dots(v_hist, u):
    """dots[k] = <v_hist[k], u> summed across leaves -> (ell_max-1,)."""
    leaves_v = jax.tree_util.tree_leaves(v_hist)
    leaves_u = jax.tree_util.tree_leaves(u)
    dt = jnp.promote_types(leaves_u[0].dtype, jnp.float32)
    acc = 0.0
    for vh, ul in zip(leaves_v, leaves_u):
        acc = acc + jnp.einsum("k...,...->k", vh.astype(dt), ul.astype(dt))
    return acc


def _hist_combine(u, v_hist, coefs):
    """u - sum_k coefs[k] * v_hist[k], leafwise."""
    def leaf(ul, vh):
        c = coefs.reshape((-1,) + (1,) * ul.ndim)
        return ul - jnp.sum(c * vh, axis=0)

    return tm.tmap(leaf, u, v_hist)
