"""Async double-buffered round engine with staleness-aware server updates.

The paper's server is a serial consumer of cohort deltas (Algorithm 1), but
its posterior-inference framing treats the aggregated delta as a stochastic
pseudo-gradient of the surrogate quadratic (Proposition 2) — which
tolerates *bounded staleness*: FA-LD-style analyses (Deng et al. 2022) show
server-side averaging remains convergent when the delta was computed at a
slightly older iterate. This engine exploits that to buy wall-clock:

  * cohort t+1's client compute is dispatched on device *before* round t's
    server update has been applied (up to ``max_staleness`` cohorts in
    flight beyond the one being applied);
  * a delta computed at params version ``v`` and applied at version
    ``v + s`` is down-weighted by ``staleness_discount ** s`` before the
    server optimizer sees it;
  * the host-side input pipeline (cohort sampling + batch stacking) runs
    ``prefetch_rounds`` ahead on a background thread
    (``data.prefetch.CohortPrefetcher``);
  * per-round metrics stay on device until the loop finishes — the
    synchronous path's per-round blocking ``float(loss)`` sync is gone.

``max_staleness=0`` dispatches exactly one cohort at a time and applies it
immediately (discount ``1.0``), reproducing the synchronous fused round
numerically (tests/test_async_engine.py).

The two stages come from ``round_program.make_cohort_program`` /
``make_server_program``; this module jits each once and owns the pipeline
bookkeeping. ``FedSim`` (``fed.async_rounds=True``) and ``launch.train
--async-rounds`` are the frontends.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, NamedTuple, Optional, Tuple, Union

import jax

from repro.core.client_state import (ClientStateStore, DeviceClientStateStore,
                                     device_scatter, jit_donating_store)
from repro.core.history import json_scalar
from repro.core.server import ServerState
from repro.data.prefetch import Cohort, close_prefetcher, make_prefetcher

#: build_cohort(round_idx) -> Cohort (see data/prefetch.py)
BuildCohort = Callable[[int], Cohort]


class _InFlight(NamedTuple):
    """One dispatched-but-unapplied cohort in the pipeline.

    ``version`` is the params version the cohort saw when dispatched;
    ``client_ids`` / ``new_states`` / ``stamps`` carry the per-client
    state write-back (None for stateless regimes): the gather-time write
    stamps let the store drop a stale write from a cohort that overlapped
    an already-applied one on the same client. With the device store the
    three are device arrays (the traced id vector, the cohort program's
    stacked state output, the on-device stamp snapshot) and the write-back
    never touches the host. ``survivors`` / ``extra_staleness`` /
    ``dropped`` are the cohort's fault annotations (``data.cohort_source``):
    the survivors mask was already threaded through the dispatched cohort
    program and gates the state write-back; straggler lateness is added to
    the staleness exponent at apply time.
    """

    agg: object
    metrics: dict
    version: int
    round_idx: int
    is_burn: bool
    client_ids: object = None
    new_states: object = None
    stamps: object = None
    survivors: object = None
    extra_staleness: int = 0
    dropped: int = 0


@dataclasses.dataclass
class AsyncRoundEngine:
    """Drives ``num_rounds`` staleness-aware rounds over split programs.

    ``cohort_fn(state, batches, weights) -> (agg, metrics)`` and
    ``server_fn(state, agg, discount) -> state`` are jitted here
    (pass the raw builders, not pre-jitted functions). ``burn_cohort_fn`` /
    ``burn_server_fn`` (optional) are used for the first ``burn_in_rounds``
    rounds — the burn regime of the config's algorithm (e.g. the FedAvg
    regime of a FedPA config, Section 5.2); the burn server stage exists
    because a burn regime may aggregate in a different payload space than
    the sampling regime (``fedpa_precision`` burns in as fedavg).

    Stateful algorithms (``stateful=True`` + a ``client_store``): each
    dispatched cohort gathers its clients' persistent state from the store
    and its ``cohort_fn`` returns ``(agg, metrics, new_states)``; the
    write-back happens at APPLY time, in round order, tagged with the
    gather-time stamps — so when two in-flight cohorts overlap on a
    client, the one applied second (which gathered before the first wrote)
    is dropped for that client instead of clobbering the fresher state.

    With the host ``ClientStateStore`` the write-back pulls ``new_states``
    to the host, which syncs on that cohort's compute — one device sync
    per stateful round that stateless rounds avoid. With a
    ``DeviceClientStateStore`` the gather happens *inside* the dispatched
    cohort program (``cohort_fn(state, batches, weights, store_state,
    client_ids) -> (agg, metrics, new_states, stamps)``, the device-store
    signature of ``make_cohort_program``) and the write-back is a small
    jitted ``device_scatter`` (store buffers donated): the CAS runs
    against the on-device stamps, the dropped-write count stays a device
    counter folded into the end-of-loop sync with the losses, and the
    stateful pipeline regains the stateless path's sync-free round loop.
    """

    cohort_fn: Callable
    server_fn: Callable
    max_staleness: int = 1
    staleness_discount: float = 1.0
    burn_cohort_fn: Optional[Callable] = None
    burn_server_fn: Optional[Callable] = None
    burn_in_rounds: int = 0
    prefetch_rounds: int = 0
    prefetch_backend: str = "thread"
    client_store: Optional[Union[ClientStateStore,
                                 DeviceClientStateStore]] = None
    stateful: bool = False
    burn_stateful: bool = False
    #: Record per-round ``dropped`` / ``straggled`` counts in history
    #: (``FedSim`` sets it from ``fed.fault_injection``).
    record_faults: bool = False
    #: Per-round communicated bytes (``compression.round_bytes`` dicts with
    #: ``bytes_up`` / ``bytes_down``), stamped on every history record;
    #: ``burn_round_bytes`` covers the burn regime's (dense) payloads.
    round_bytes: Optional[dict] = None
    burn_round_bytes: Optional[dict] = None

    def __post_init__(self):
        """Validate knobs, normalize the burn-regime flags, jit the stages."""
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if not 0.0 <= self.staleness_discount <= 1.0:
            raise ValueError("staleness_discount must be in [0, 1]")
        if self.burn_cohort_fn is None:
            # no dedicated burn stage: burn rounds run the main cohort_fn,
            # so they are stateful exactly when the main regime is
            self.burn_stateful = self.stateful
        if (self.stateful or self.burn_stateful) and self.client_store is None:
            raise ValueError(
                "stateful=True requires a client-state store (client_store)")
        self._device_store = isinstance(self.client_store,
                                        DeviceClientStateStore)
        # the device write-back stage: donate the store so the (N, ...)
        # buffers alias in place instead of doubling per-client state;
        # a population-sharded store additionally pins the scatter's store
        # output to its own placement so the alias is shard-for-shard
        self._scatter = None
        if self._device_store:
            pop_sh = self.client_store.population_sharding
            self._scatter = jit_donating_store(
                device_scatter, 0,
                out_shardings=None if pop_sh is None else (pop_sh, None))
        self._cohort = jax.jit(self.cohort_fn)
        self._burn = (jax.jit(self.burn_cohort_fn)
                      if self.burn_cohort_fn is not None else self._cohort)
        self._server = jax.jit(self.server_fn)
        self._burn_server = (jax.jit(self.burn_server_fn)
                             if self.burn_server_fn is not None
                             else self._server)

    def _dispatch(self, state: ServerState, cohort: Cohort, t_next: int,
                  version: int) -> _InFlight:
        """Dispatch one cohort program and wrap its outputs as ``_InFlight``.

        Stateful regimes also carry the per-client state write-back: with
        the device store the gather happens inside the dispatched program
        against the store's current device buffers (the returned stamps
        snapshot tags the CAS); with the host store the gather is a host
        numpy slice."""
        is_burn = t_next < self.burn_in_rounds
        fn = self._burn if is_burn else self._cohort
        surv = cohort.survivors
        fault = (surv, cohort.extra_staleness, cohort.dropped)
        if not (self.burn_stateful if is_burn else self.stateful):
            agg, metrics = fn(state, cohort.batches, cohort.weights, surv)
            return _InFlight(agg, metrics, version, t_next, is_burn,
                             None, None, None, *fault)
        if self._device_store:
            ids = self.client_store.prepare_ids(cohort.client_ids)
            agg, metrics, new_states, stamps = fn(
                state, cohort.batches, cohort.weights,
                self.client_store.device_state(), ids, surv)
            return _InFlight(agg, metrics, version, t_next, is_burn,
                             ids, new_states, stamps, *fault)
        cstates, stamps = self.client_store.gather(cohort.client_ids)
        agg, metrics, new_states = fn(state, cohort.batches, cohort.weights,
                                      cstates, surv)
        return _InFlight(agg, metrics, version, t_next, is_burn,
                         cohort.client_ids, new_states, stamps, *fault)

    def _write_back_states(self, fl: _InFlight, rec: dict) -> None:
        """Apply-order client-state write-back, tagged with the gather-time
        stamps: a client already updated by an overlapping cohort keeps
        that fresher value (stale write dropped); a dropped client's
        half-finished state must not land."""
        if fl.new_states is None:
            return
        if self._device_store:
            # one jitted scatter, store buffers donated; the drop count
            # stays a device scalar until the end-of-loop sync — no
            # per-round host pull
            new_store, drops = self._scatter(
                self.client_store.device_state(), fl.client_ids,
                fl.new_states, fl.stamps, fl.survivors)
            self.client_store.set_device_state(new_store)
            rec["state_drops"] = drops
        else:
            rec["state_drops"] = self.client_store.scatter(
                fl.client_ids, fl.new_states, fl.stamps,
                write_mask=fl.survivors)

    @staticmethod
    def _to_history(raw: List[dict]) -> List[dict]:
        """Convert the on-device round records into JSON-safe history in one
        end-of-loop sync (eval metrics and the device store's state_drops
        counters convert with the losses)."""
        history = []
        for rec in raw:
            entry = {"round": rec["round"], "staleness": rec["staleness"],
                     "loss_first": float(rec["metrics"]["loss_first"]),
                     "loss_last": float(rec["metrics"]["loss_last"])}
            entry["client_loss"] = entry["loss_last"]
            for k in ("dropped", "straggled"):
                if k in rec:
                    entry[k] = rec[k]
            for k in ("bytes_up", "bytes_down"):
                if k in rec:
                    entry[k] = json_scalar(rec[k])
            if "state_drops" in rec:
                entry["state_drops"] = json_scalar(rec["state_drops"])
            entry.update({k: json_scalar(v)
                          for k, v in rec.get("eval", {}).items()})
            history.append(entry)
        return history

    def run(
        self,
        state: ServerState,
        build_cohort: BuildCohort,
        num_rounds: int,
        *,
        eval_fn: Optional[Callable] = None,
        eval_every: int = 1,
        on_round: Optional[Callable] = None,
    ) -> Tuple[ServerState, List[dict]]:
        """Returns ``(state, history)``; one history entry per applied round
        with ``loss_first`` / ``loss_last`` / ``client_loss`` / ``staleness``
        (+ ``eval_fn`` metrics every ``eval_every`` rounds, converted to
        plain Python in the same final sync as the losses, and
        ``state_drops`` — overlap-dropped client-state writes — for
        stateful regimes). Every entry is JSON-serializable.

        ``on_round(record, state)`` fires after each server update with the
        raw (possibly still-on-device) metrics and the post-update state —
        for live logging/checkpointing. Forcing metrics there re-introduces
        a per-round sync, so log sparingly in throughput-sensitive loops.
        """
        if eval_fn is not None and eval_every < 1:
            raise ValueError(
                f"eval_every must be >= 1 when eval_fn is set, got "
                f"{eval_every} (evaluate every round with eval_every=1, or "
                f"pass eval_fn=None to disable evaluation)")
        source = (make_prefetcher(self.prefetch_backend, build_cohort, 0,
                                  num_rounds, depth=self.prefetch_rounds)
                  if self.prefetch_rounds > 0 else None)
        get = source.get if source is not None else build_cohort
        pending: deque = deque()   # _InFlight, in dispatch (== apply) order
        raw: List[dict] = []
        version = 0                # server updates applied so far
        t_next = 0                 # next round to dispatch
        completed = False
        try:
            for t_apply in range(num_rounds):
                # keep up to max_staleness cohorts in flight beyond the one
                # being applied; each remembers the params version it saw
                while (t_next < num_rounds
                       and len(pending) <= self.max_staleness):
                    pending.append(self._dispatch(state, get(t_next),
                                                  t_next, version))
                    t_next += 1

                fl = pending.popleft()
                assert fl.round_idx == t_apply, (fl.round_idx, t_apply)
                # a straggling cohort is applied at its slot but discounted
                # as if it were extra_staleness rounds later — the late
                # delta rides the existing staleness_discount**s path
                staleness = version - fl.version + fl.extra_staleness
                server = self._burn_server if fl.is_burn else self._server
                state = server(state, fl.agg,
                               self.staleness_discount ** staleness)
                version += 1

                rec = {"round": t_apply, "staleness": staleness,
                       "metrics": fl.metrics}
                bts = (self.burn_round_bytes if fl.is_burn
                       else self.round_bytes) or self.round_bytes
                if bts is not None:
                    rec["bytes_up"] = bts["bytes_up"]
                    rec["bytes_down"] = bts["bytes_down"]
                if self.record_faults:
                    rec["dropped"] = int(fl.dropped)
                    rec["straggled"] = int(fl.extra_staleness)
                self._write_back_states(fl, rec)
                if eval_fn is not None and (t_apply % eval_every == 0
                                            or t_apply == num_rounds - 1):
                    rec["eval"] = eval_fn(state.params)
                raw.append(rec)
                if on_round is not None:
                    on_round(rec, state)
            completed = True
        finally:
            if source is not None:
                # a hung prefetch worker stays loud on a clean exit but
                # must not mask an exception unwinding out of the loop
                close_prefetcher(source, unwinding=not completed)

        # one sync at the end instead of one per round — splicing raw
        # device arrays into history broke JSON serialization and hid a
        # sync on first access
        return state, self._to_history(raw)
