"""Deprecated alias: ``AsyncRoundEngine`` is now ``core.engine.RoundEngine``.

The double-buffered async pipeline this module used to implement —
up to ``max_staleness`` cohorts in flight, deltas down-weighted by
``staleness_discount ** s``, apply-order CAS write-back of per-client
state — lives in the unified staleness-general ``RoundEngine``
(``core/engine.py``), whose synchronous path is the same loop with an
in-flight window of one. History records are assembled by the shared
``core.history.RoundRecorder`` (uniform schema, one end-of-loop sync).

Migration: construct ``repro.core.engine.RoundEngine`` directly — the
constructor is a superset of this one (same field names; note
``RoundEngine`` defaults ``max_staleness=0`` where this alias keeps the
historic ``1``). ``AsyncRoundEngine`` remains import- and
constructor-compatible but will not grow new features.
"""
from __future__ import annotations

import dataclasses

from repro.core.engine import BuildCohort, RoundEngine  # noqa: F401

__all__ = ["AsyncRoundEngine", "BuildCohort"]


@dataclasses.dataclass
class AsyncRoundEngine(RoundEngine):
    """Deprecated thin alias of :class:`repro.core.engine.RoundEngine`.

    Kept so existing frontends keep constructing (and validating) the
    async pipeline under its old name; only the ``max_staleness`` default
    differs (1, the historic async default, vs the unified engine's 0).
    Without a fused ``round_fn`` every run — including ``max_staleness=0``
    — takes the split-stage pipeline, exactly as the standalone async
    engine always did.
    """

    max_staleness: int = 1
