"""Async double-buffered round engine with staleness-aware server updates.

The paper's server is a serial consumer of cohort deltas (Algorithm 1), but
its posterior-inference framing treats the aggregated delta as a stochastic
pseudo-gradient of the surrogate quadratic (Proposition 2) — which
tolerates *bounded staleness*: FA-LD-style analyses (Deng et al. 2022) show
server-side averaging remains convergent when the delta was computed at a
slightly older iterate. This engine exploits that to buy wall-clock:

  * cohort t+1's client compute is dispatched on device *before* round t's
    server update has been applied (up to ``max_staleness`` cohorts in
    flight beyond the one being applied);
  * a delta computed at params version ``v`` and applied at version
    ``v + s`` is down-weighted by ``staleness_discount ** s`` before the
    server optimizer sees it;
  * the host-side input pipeline (cohort sampling + batch stacking) runs
    ``prefetch_rounds`` ahead on a background thread
    (``data.prefetch.CohortPrefetcher``);
  * per-round metrics stay on device until the loop finishes — the
    synchronous path's per-round blocking ``float(loss)`` sync is gone.

``max_staleness=0`` dispatches exactly one cohort at a time and applies it
immediately (discount ``1.0``), reproducing the synchronous fused round
numerically (tests/test_async_engine.py).

The two stages come from ``round_program.make_cohort_program`` /
``make_server_program``; this module jits each once and owns the pipeline
bookkeeping. ``FedSim`` (``fed.async_rounds=True``) and ``launch.train
--async-rounds`` are the frontends.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, Optional, Tuple

import jax

from repro.core.server import ServerState
from repro.data.prefetch import Cohort, CohortPrefetcher

#: build_cohort(round_idx) -> Cohort (see data/prefetch.py)
BuildCohort = Callable[[int], Cohort]


@dataclasses.dataclass
class AsyncRoundEngine:
    """Drives ``num_rounds`` staleness-aware rounds over split programs.

    ``cohort_fn(state, batches, weights) -> (agg, metrics)`` and
    ``server_fn(state, agg, discount) -> state`` are jitted here
    (pass the raw builders, not pre-jitted functions). ``burn_cohort_fn`` /
    ``burn_server_fn`` (optional) are used for the first ``burn_in_rounds``
    rounds — the burn regime of the config's algorithm (e.g. the FedAvg
    regime of a FedPA config, Section 5.2); the burn server stage exists
    because a burn regime may aggregate in a different payload space than
    the sampling regime (``fedpa_precision`` burns in as fedavg).
    """

    cohort_fn: Callable
    server_fn: Callable
    max_staleness: int = 1
    staleness_discount: float = 1.0
    burn_cohort_fn: Optional[Callable] = None
    burn_server_fn: Optional[Callable] = None
    burn_in_rounds: int = 0
    prefetch_rounds: int = 0

    def __post_init__(self):
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if not 0.0 <= self.staleness_discount <= 1.0:
            raise ValueError("staleness_discount must be in [0, 1]")
        self._cohort = jax.jit(self.cohort_fn)
        self._burn = (jax.jit(self.burn_cohort_fn)
                      if self.burn_cohort_fn is not None else self._cohort)
        self._server = jax.jit(self.server_fn)
        self._burn_server = (jax.jit(self.burn_server_fn)
                             if self.burn_server_fn is not None
                             else self._server)

    def run(
        self,
        state: ServerState,
        build_cohort: BuildCohort,
        num_rounds: int,
        *,
        eval_fn: Optional[Callable] = None,
        eval_every: int = 1,
        on_round: Optional[Callable] = None,
    ) -> Tuple[ServerState, List[dict]]:
        """Returns ``(state, history)``; one history entry per applied round
        with ``loss_first`` / ``loss_last`` / ``client_loss`` / ``staleness``
        (+ ``eval_fn`` metrics every ``eval_every`` rounds).

        ``on_round(record, state)`` fires after each server update with the
        raw (possibly still-on-device) metrics and the post-update state —
        for live logging/checkpointing. Forcing metrics there re-introduces
        a per-round sync, so log sparingly in throughput-sensitive loops.
        """
        source = (CohortPrefetcher(build_cohort, 0, num_rounds,
                                   depth=self.prefetch_rounds)
                  if self.prefetch_rounds > 0 else None)
        get = source.get if source is not None else build_cohort
        pending: deque = deque()  # (agg, metrics, version, round, is_burn)
        raw: List[dict] = []
        version = 0                # server updates applied so far
        t_next = 0                 # next round to dispatch
        try:
            for t_apply in range(num_rounds):
                # keep up to max_staleness cohorts in flight beyond the one
                # being applied; each remembers the params version it saw
                while (t_next < num_rounds
                       and len(pending) <= self.max_staleness):
                    cohort = get(t_next)
                    is_burn = t_next < self.burn_in_rounds
                    fn = self._burn if is_burn else self._cohort
                    agg, metrics = fn(state, cohort.batches, cohort.weights)
                    pending.append((agg, metrics, version, t_next, is_burn))
                    t_next += 1

                agg, metrics, v, t, is_burn = pending.popleft()
                assert t == t_apply, (t, t_apply)
                staleness = version - v
                server = self._burn_server if is_burn else self._server
                state = server(state, agg,
                               self.staleness_discount ** staleness)
                version += 1

                rec = {"round": t_apply, "staleness": staleness,
                       "metrics": metrics}
                if eval_fn is not None and (t_apply % eval_every == 0
                                            or t_apply == num_rounds - 1):
                    rec["eval"] = eval_fn(state.params)
                raw.append(rec)
                if on_round is not None:
                    on_round(rec, state)
        finally:
            if source is not None:
                source.close()

        # one sync at the end instead of one per round
        history = []
        for rec in raw:
            entry = {"round": rec["round"], "staleness": rec["staleness"],
                     "loss_first": float(rec["metrics"]["loss_first"]),
                     "loss_last": float(rec["metrics"]["loss_last"])}
            entry["client_loss"] = entry["loss_last"]
            entry.update(rec.get("eval", {}))
            history.append(entry)
        return state, history
