"""The unified compiled round engine: one XLA program per federated round.

An entire generalized federated round (Algorithm 1) — cohort of clients
running their local updates, weighted delta aggregation, server optimizer
step — is staged as a single jittable function, so the simulation path
(``round.FedSim``) and the multi-pod SPMD path (``sharded_round``) pay one
dispatch per round instead of one per client. The round factors into two
separately jittable stages:

  * ``make_cohort_program`` — clients -> weighted mean delta (+ losses);
  * ``make_server_program`` — server optimizer step, with an optional
    staleness discount on the delta (``core/async_engine.py`` overlaps
    cohort t+1 with server round t using exactly these two stages);

and ``make_round_program`` fuses them back into the single-dispatch
``round_fn`` the synchronous paths jit. Three client placements:

  * ``parallel``  — ``vmap`` over the client axis; on a mesh, pass
    ``spmd_axes`` so per-client state shards one-client-per-data-slice
    (the paper's O(d)-communication pattern made structural).
  * ``sequential`` — ``lax.scan`` over clients, each using the full mesh;
    for memory-bound configs (>=10B archs with FSDP-sharded client state).
  * ``chunked``   — scan-of-vmap: chunks of ``chunk_size`` clients run
    vmapped, chunks run sequentially, so ``clients_per_round`` larger than
    memory allows still compiles (and dispatches) once. Cohorts that don't
    divide evenly are padded with zero-weight duplicate clients.

All placements share one copy of the client math (``make_client_update`` —
FedAvg / FedPA / streaming-FedPA / MIME) and of the weighted aggregation,
and they produce the same round math up to floating-point reduction order
(tests/test_round_engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import tree_math as tm
from repro.core.client import make_client_update
from repro.core.server import (ServerState, normalized_weights,
                               server_update, weighted_sum)
from repro.optim import Optimizer, get_optimizer

#: Client placements understood by the engine.
PLACEMENTS = ("parallel", "sequential", "chunked")


def resolve_placement(fed: FedConfig, placement: Optional[str] = None) -> str:
    """Explicit argument wins; otherwise the ``FedConfig`` knob."""
    p = placement or fed.round_placement
    if p not in PLACEMENTS:
        raise ValueError(f"unknown placement {p!r}; known: {PLACEMENTS}")
    return p


def _resolve_chunk(fed: FedConfig, chunk_size: Optional[int],
                   num_clients: int) -> int:
    c = chunk_size if chunk_size is not None else fed.round_chunk_size
    if c <= 0:
        # auto: biggest power-of-two chunk <= 8 that isn't larger than the
        # cohort — small enough to bound peak memory, big enough to amortize.
        c = 1
        while c * 2 <= min(8, num_clients):
            c *= 2
    return min(c, num_clients)


def make_cohort_program(
    grad_fn: Callable,
    fed: FedConfig,
    *,
    placement: Optional[str] = None,
    chunk_size: Optional[int] = None,
    spmd_axes: Optional[Tuple[str, ...]] = None,
    use_sampling: bool = True,
    client_opt: Optional[Optimizer] = None,
    wrap_client: Optional[Callable] = None,
    prepare_params: Optional[Callable] = None,
    constrain_accum: Optional[Callable] = None,
) -> Callable:
    """Build ``cohort_fn(state, client_batches[, client_weights])``.

    The client half of a round: cohort of local updates -> weighted mean
    delta. ``client_batches``: pytree whose leaves carry a leading client
    axis C and a second per-client step axis K (``fed.local_steps``).
    ``client_weights`` (optional, shape (C,)) are normalized inside the
    program; None means uniform. Returns ``(mean_delta, {"loss_first",
    "loss_last"})`` with the losses averaged (unweighted) over the cohort.

    Takes the full ``ServerState`` (not just params) because MIME clients
    read the frozen server momentum out of the optimizer state; only
    ``state.params`` (+ opt stats) are consumed, so the async engine may
    pass a state that is ``s`` versions stale.
    """
    eff = fed
    if not use_sampling and fed.algorithm == "fedpa":
        eff = dataclasses.replace(fed, algorithm="fedavg")
    client_opt = client_opt or get_optimizer(eff.client_opt, eff.client_lr,
                                             eff.client_momentum)
    client_update = make_client_update(grad_fn, eff, client_opt)
    if wrap_client is not None:
        client_update = wrap_client(client_update)
    place = resolve_placement(fed, placement)
    needs_server_stats = eff.algorithm == "mime"
    delta_dtype = jnp.dtype(eff.delta_dtype)

    def _server_stats(state: ServerState):
        """Frozen server momentum shipped to MIME clients (Section 6)."""
        opt = state.opt_state
        if isinstance(opt, dict) and "m" in opt:
            return opt["m"]
        return tm.tzeros_like(state.params)

    def _client_axes(n_extra: int):
        return (None, 0) + (None,) * n_extra

    def _run_parallel(params, client_batches, weights, extras):
        vm = jax.vmap(client_update, in_axes=_client_axes(len(extras)),
                      spmd_axis_name=spmd_axes)
        deltas, metrics = vm(params, client_batches, *extras)
        return weighted_sum(deltas, weights), metrics

    def _zero_accum(params):
        acc = tm.tzeros_like(params, delta_dtype)
        if constrain_accum is not None:
            acc = constrain_accum(acc, params)
        return acc

    def _run_sequential(params, client_batches, weights, extras):
        def body(acc, xs):
            batches, w = xs
            delta, metrics = client_update(params, batches, *extras)
            acc = tm.tmap(lambda a, d: a + (w * d).astype(a.dtype), acc, delta)
            return acc, metrics

        return jax.lax.scan(body, _zero_accum(params),
                            (client_batches, weights))

    def _run_chunked(params, client_batches, weights, extras, chunk):
        C = weights.shape[0]
        n_chunks = -(-C // chunk)
        pad = n_chunks * chunk - C
        if pad:
            # zero-weight duplicates of client 0 square off the last chunk
            client_batches = tm.tmap(
                lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[:1], pad, axis=0)], axis=0),
                client_batches,
            )
            weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])
        chunked = tm.tmap(
            lambda x: x.reshape((n_chunks, chunk) + x.shape[1:]), client_batches
        )
        w_chunks = weights.reshape(n_chunks, chunk)

        def body(acc, xs):
            batches, w = xs
            vm = jax.vmap(client_update, in_axes=_client_axes(len(extras)),
                          spmd_axis_name=spmd_axes)
            deltas, metrics = vm(params, batches, *extras)
            acc = tm.tmap(lambda a, c: a + c.astype(a.dtype),
                          acc, weighted_sum(deltas, w))
            return acc, metrics

        mean_delta, metrics = jax.lax.scan(body, _zero_accum(params),
                                           (chunked, w_chunks))
        # (n_chunks, chunk) -> (C,) with the padding sliced off
        metrics = tm.tmap(lambda x: x.reshape((n_chunks * chunk,))[:C], metrics)
        return mean_delta, metrics

    def cohort_fn(state: ServerState, client_batches, client_weights=None):
        C = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
        params = (state.params if prepare_params is None
                  else prepare_params(state.params))
        extras = (_server_stats(state),) if needs_server_stats else ()
        weights = normalized_weights(client_weights, C)

        if place == "parallel":
            mean_delta, metrics = _run_parallel(params, client_batches,
                                                weights, extras)
        elif place == "sequential":
            mean_delta, metrics = _run_sequential(params, client_batches,
                                                  weights, extras)
        else:
            chunk = _resolve_chunk(fed, chunk_size, C)
            mean_delta, metrics = _run_chunked(params, client_batches,
                                               weights, extras, chunk)

        return mean_delta, {
            "loss_first": jnp.mean(metrics["loss_first"]),
            "loss_last": jnp.mean(metrics["loss_last"]),
        }

    return cohort_fn


def make_server_program(
    fed: FedConfig,
    *,
    server_opt: Optional[Optimizer] = None,
    prepare_params: Optional[Callable] = None,
    finalize_params: Optional[Callable] = None,
) -> Callable:
    """Build ``server_fn(state, mean_delta, discount=None) -> new_state``.

    The server half of a round: one server-optimizer step on the aggregated
    pseudo-gradient. ``discount`` (optional traced scalar) scales the delta
    before the optimizer sees it — the async engine passes
    ``staleness_discount ** s`` for a delta computed at params version ``v``
    and applied at version ``v + s``; ``discount=None`` (or 1.0) is the
    synchronous update. The scaling runs in fp32 and casts back to the
    delta dtype, so a discount of exactly 1.0 is a bitwise no-op and the
    ``staleness=0`` async path matches the fused sync program.
    """
    server_opt = server_opt or get_optimizer(fed.server_opt, fed.server_lr,
                                             fed.server_momentum)

    def server_fn(state: ServerState, mean_delta, discount=None):
        params = (state.params if prepare_params is None
                  else prepare_params(state.params))
        if discount is not None:
            d = jnp.asarray(discount, jnp.float32)
            mean_delta = tm.tmap(
                lambda x: (d * x.astype(jnp.float32)).astype(x.dtype),
                mean_delta)
        new_state = server_update(state._replace(params=params), mean_delta,
                                  server_opt)
        if finalize_params is not None:
            new_state = new_state._replace(
                params=finalize_params(new_state.params))
        return new_state

    return server_fn


def make_round_program(
    grad_fn: Callable,
    fed: FedConfig,
    *,
    placement: Optional[str] = None,
    chunk_size: Optional[int] = None,
    spmd_axes: Optional[Tuple[str, ...]] = None,
    use_sampling: bool = True,
    client_opt: Optional[Optimizer] = None,
    server_opt: Optional[Optimizer] = None,
    wrap_client: Optional[Callable] = None,
    prepare_params: Optional[Callable] = None,
    finalize_params: Optional[Callable] = None,
    constrain_accum: Optional[Callable] = None,
) -> Callable:
    """Build the fused ``round_fn(state, client_batches[, client_weights])``.

    Composes ``make_cohort_program`` and ``make_server_program`` into the
    single-dispatch synchronous round: cohort of client updates -> weighted
    aggregation -> server step. Returns ``(new_state, {"loss_first",
    "loss_last"})``.

    ``use_sampling=False`` builds the burn-in-round variant of a FedPA
    config (the FedAvg regime of Section 5.2) with identical signature.

    Sharding hooks (all optional, identity by default) let the multi-pod
    path reuse this exact program structure:

    * ``wrap_client(update) -> update'`` — wrap the per-client update, e.g.
      to all-gather FSDP-sharded params at the compute boundary.
    * ``prepare_params(params)`` — applied to the server params before they
      are handed to clients / the server optimizer. Must be idempotent
      (sharding constraints are): the cohort and server stages each apply
      it, so the fused round runs it twice per round.
    * ``finalize_params(params)`` — applied to the post-update params.
    * ``constrain_accum(zeros, like_params)`` — sharding constraint for the
      sequential/chunked delta accumulator.

    The returned function is pure and jit-compatible; callers own the
    ``jax.jit`` (``FedSim`` jits it, the dry-run lowers it un-jitted).
    """
    cohort_fn = make_cohort_program(
        grad_fn, fed, placement=placement, chunk_size=chunk_size,
        spmd_axes=spmd_axes, use_sampling=use_sampling, client_opt=client_opt,
        wrap_client=wrap_client, prepare_params=prepare_params,
        constrain_accum=constrain_accum,
    )
    server_fn = make_server_program(
        fed, server_opt=server_opt, prepare_params=prepare_params,
        finalize_params=finalize_params,
    )

    def round_fn(state: ServerState, client_batches, client_weights=None):
        mean_delta, metrics = cohort_fn(state, client_batches, client_weights)
        return server_fn(state, mean_delta), metrics

    return round_fn
