"""The unified compiled round engine: one XLA program per federated round.

An entire generalized federated round (Algorithm 1) — cohort of clients
running their local updates, weighted payload aggregation, server optimizer
step — is staged as a single jittable function, so the simulation path
(``round.FedSim``) and the multi-pod SPMD path (``sharded_round``) pay one
dispatch per round instead of one per client. The round factors into two
separately jittable stages:

  * ``make_cohort_program`` — clients -> aggregated payload (+ losses);
  * ``make_server_program`` — server optimizer step, with an optional
    staleness discount on the aggregate (``core/async_engine.py`` overlaps
    cohort t+1 with server round t using exactly these two stages);

and ``make_round_program`` fuses them back into the single-dispatch
``round_fn`` the synchronous paths jit. Three client placements:

  * ``parallel``  — ``vmap`` over the client axis; on a mesh, pass
    ``spmd_axes`` so per-client state shards one-client-per-data-slice
    (the paper's O(d)-communication pattern made structural).
  * ``sequential`` — ``lax.scan`` over clients, each using the full mesh;
    for memory-bound configs (>=10B archs with FSDP-sharded client state).
  * ``chunked``   — scan-of-vmap: chunks of ``chunk_size`` clients run
    vmapped, chunks run sequentially, so ``clients_per_round`` larger than
    memory allows still compiles (and dispatches) once. Cohorts that don't
    divide evenly are padded with zero-weight duplicate clients.

All round math is resolved through the ``repro.algorithms`` strategy API
(``FedConfig.algorithm`` -> a registered ``FedAlgorithm``): the algorithm
owns the client update, the broadcast extras, the linear payload
accumulator the placements fold into, and the server step. The placements
only decide how the cohort is laid out; they produce the same round math up
to floating-point reduction order (tests/test_round_engine.py).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import tree_math as tm
from repro.core.client_state import STORES, device_gather, device_scatter
from repro.core.server import ServerState, normalized_weights
from repro.optim import Optimizer, get_optimizer

#: Client placements understood by the engine.
PLACEMENTS = ("parallel", "sequential", "chunked")

#: Client-state placements understood by the engine (the registered store
#: implementations — ``core.client_state.STORES`` is the source of truth).
STATE_PLACEMENTS = tuple(STORES)


def resolve_placement(fed: FedConfig, placement: Optional[str] = None) -> str:
    """Explicit argument wins; otherwise the ``FedConfig`` knob."""
    p = placement or fed.round_placement
    if p not in PLACEMENTS:
        raise ValueError(f"unknown placement {p!r}; known: {PLACEMENTS}")
    return p


def resolve_state_placement(fed: FedConfig,
                            state_placement: Optional[str] = None) -> str:
    """Explicit argument wins; otherwise ``fed.client_state_placement``."""
    p = state_placement or fed.client_state_placement
    if p not in STATE_PLACEMENTS:
        raise ValueError(
            f"unknown client-state placement {p!r}; known: {STATE_PLACEMENTS}")
    return p


def _resolve_chunk(fed: FedConfig, chunk_size: Optional[int],
                   num_clients: int) -> int:
    c = chunk_size if chunk_size is not None else fed.round_chunk_size
    if c <= 0:
        # auto: biggest power-of-two chunk <= 8 that isn't larger than the
        # cohort — small enough to bound peak memory, big enough to amortize.
        c = 1
        while c * 2 <= min(8, num_clients):
            c *= 2
    return min(c, num_clients)


class _CohortCtx(NamedTuple):
    """Everything the placement runners need, resolved once at build time."""
    alg: object
    client_update: Callable
    spmd_axes: Optional[Tuple[str, ...]]
    stateful: bool
    constrain_accum: Optional[Callable]
    fed: FedConfig
    place: str
    chunk_size: Optional[int]
    prepare_params: Optional[Callable]
    server_opt: Optimizer


def _budget_masked(grad_fn: Callable) -> Callable:
    """Wrap ``grad_fn`` with the heterogeneous local-step budget mask.

    A client past its budget runs "idle" steps — gradients masked to zero
    so plain-SGD params freeze (exactness enforced by FedConfig:
    client_opt="sgd" and a gradient-driven algorithm). The per-step 0/1
    budget mask rides in the batch dict as the "_active" leaf, (C, K)
    alongside the data's (C, K, ...) leaves — data/cohort_source.py
    injects it."""
    def masked_grad_fn(params, batch):
        if not isinstance(batch, dict) or "_active" not in batch:
            raise ValueError(
                "min_local_steps > 0 needs dict batches carrying the "
                "'_active' per-step budget mask "
                "(data/cohort_source.py injects it)")
        active = jnp.asarray(batch["_active"], jnp.float32)
        data = {k: v for k, v in batch.items() if k != "_active"}
        loss, grads = grad_fn(params, data)
        return loss, tm.tmap(lambda g: g * active.astype(g.dtype), grads)

    return masked_grad_fn


def _client_axes(ctx: _CohortCtx, n_extra: int):
    return (None, 0) + ((0,) if ctx.stateful else ()) + (None,) * n_extra


def _qffl_weights(ctx: _CohortCtx, weights, metrics):
    """q-FFL effective weights: ``w_k * max(loss_first_k, 0)**q``.

    The fairness tilt of q-FFL (Li et al. 2020) — high-loss clients count
    for more in the aggregate; ``_run_cohort`` renormalizes the tilted fold
    by ``sum_k w_k * lam_k`` so the aggregate stays a weighted mean. The
    gate is trace-time: ``fed.qffl_q == 0`` (the default) returns the
    weights untouched, so the default program is bitwise the untilted one.
    ``loss_first`` (the pre-update local loss) is the tilt signal so the
    weight reflects where the client *started* this round, not what its
    local steps already fixed. Zero-weight entries (dropped clients,
    chunk padding) stay zero for any q.
    """
    if not ctx.fed.qffl_q:
        return weights
    lam = jnp.maximum(metrics["loss_first"], 0.0) ** ctx.fed.qffl_q
    return weights * lam.astype(weights.dtype)


def _run_parallel(ctx, params, client_batches, weights, extras, cstates):
    vm = jax.vmap(ctx.client_update, in_axes=_client_axes(ctx, len(extras)),
                  spmd_axis_name=ctx.spmd_axes)
    res = vm(params, client_batches,
             *((cstates,) if ctx.stateful else ()), *extras)
    w = _qffl_weights(ctx, weights, res.metrics)
    return (ctx.alg.reduce_stacked(res.payload, w), res.metrics,
            res.state_update)


def _zero_accum(ctx, params):
    acc = ctx.alg.init_accum(params)
    if ctx.constrain_accum is not None:
        acc = ctx.alg.map_components(
            lambda z: ctx.constrain_accum(z, params), acc)
    return acc


def _run_sequential(ctx, params, client_batches, weights, extras, cstates):
    def body(acc, xs):
        batches, w, cs = xs
        res = ctx.client_update(params, batches,
                                *((cs,) if ctx.stateful else ()), *extras)
        return (ctx.alg.accumulate(acc, res.payload,
                                   _qffl_weights(ctx, w, res.metrics)),
                (res.metrics, res.state_update))

    agg, (metrics, new_states) = jax.lax.scan(
        body, _zero_accum(ctx, params),
        (client_batches, weights, cstates if ctx.stateful else ()))
    return agg, metrics, new_states


def _run_chunked(ctx, params, client_batches, weights, extras, cstates,
                 chunk):
    C = weights.shape[0]
    n_chunks = -(-C // chunk)
    pad = n_chunks * chunk - C

    def pad_lead(x):
        return jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)],
                               axis=0)

    if pad:
        # zero-weight duplicates of client 0 square off the last chunk
        client_batches = tm.tmap(pad_lead, client_batches)
        weights = jnp.concatenate([weights, jnp.zeros((pad,), weights.dtype)])
        if ctx.stateful:
            cstates = tm.tmap(pad_lead, cstates)

    def to_chunks(x):
        return x.reshape((n_chunks, chunk) + x.shape[1:])

    chunked = tm.tmap(to_chunks, client_batches)
    w_chunks = weights.reshape(n_chunks, chunk)
    cs_chunks = tm.tmap(to_chunks, cstates) if ctx.stateful else ()

    def body(acc, xs):
        batches, w, cs = xs
        vm = jax.vmap(ctx.client_update,
                      in_axes=_client_axes(ctx, len(extras)),
                      spmd_axis_name=ctx.spmd_axes)
        res = vm(params, batches,
                 *((cs,) if ctx.stateful else ()), *extras)
        acc = tm.tmap(lambda a, c: a + c.astype(a.dtype),
                      acc, ctx.alg.reduce_stacked(
                          res.payload, _qffl_weights(ctx, w, res.metrics)))
        return acc, (res.metrics, res.state_update)

    agg, (metrics, new_states) = jax.lax.scan(
        body, _zero_accum(ctx, params), (chunked, w_chunks, cs_chunks))
    # (n_chunks, chunk) -> (C,) with the padding sliced off
    unpad = lambda x: x.reshape((n_chunks * chunk,) + x.shape[2:])[:C]
    metrics = tm.tmap(unpad, metrics)
    if ctx.stateful:
        new_states = tm.tmap(unpad, new_states)
    return agg, metrics, new_states


def _run_cohort(ctx: _CohortCtx, state: ServerState, client_batches,
                client_weights, client_states, survivor_mask=None):
    """One cohort pass through the resolved placement runner."""
    C = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
    params = (state.params if ctx.prepare_params is None
              else ctx.prepare_params(state.params))
    extras = ctx.alg.broadcast(state, ctx.server_opt)
    if survivor_mask is not None:
        mask = jnp.asarray(survivor_mask, jnp.float32)
        base = (jnp.ones((C,), jnp.float32) if client_weights is None
                else jnp.asarray(client_weights, jnp.float32))
        client_weights = base * mask
    weights = normalized_weights(client_weights, C)

    if ctx.place == "parallel":
        agg, metrics, new_states = _run_parallel(
            ctx, params, client_batches, weights, extras, client_states)
    elif ctx.place == "sequential":
        agg, metrics, new_states = _run_sequential(
            ctx, params, client_batches, weights, extras, client_states)
    else:
        chunk = _resolve_chunk(ctx.fed, ctx.chunk_size, C)
        agg, metrics, new_states = _run_chunked(
            ctx, params, client_batches, weights, extras, client_states,
            chunk)

    if ctx.fed.qffl_q:
        # the placements folded with the q-FFL-tilted weights w_k * lam_k
        # (_qffl_weights); dividing the linear accumulator by
        # z = sum_k w_k * lam_k makes the effective weights
        # (w_k * lam_k) / z — a normalized weighting, same contract as the
        # untilted path. max() guards the all-dropped / all-zero-loss
        # cohort (z = 0 -> zero aggregate, matching the untilted path).
        # Ratio-form aggregates ({num, den} pairs — fedpa_precision,
        # fedlora) cancel z in finish_cohort, so fedlora's encoded-codec
        # map_components skipping the division is still exact.
        lam = jnp.maximum(metrics["loss_first"], 0.0) ** ctx.fed.qffl_q
        z = jnp.sum(weights * lam.astype(weights.dtype))
        agg = ctx.alg.map_components(
            lambda a: a / jnp.maximum(z, 1e-12).astype(a.dtype), agg)

    # cohort-stage epilogue on the summed accumulator, still traced inside
    # the cohort program: fedlora decodes its low-rank accumulator here with
    # the dispatch-time state.round (the async engine may apply the result
    # against a newer server state)
    agg = ctx.alg.finish_cohort(state, agg)

    if survivor_mask is None:
        losses = {
            "loss_first": jnp.mean(metrics["loss_first"]),
            "loss_last": jnp.mean(metrics["loss_last"]),
        }
    else:
        # survivor-only means; an all-dropped round reports 0.0 losses
        mask = jnp.asarray(survivor_mask, jnp.float32)
        n = jnp.maximum(jnp.sum(mask), 1.0)
        losses = {
            "loss_first": jnp.sum(metrics["loss_first"] * mask) / n,
            "loss_last": jnp.sum(metrics["loss_last"] * mask) / n,
        }
    return agg, losses, new_states


def make_cohort_program(
    grad_fn: Callable,
    fed: FedConfig,
    *,
    placement: Optional[str] = None,
    chunk_size: Optional[int] = None,
    spmd_axes: Optional[Tuple[str, ...]] = None,
    use_sampling: bool = True,
    client_opt: Optional[Optimizer] = None,
    server_opt: Optional[Optimizer] = None,
    wrap_client: Optional[Callable] = None,
    prepare_params: Optional[Callable] = None,
    constrain_accum: Optional[Callable] = None,
    state_placement: Optional[str] = None,
) -> Callable:
    """Build ``cohort_fn(state, client_batches[, client_weights[, states]])``.

    The client half of a round: cohort of local updates -> aggregated
    payload (the algorithm's linear accumulator; for mean-delta algorithms
    this IS the weighted mean delta). ``client_batches``: pytree whose
    leaves carry a leading client axis C and a second per-client step axis
    K (``fed.local_steps``). ``client_weights`` (optional, shape (C,)) are
    normalized inside the program; None means uniform. Returns
    ``(agg, {"loss_first", "loss_last"})`` with the losses averaged
    (unweighted) over the cohort; ``agg`` feeds ``make_server_program``'s
    server stage, which finalizes it into the pseudo-gradient.

    ``survivor_mask`` (optional trailing argument, shape (C,) float 0/1) is
    the fault-injection path (``data/cohort_source.py``): a client whose
    mask entry is 0 dropped out mid-round, so its weight is zeroed *before*
    normalization — the survivors' weighted partial aggregation renormalizes
    over the survivors only — and its losses are excluded from the cohort
    means. An all-zero mask degrades to a zero aggregate (traced
    ``normalized_weights`` yields zero weights, never NaN), i.e. the server
    sees a zero pseudo-gradient for an all-dropped round. ``None`` (the
    default) traces the exact mask-free program of the fault-free engine,
    so zero-rate fault configs are bitwise-identical to today's rounds.

    For a *stateful* algorithm (``alg.stateful``) the signature depends on
    the client-state placement (``state_placement``, default
    ``fed.client_state_placement``):

    * ``"host"`` — one extra argument and result: ``cohort_fn(state,
      client_batches, client_weights, client_states) -> (agg, losses,
      new_client_states)``. ``client_states`` is the cohort's gathered
      ``ClientStateStore`` slice (leading axis C) and
      ``new_client_states`` the stacked ``ClientResult.state_update`` to
      scatter back — the gather/scatter edges are host-side numpy.
    * ``"device"`` — the gather moves *inside* the program:
      ``cohort_fn(state, client_batches, client_weights, store_state,
      client_ids) -> (agg, losses, new_client_states, stamps)``.
      ``store_state`` is ``DeviceClientStateStore.device_state()`` (the
      full dense ``(N, ...)`` buffers + write stamps) and ``client_ids``
      the traced cohort id vector; the cohort's slice is gathered on
      device and the returned stacked updates + gather-time stamps feed
      ``core.client_state.device_scatter`` (fused into the round by
      ``make_round_program``, or applied later by the async engine) — no
      state traffic ever touches the host.

    Takes the full ``ServerState`` (not just params) because the
    algorithm's broadcast hook may read server-optimizer statistics (MIME's
    frozen momentum) or persistent algorithm state (SCAFFOLD's server
    control variate); only ``state.params`` (+ opt stats) are consumed, so
    the async engine may pass a state that is ``s`` versions stale.
    ``server_opt`` is only consulted by that hook and defaults to the
    ``fed``-configured server optimizer.
    """
    from repro.algorithms import resolve_algorithm  # noqa: PLC0415 — cycle

    alg = resolve_algorithm(fed, use_sampling)
    eff = alg.fed
    client_opt = client_opt or get_optimizer(eff.client_opt, eff.client_lr,
                                             eff.client_momentum)
    server_opt = server_opt or get_optimizer(fed.server_opt, fed.server_lr,
                                             fed.server_momentum)
    if eff.min_local_steps:
        grad_fn = _budget_masked(grad_fn)

    client_update = alg.make_client_update(grad_fn, client_opt)
    if wrap_client is not None:
        client_update = wrap_client(client_update)
    state_place = resolve_state_placement(fed, state_placement)
    ctx = _CohortCtx(
        alg=alg, client_update=client_update, spmd_axes=spmd_axes,
        stateful=alg.stateful, constrain_accum=constrain_accum, fed=fed,
        place=resolve_placement(fed, placement), chunk_size=chunk_size,
        prepare_params=prepare_params, server_opt=server_opt,
    )

    if ctx.stateful and state_place == "device":
        def cohort_fn(state: ServerState, client_batches,
                      client_weights=None, store_state=None,
                      client_ids=None, survivor_mask=None):
            if store_state is None or client_ids is None:
                raise ValueError(
                    f"algorithm {alg.name!r} is stateful with the device "
                    f"store: cohort_fn needs store_state "
                    f"(DeviceClientStateStore.device_state()) and the "
                    f"cohort's client_ids (prepare_ids)")
            cstates, stamps = device_gather(store_state, client_ids)
            agg, losses, new_states = _run_cohort(
                ctx, state, client_batches, client_weights, cstates,
                survivor_mask)
            return agg, losses, new_states, stamps
    elif ctx.stateful:
        def cohort_fn(state: ServerState, client_batches,
                      client_weights=None, client_states=None,
                      survivor_mask=None):
            if client_states is None:
                raise ValueError(
                    f"algorithm {alg.name!r} is stateful: cohort_fn needs "
                    f"the gathered client_states slice "
                    f"(ClientStateStore.gather)")
            return _run_cohort(ctx, state, client_batches, client_weights,
                               client_states, survivor_mask)
    else:
        def cohort_fn(state: ServerState, client_batches,
                      client_weights=None, survivor_mask=None):
            agg, losses, _ = _run_cohort(ctx, state, client_batches,
                                         client_weights, None, survivor_mask)
            return agg, losses

    return cohort_fn


def make_server_program(
    fed: FedConfig,
    *,
    server_opt: Optional[Optimizer] = None,
    use_sampling: bool = True,
    prepare_params: Optional[Callable] = None,
    finalize_params: Optional[Callable] = None,
) -> Callable:
    """Build ``server_fn(state, agg, discount=None) -> new_state``.

    The server half of a round: finalize the cohort aggregate into the
    pseudo-gradient and take one server-optimizer step — both owned by the
    algorithm's ``server_update`` hook. ``discount`` (optional traced
    scalar) is the async engine's ``staleness_discount ** s`` for an
    aggregate computed at params version ``v`` and applied at version
    ``v + s``; ``discount=None`` (or 1.0) is the synchronous update. The
    default hook scales the pseudo-gradient in fp32 and casts back, so a
    discount of exactly 1.0 is a bitwise no-op and the ``staleness=0``
    async path matches the fused sync program; algorithms may discount per
    parameter (``fedpa_precision``). ``use_sampling=False`` builds the
    stage for the burn-in regime's aggregate structure.
    """
    from repro.algorithms import resolve_algorithm  # noqa: PLC0415 — cycle

    alg = resolve_algorithm(fed, use_sampling)
    server_opt = server_opt or get_optimizer(fed.server_opt, fed.server_lr,
                                             fed.server_momentum)

    def server_fn(state: ServerState, agg, discount=None):
        params = (state.params if prepare_params is None
                  else prepare_params(state.params))
        new_state = alg.server_update(state._replace(params=params), agg,
                                      server_opt, discount)
        if finalize_params is not None:
            new_state = new_state._replace(
                params=finalize_params(new_state.params))
        return new_state

    return server_fn


def make_round_program(
    grad_fn: Callable,
    fed: FedConfig,
    *,
    placement: Optional[str] = None,
    chunk_size: Optional[int] = None,
    spmd_axes: Optional[Tuple[str, ...]] = None,
    use_sampling: bool = True,
    client_opt: Optional[Optimizer] = None,
    server_opt: Optional[Optimizer] = None,
    wrap_client: Optional[Callable] = None,
    prepare_params: Optional[Callable] = None,
    finalize_params: Optional[Callable] = None,
    constrain_accum: Optional[Callable] = None,
    state_placement: Optional[str] = None,
) -> Callable:
    """Build the fused ``round_fn(state, client_batches[, client_weights])``.

    Composes ``make_cohort_program`` and ``make_server_program`` into the
    single-dispatch synchronous round: cohort of client updates -> weighted
    aggregation -> server step. Returns ``(new_state, {"loss_first",
    "loss_last"})``. For a stateful algorithm with the host store the round
    takes the cohort's gathered ``client_states`` and returns
    ``(new_state, losses, new_client_states)``; with the device store
    (``state_placement="device"``) it takes ``(store_state, client_ids)``
    instead and returns ``(new_state, losses, new_store_state)`` — gather,
    clients, CAS scatter, and server step all in the one jitted program,
    so callers may donate ``store_state``
    (``core.client_state.jit_donating_store``) for an in-place update
    (see ``make_cohort_program``).

    ``use_sampling=False`` builds the burn-in-round variant of the config's
    algorithm (e.g. the FedAvg regime of a FedPA config, Section 5.2) with
    identical signature.

    Sharding hooks (all optional, identity by default) let the multi-pod
    path reuse this exact program structure:

    * ``wrap_client(update) -> update'`` — wrap the per-client update
      (``update`` returns a ``ClientResult``), e.g. to all-gather
      FSDP-sharded params at the compute boundary.
    * ``prepare_params(params)`` — applied to the server params before they
      are handed to clients / the server optimizer. Must be idempotent
      (sharding constraints are): the cohort and server stages each apply
      it, so the fused round runs it twice per round.
    * ``finalize_params(params)`` — applied to the post-update params.
    * ``constrain_accum(zeros, like_params)`` — sharding constraint for the
      sequential/chunked accumulator (applied per param-shaped component).

    The returned function is pure and jit-compatible; callers own the
    ``jax.jit`` (``FedSim`` jits it, the dry-run lowers it un-jitted).
    """
    cohort_fn = make_cohort_program(
        grad_fn, fed, placement=placement, chunk_size=chunk_size,
        spmd_axes=spmd_axes, use_sampling=use_sampling, client_opt=client_opt,
        server_opt=server_opt, wrap_client=wrap_client,
        prepare_params=prepare_params, constrain_accum=constrain_accum,
        state_placement=state_placement,
    )
    server_fn = make_server_program(
        fed, server_opt=server_opt, use_sampling=use_sampling,
        prepare_params=prepare_params, finalize_params=finalize_params,
    )

    from repro.algorithms import resolve_algorithm  # noqa: PLC0415 — cycle

    stateful = resolve_algorithm(fed, use_sampling).stateful
    state_place = resolve_state_placement(fed, state_placement)

    if stateful and state_place == "device":
        def round_fn(state: ServerState, client_batches, client_weights=None,
                     store_state=None, client_ids=None, survivor_mask=None):
            agg, metrics, new_states, stamps = cohort_fn(
                state, client_batches, client_weights, store_state,
                client_ids, survivor_mask)
            # within one program nothing can write between the gather and
            # this scatter, so the CAS always succeeds (drops == 0 by
            # construction; discarded). A survivor mask suppresses the
            # dropped clients' writes: their state must not land.
            new_store, _ = device_scatter(store_state, client_ids,
                                          new_states, stamps,
                                          write_mask=survivor_mask)
            return server_fn(state, agg), metrics, new_store
    elif stateful:
        def round_fn(state: ServerState, client_batches, client_weights=None,
                     client_states=None, survivor_mask=None):
            agg, metrics, new_states = cohort_fn(
                state, client_batches, client_weights, client_states,
                survivor_mask)
            return server_fn(state, agg), metrics, new_states
    else:
        def round_fn(state: ServerState, client_batches, client_weights=None,
                     survivor_mask=None):
            agg, metrics = cohort_fn(state, client_batches, client_weights,
                                     survivor_mask)
            return server_fn(state, agg), metrics

    return round_fn
