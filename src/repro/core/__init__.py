"""The paper's primary contribution: federated posterior averaging.

Layers (bottom-up): tree_math -> shrinkage/dp_delta/posterior/iasg
(the posterior machinery) -> repro.algorithms (the registered FedAlgorithm
strategies: client updates, payload aggregation, server steps) ->
round_program (the one-jit-per-round programs) -> engine (the ONE
staleness-general round loop + history recorder) -> round (simulation) /
sharded_round (multi-pod SPMD), both thin frontends over the engine.
``client``/``server`` keep the historical per-piece entry points.
"""
from repro.core.async_engine import AsyncRoundEngine  # noqa: F401
from repro.core.client import make_client_update  # noqa: F401
from repro.core.client_state import (  # noqa: F401
    BaseClientStateStore,
    ClientStateStore,
    DeviceClientStateStore,
    PopulationLayout,
    device_gather,
    device_scatter,
    jit_donating_store,
    make_client_store,
    population_layout,
    register_store,
)
from repro.core.diagnostics import (  # noqa: F401
    bias_variance,
    effective_sample_size,
    ess_from_losses,
)
from repro.core.dp_delta import (  # noqa: F401
    DPState,
    dp_delta,
    fedavg_delta,
    online_dp_delta,
    online_dp_init,
    online_dp_update,
)
from repro.core.engine import RoundEngine  # noqa: F401
from repro.core.history import RoundRecorder, json_scalar  # noqa: F401
from repro.core.iasg import IASGResult, iasg_sample, sgd_steps  # noqa: F401
from repro.core.posterior import (  # noqa: F401
    QuadraticClient,
    client_from_data,
    fedavg_fixed_point,
    global_posterior_mode,
    global_quadratic,
)
from repro.core.round import FedSim  # noqa: F401
from repro.core.round_program import (  # noqa: F401
    PLACEMENTS,
    make_cohort_program,
    make_round_program,
    make_server_program,
)
from repro.core.server import (  # noqa: F401
    ServerState,
    aggregate_deltas,
    aggregate_deltas_list,
    check_weight_total,
    init_server_state,
    normalized_weights,
    server_update,
    weighted_sum,
)
from repro.core.sharded_round import (  # noqa: F401
    default_placement,
    make_fed_round,
    make_fed_round_split,
)
from repro.core.shrinkage import dense_delta, shrinkage_cov  # noqa: F401
