"""Sampler-quality diagnostics (Appendix A.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def effective_sample_size(log_weights: jnp.ndarray) -> jnp.ndarray:
    """ESS = (sum w)^2 / sum w^2 with w given in log-space.

    The paper weighs samples proportionally to their posterior probability,
    i.e. log w_j = -loss(theta_j); computed with logsumexp stabilization.
    """
    lse1 = jax.scipy.special.logsumexp(log_weights)
    lse2 = jax.scipy.special.logsumexp(2.0 * log_weights)
    return jnp.exp(2.0 * lse1 - lse2)


def ess_from_losses(losses: jnp.ndarray) -> jnp.ndarray:
    """ESS of samples whose losses (negative log posteriors) are given."""
    return effective_sample_size(-losses)


def sample_autocorr(samples: jnp.ndarray, lag: int = 1) -> jnp.ndarray:
    """Mean lag-k autocorrelation across dimensions of (l, d) samples."""
    x = samples - samples.mean(axis=0)
    num = jnp.sum(x[:-lag] * x[lag:], axis=0)
    den = jnp.sum(x * x, axis=0) + 1e-30
    return jnp.mean(num / den)


def bias_variance(estimates: jnp.ndarray, exact: jnp.ndarray):
    """Empirical bias L2-norm and covariance Frobenius norm (Fig. 3 metrics).

    ``estimates``: (n_trials, d) independent estimates of the same exact (d,)
    quantity (a client delta). Returns (||bias||_2, ||Cov||_F).
    """
    mean = estimates.mean(axis=0)
    bias = jnp.linalg.norm(mean - exact)
    centered = estimates - mean
    cov = centered.T @ centered / max(estimates.shape[0] - 1, 1)
    return bias, jnp.linalg.norm(cov)
